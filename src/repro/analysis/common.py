"""Shared AST infrastructure for repro-lint (`python -m repro.analysis`).

Everything here is stdlib-`ast` based — no runtime dependency on jax, numpy
or the analyzed code itself, so the analyzer can run in a bare CI job and
never imports the modules it checks.

The pieces the four passes share:

* **Finding** — one `file:line rule-id message` diagnostic with a stable
  `baseline_key()` that survives unrelated line-number churn (the key hashes
  the *source text* of the flagged line plus its scope, not its position).

* **SourceFile** — a parsed file: AST, raw lines, per-line suppression
  directives (``# repro-lint: disable=<rule>[,<rule>...]``), and a parent
  map (stdlib ``ast`` has no parent pointers; several passes need to ask
  "is this attribute the base of a mutating ``.append`` call?").

* **ClassInfo / lock modelling** — per-class discovery of lock attributes
  (``self._lock = threading.Lock()``, anything lock-ish used in a ``with``)
  and Condition aliases (``self._cond = threading.Condition(self._lock)``
  acquires ``_lock``), plus `iter_with_held()`, the traversal that yields
  every node of a function body together with the set of locks lexically
  held there.  The ``*_locked`` naming convention is folded in here: a
  method whose name ends in ``_locked`` is analyzed as if ``self._lock``
  were held on entry (that is exactly the contract the runtime
  `serve.faults.assert_holds` helper cross-checks in debug mode).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

# attribute-call names treated as WRITES to their receiver for guarded-field
# inference: `self.xs.append(v)` mutates `self.xs` exactly like a store would
MUTATOR_METHODS = frozenset({
    "append", "appendleft", "extend", "extendleft", "insert", "add",
    "discard", "remove", "pop", "popitem", "popleft", "clear", "update",
    "setdefault", "move_to_end", "sort", "reverse", "rotate",
})

# the method convention: these run before the object is shared across
# threads, so unlocked stores in them define fields rather than race
CONSTRUCTOR_METHODS = frozenset({"__init__", "__new__", "__post_init__"})

LOCKED_SUFFIX = "_locked"
# the lock the `*_locked` suffix convention refers to
CONVENTION_LOCK = "_lock"

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\-]+)")


@dataclass(frozen=True)
class Finding:
    """One diagnostic. `scope` is the dotted lexical scope (Class.method)
    the finding sits in — part of the baseline key so a finding does not
    escape the baseline just because unrelated lines shifted it."""

    path: str          # posix-relative to the analysis root
    line: int
    col: int
    rule: str
    message: str
    scope: str = ""

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col} {self.rule} {self.message}"

    def baseline_key(self, source_line: str = "") -> str:
        norm = " ".join(source_line.split())
        return f"{self.path}::{self.rule}::{self.scope}::{norm}"

    def sort_key(self) -> tuple:
        return (self.path, self.line, self.col, self.rule)


def dotted_name(node: ast.AST) -> str | None:
    """`a.b.c` / `a` as a dotted string, None for anything non-name-like."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted_name(node.func)


def self_attr(node: ast.AST) -> str | None:
    """'x' when node is exactly `self.x`, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class SourceFile:
    """One parsed source file plus the side tables every pass needs."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        self.suppressions = self._collect_suppressions()
        self.span_suppressions = self._anchor_suppressions()
        self.parents: dict[int, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[id(child)] = parent

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        try:
            rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        return cls(path, rel, path.read_text())

    def _collect_suppressions(self) -> dict[int, frozenset[str]]:
        out: dict[int, frozenset[str]] = {}
        for i, line in enumerate(self.lines, start=1):
            if "repro-lint" not in line:
                continue
            m = _SUPPRESS_RE.search(line)
            if m:
                rules = frozenset(
                    r.strip() for r in m.group(1).split(",") if r.strip()
                )
                out[i] = rules
        return out

    def _anchor_suppressions(self) -> list[tuple[int, int, frozenset[str]]]:
        """Extend each suppression comment to its enclosing statement span.

        A ``# repro-lint: disable=<rule>`` on a decorator line or on the
        first line of a multiline call must cover the whole statement the
        comment sits on, not just its physical line (findings anchor to
        whichever line the relevant AST node starts on).  Simple statements
        are covered end to end; compound statements (def/class/if/with/...)
        are covered over their *header* only — decorators through the line
        before the first body statement — so a disable on a ``def`` line
        can never silence the entire function body.
        """
        if not self.suppressions:
            return []
        spans: list[tuple[int, int]] = []
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.stmt):
                continue
            start = node.lineno
            deco = getattr(node, "decorator_list", None)
            if deco:
                start = min([start] + [d.lineno for d in deco])
            body = getattr(node, "body", None)
            if isinstance(body, list) and body and isinstance(body[0],
                                                              ast.stmt):
                end = body[0].lineno - 1  # header only, never the body
            else:
                end = node.end_lineno or node.lineno
            spans.append((start, max(start, end)))
        out: list[tuple[int, int, frozenset[str]]] = []
        for line, rules in self.suppressions.items():
            best: tuple[int, int] | None = None
            for start, end in spans:
                if start <= line <= end and (
                        best is None
                        or end - start < best[1] - best[0]):
                    best = (start, end)
            if best is not None:
                out.append((best[0], best[1], rules))
        return out

    def suppressed(self, line: int, rule: str) -> bool:
        rules = self.suppressions.get(line)
        if rules is not None and (rule in rules or "all" in rules):
            return True
        for start, end, span_rules in self.span_suppressions:
            if start <= line <= end and (rule in span_rules
                                         or "all" in span_rules):
                return True
        return False

    def source_line(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""

    def parent(self, node: ast.AST) -> ast.AST | None:
        return self.parents.get(id(node))


# ---------------------------------------------------------------------------
# class / lock modelling
# ---------------------------------------------------------------------------
@dataclass
class ClassInfo:
    node: ast.ClassDef
    name: str
    methods: dict[str, ast.FunctionDef | ast.AsyncFunctionDef] = field(
        default_factory=dict)
    lock_attrs: set[str] = field(default_factory=set)     # real locks
    rlock_attrs: set[str] = field(default_factory=set)    # reentrant subset
    cond_aliases: dict[str, str] = field(default_factory=dict)  # cond -> lock

    def canonical_lock(self, attr: str) -> str:
        """Resolve a Condition alias to the lock it acquires."""
        return self.cond_aliases.get(attr, attr)

    def is_lock_like(self, attr: str) -> bool:
        return (attr in self.lock_attrs or attr in self.cond_aliases
                or "lock" in attr.lower())


def _lock_ctor(node: ast.AST) -> str | None:
    """'lock' / 'rlock' / 'cond' when node constructs a threading primitive."""
    if not isinstance(node, ast.Call):
        return None
    name = call_name(node)
    if name is None:
        return None
    leaf = name.rsplit(".", 1)[-1]
    if leaf == "Lock":
        return "lock"
    if leaf == "RLock":
        return "rlock"
    if leaf == "Condition":
        return "cond"
    return None


def collect_classes(sf: SourceFile) -> list[ClassInfo]:
    """Lexical class table: methods, lock attributes, Condition aliases.

    Inheritance is intentionally not resolved — guarded-field inference is
    per-lexical-class (a subclass in another module does not see the parent's
    guarded set; document, don't guess)."""
    out: list[ClassInfo] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        info = ClassInfo(node=node, name=node.name)
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info.methods[item.name] = item
        for meth in info.methods.values():
            for sub in ast.walk(meth):
                if not isinstance(sub, ast.Assign):
                    continue
                kind = _lock_ctor(sub.value)
                if kind is None:
                    continue
                for tgt in sub.targets:
                    attr = self_attr(tgt)
                    if attr is None:
                        continue
                    if kind == "lock":
                        info.lock_attrs.add(attr)
                    elif kind == "rlock":
                        info.lock_attrs.add(attr)
                        info.rlock_attrs.add(attr)
                    else:  # Condition(maybe_lock)
                        args = sub.value.args
                        under = self_attr(args[0]) if args else None
                        if under is not None:
                            info.cond_aliases[attr] = under
                        else:
                            # a bare Condition owns its own (hidden) lock
                            info.lock_attrs.add(attr)
        out.append(info)
    return out


def with_locks(node: ast.With | ast.AsyncWith, info: ClassInfo | None
               ) -> set[str]:
    """Lock attributes a `with` statement acquires (`with self._lock:` /
    `with self._cond:` — aliases canonicalized)."""
    held: set[str] = set()
    for item in node.items:
        attr = self_attr(item.context_expr)
        if attr is None:
            continue
        if info is not None:
            if info.is_lock_like(attr):
                held.add(info.canonical_lock(attr))
        elif "lock" in attr.lower():
            held.add(attr)
    return held


def base_held(func: ast.FunctionDef | ast.AsyncFunctionDef) -> frozenset[str]:
    """Locks a function may assume held on entry: the `*_locked` suffix
    convention promises the caller acquired `self._lock`."""
    if func.name.endswith(LOCKED_SUFFIX):
        return frozenset({CONVENTION_LOCK})
    return frozenset()


def iter_with_held(func: ast.FunctionDef | ast.AsyncFunctionDef,
                   info: ClassInfo | None = None):
    """Yield `(node, held)` for every node in `func`'s body, where `held`
    is the frozenset of lock attrs lexically held at that node.

    Nested function/lambda bodies reset `held` to empty — they execute
    later (thread targets, callbacks), not under the enclosing `with`."""

    def visit(node: ast.AST, held: frozenset[str], top: bool):
        if not top and isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            inner = (base_held(node)
                     if not isinstance(node, ast.Lambda) else frozenset())
            yield node, held
            for child in ast.iter_child_nodes(node):
                yield from visit(child, inner, False)
            return
        yield node, held
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = held | frozenset(with_locks(node, info))
            for item in node.items:
                yield from visit(item, held, False)
            for child in node.body:
                yield from visit(child, inner, False)
            return
        for child in ast.iter_child_nodes(node):
            yield from visit(child, held, False)

    start = base_held(func)
    for child in ast.iter_child_nodes(func):
        yield from visit(child, start, False)


def access_kind(sf: SourceFile, node: ast.Attribute) -> str:
    """'read' / 'write' for a `self.x` attribute node.

    Writes: plain stores (`self.x = ...`, `self.x += ...`, `del self.x`),
    container-slot stores (`self.x[k] = ...`, `del self.x[k]`), and calls
    to mutating methods (`self.x.append(...)`, `self.x[k].append(...)`)."""
    if isinstance(node.ctx, (ast.Store, ast.Del)):
        return "write"
    parent = sf.parent(node)
    # self.x[k] = v  /  del self.x[k]
    if (isinstance(parent, ast.Subscript)
            and isinstance(parent.ctx, (ast.Store, ast.Del))):
        return "write"
    # self.x.append(v)  /  self.x[k].append(v)
    hop = parent
    if isinstance(hop, ast.Subscript) and isinstance(hop.ctx, ast.Load):
        hop = sf.parent(hop)
    if (isinstance(hop, ast.Attribute) and hop.attr in MUTATOR_METHODS
            and isinstance(sf.parent(hop), ast.Call)
            and sf.parent(hop).func is hop):
        return "write"
    return "read"


def scope_of(sf: SourceFile, node: ast.AST) -> str:
    """Dotted Class.method scope containing `node` (lexical)."""
    parts: list[str] = []
    cur = sf.parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            parts.append(cur.name)
        cur = sf.parent(cur)
    return ".".join(reversed(parts))
