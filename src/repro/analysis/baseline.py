"""Baseline file handling: grandfather existing findings, gate new ones.

The baseline (checked in as ``.repro-lint-baseline.json``) maps a stable
finding key to an occurrence count.  The key is
``path::rule::scope::normalized-source-line`` — no line numbers, so
unrelated edits that shift a grandfathered finding up or down do not
resurrect it, while *changing the flagged line itself* (or moving it to a
new scope) does.  A count accommodates N identical lines in one scope.

Workflow: fix every finding you can; suppress intentional ones in-line
(``# repro-lint: disable=<rule>`` with a justification); only what remains
goes in the baseline via ``python -m repro.analysis --write-baseline``.
New findings against a checked-in baseline fail CI.  Stale entries (the
finding disappeared) are reported as a warning so the file shrinks over
time instead of fossilizing.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path

from repro.analysis.common import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = ".repro-lint-baseline.json"


def load(path: Path) -> Counter:
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported baseline version {data.get('version')!r} in {path}"
        )
    return Counter({str(k): int(v) for k, v in data["findings"].items()})


def save(path: Path, keys: Counter) -> None:
    payload = {
        "version": BASELINE_VERSION,
        "comment": (
            "grandfathered repro-lint findings; see docs/concurrency.md — "
            "regenerate with: python -m repro.analysis --write-baseline"
        ),
        "findings": {k: keys[k] for k in sorted(keys)},
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=False) + "\n")


def apply(findings: list[tuple[Finding, str]], baseline: Counter
          ) -> tuple[list[Finding], int, list[str]]:
    """Split findings into (new, n_suppressed, stale_keys).

    `findings` pairs each Finding with its baseline key.  Up to the
    baselined count of each key is suppressed; the rest are new.  Keys in
    the baseline with no remaining occurrence are stale.
    """
    budget = Counter(baseline)
    new: list[Finding] = []
    suppressed = 0
    for f, key in findings:
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            suppressed += 1
        else:
            new.append(f)
    stale = sorted(k for k, n in budget.items() if n > 0)
    return new, suppressed, stale
