"""Pallas pass: Mosaic-lowerability and kernel-structure pre-checks.

The repo's custom kernels validate in interpret mode on CPU; the ROADMAP's
TPU-verification item is blocked on hardware.  This pass front-loads the
hazards that are statically visible today, so "works interpreted, dies in
Mosaic" bugs surface at lint time instead of on silicon:

* **pallas-lowering** — ops inside a ``pl.pallas_call`` kernel body that
  are interpret-only (or historically unreliable) under the Mosaic TPU
  compiler: ``lax.top_k``, sort/argsort, ``take_along_axis`` and the
  gather/scatter family.  The complementary *allowlist* (what the repo's
  kernels are expected to stick to — elementwise math, ``dot_general``,
  ``broadcasted_iota``, masking/select, ``fori_loop``, DMA builtins) is
  documented in docs/static-analysis.md; the check itself is a denylist so
  new jnp helpers don't all need enumeration.

* **pallas-blockspec** — BlockSpec/grid arithmetic: an ``index_map``
  lambda whose arity doesn't match the grid rank (plus scalar-prefetch
  refs), whose returned tuple length doesn't match the block shape, or
  that returns *element* offsets (``i * block_m``) where Pallas expects
  *block* indices; and grid entries of the form ``a // b`` with no
  ``a % b`` divisibility check anywhere in the wrapper (the remainder
  rows would silently never be visited).

* **pallas-anyspace** — direct subscript / ``pl.load`` / ``pl.store``
  access to a ref whose BlockSpec pins ``memory_space=ANY``.  ANY-space
  refs live wherever the compiler put them (usually HBM) and must be
  moved through explicit DMA (``ref.at[...]`` + ``make_async_copy``) or
  accepted as a known Mosaic hazard — the repo's segment-reduce output
  accumulation is the sanctioned, documented instance.

* **pallas-out-init** — reading an output ref that is neither
  zero-initialized through ``input_output_aliases`` nor written by an
  unconditional (or ``pl.when``-guarded first-step) store before the
  read.  Output buffers start uninitialized; ``o_ref[...] += x`` as the
  first access accumulates into garbage on hardware even though
  interpret mode's zero-filled buffers hide it.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analysis.common import (Finding, SourceFile, call_name,
                                   dotted_name, scope_of)

RULES = ("pallas-lowering", "pallas-blockspec", "pallas-anyspace",
         "pallas-out-init")

# interpret-only / Mosaic-hostile ops (see docs/static-analysis.md for the
# positive allowlist these are the complement of)
DENY_OPS = frozenset({
    "top_k", "approx_max_k", "approx_min_k",
    "sort", "argsort", "sort_key_val", "searchsorted",
    "take", "take_along_axis", "gather",
    "scatter", "scatter_add", "scatter_max", "scatter_min", "scatter_mul",
    "unique", "nonzero",
})
_OP_BASES = frozenset({"jax", "jnp", "lax", "np", "numpy"})


def _leaf(name: str | None) -> str | None:
    return name.rsplit(".", 1)[-1] if name else None


def _deny_call(node: ast.Call) -> str | None:
    name = call_name(node)
    if not name or "." not in name:
        return None
    base, leaf = name.split(".", 1)[0], name.rsplit(".", 1)[-1]
    if base in _OP_BASES and leaf in DENY_OPS:
        return name
    return None


# ---------------------------------------------------------------------------
# call-site model
# ---------------------------------------------------------------------------
@dataclass
class _BlockSpec:
    node: ast.Call
    block_shape: ast.Tuple | None = None
    index_map: ast.AST | None = None
    any_space: bool = False


@dataclass
class _Site:
    call: ast.Call
    kernel: ast.FunctionDef
    n_prefetch: int = 0
    in_specs: list[_BlockSpec] = field(default_factory=list)
    out_specs: list[_BlockSpec] = field(default_factory=list)
    n_out: int = 0
    n_scratch: int = 0
    grid: ast.AST | None = None
    aliased_outs: set[int] = field(default_factory=set)
    specs_known: bool = False


def _parse_blockspec(node: ast.AST) -> _BlockSpec | None:
    if not (isinstance(node, ast.Call)
            and _leaf(call_name(node)) == "BlockSpec"):
        return None
    bs = _BlockSpec(node=node)
    if node.args and isinstance(node.args[0], ast.Tuple):
        bs.block_shape = node.args[0]
    if len(node.args) >= 2:
        bs.index_map = node.args[1]
    for kw in node.keywords:
        if kw.arg == "block_shape" and isinstance(kw.value, ast.Tuple):
            bs.block_shape = kw.value
        elif kw.arg == "index_map":
            bs.index_map = kw.value
        elif kw.arg == "memory_space":
            bs.any_space = (_leaf(dotted_name(kw.value)) == "ANY")
    return bs


def _spec_list(node: ast.AST | None) -> list[_BlockSpec] | None:
    """A [BlockSpec, ...] literal / single BlockSpec as a list, else None."""
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        out = []
        for elt in node.elts:
            bs = _parse_blockspec(elt)
            if bs is None:
                return None
            out.append(bs)
        return out
    bs = _parse_blockspec(node)
    return [bs] if bs is not None else None


def _seq_len(node: ast.AST | None) -> int | None:
    if isinstance(node, (ast.List, ast.Tuple)):
        return len(node.elts)
    if node is not None:
        return 1
    return None


def _kernel_def(sf: SourceFile, call: ast.Call,
                funcs: dict[str, ast.FunctionDef]
                ) -> tuple[ast.FunctionDef | None, int]:
    """(kernel FunctionDef, positionally-bound leading params) for the
    first pallas_call argument; handles `functools.partial(kernel, ...)`
    and local `kernel = functools.partial(...)` aliases."""
    if not call.args:
        return None, 0
    expr: ast.AST = call.args[0]
    for _ in range(3):
        if isinstance(expr, ast.Name):
            if expr.id in funcs:
                return funcs[expr.id], 0
            # local alias: kernel = functools.partial(_kernel, ...)
            cur = sf.parent(call)
            target = None
            while cur is not None and target is None:
                if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Module)):
                    for sub in ast.walk(cur):
                        if (isinstance(sub, ast.Assign)
                                and any(isinstance(t, ast.Name)
                                        and t.id == expr.id
                                        for t in sub.targets)):
                            target = sub.value
                            break
                cur = sf.parent(cur)
            if target is None:
                return None, 0
            expr = target
            continue
        if (isinstance(expr, ast.Call)
                and _leaf(call_name(expr)) == "partial" and expr.args):
            bound = len(expr.args) - 1
            inner = expr.args[0]
            if isinstance(inner, ast.Name) and inner.id in funcs:
                return funcs[inner.id], bound
            return None, 0
        return None, 0
    return None, 0


def _resolve_local(sf: SourceFile, use_site: ast.AST, name: str
                   ) -> ast.AST | None:
    cur = sf.parent(use_site)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Module)):
            for sub in ast.walk(cur):
                if (isinstance(sub, ast.Assign)
                        and any(isinstance(t, ast.Name) and t.id == name
                                for t in sub.targets)):
                    return sub.value
        cur = sf.parent(cur)
    return None


def _collect_sites(sf: SourceFile) -> list[_Site]:
    funcs = {
        n.name: n for n in ast.walk(sf.tree)
        if isinstance(n, ast.FunctionDef)
    }
    sites: list[_Site] = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and _leaf(call_name(node)) == "pallas_call"):
            continue
        kernel, bound = _kernel_def(sf, node, funcs)
        if kernel is None:
            continue
        site = _Site(call=node, kernel=kernel)
        kwargs = {kw.arg: kw.value for kw in node.keywords if kw.arg}

        spec_src = kwargs
        grid_spec = kwargs.get("grid_spec")
        if isinstance(grid_spec, ast.Name):
            grid_spec = _resolve_local(sf, node, grid_spec.id)
        if (isinstance(grid_spec, ast.Call)
                and _leaf(call_name(grid_spec)) in (
                    "PrefetchScalarGridSpec", "GridSpec")):
            spec_src = {kw.arg: kw.value for kw in grid_spec.keywords
                        if kw.arg}
            npre = spec_src.get("num_scalar_prefetch")
            if isinstance(npre, ast.Constant) and isinstance(npre.value, int):
                site.n_prefetch = npre.value

        in_specs = _spec_list(spec_src.get("in_specs"))
        out_specs = _spec_list(spec_src.get("out_specs"))
        site.grid = spec_src.get("grid")
        if isinstance(site.grid, ast.Name):
            site.grid = _resolve_local(sf, node, site.grid.id)
        site.n_scratch = _seq_len(spec_src.get("scratch_shapes")) or 0
        n_out = (_seq_len(spec_src.get("out_specs"))
                 or _seq_len(kwargs.get("out_shape")))
        if in_specs is not None and n_out is not None:
            site.in_specs = in_specs
            site.out_specs = out_specs or []
            site.n_out = n_out
            site.specs_known = True

        aliases = kwargs.get("input_output_aliases")
        if isinstance(aliases, ast.Dict):
            for v in aliases.values:
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    site.aliased_outs.add(v.value)

        # account for params consumed by functools.partial positional args
        site._bound = bound  # type: ignore[attr-defined]
        sites.append(site)
    return sites


def _ref_roles(site: _Site) -> tuple[dict[str, _BlockSpec | None],
                                     dict[str, int]]:
    """(ref name -> BlockSpec or None, output ref name -> output index)."""
    kernel = site.kernel
    params = [a.arg for a in kernel.args.posonlyargs + kernel.args.args]
    params = params[getattr(site, "_bound", 0):]
    spec_of: dict[str, _BlockSpec | None] = {}
    outs: dict[str, int] = {}
    i = site.n_prefetch
    for bs in site.in_specs:
        if i < len(params):
            spec_of[params[i]] = bs
        i += 1
    for j in range(site.n_out):
        if i < len(params):
            bs = site.out_specs[j] if j < len(site.out_specs) else None
            spec_of[params[i]] = bs
            outs[params[i]] = j
        i += 1
    return spec_of, outs


# ---------------------------------------------------------------------------
# access classification inside a kernel body
# ---------------------------------------------------------------------------
def _when_guarded(sf: SourceFile, node: ast.AST,
                  kernel: ast.FunctionDef) -> bool:
    cur = sf.parent(node)
    while cur is not None and cur is not kernel:
        if isinstance(cur, ast.FunctionDef):
            for dec in cur.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                if _leaf(dotted_name(d)) == "when":
                    return True
        cur = sf.parent(cur)
    return False


def _accesses(sf: SourceFile, kernel: ast.FunctionDef, names: set[str]):
    """Yield (name, line, col, kind, guarded) for every ref access;
    kind in {'read', 'write', 'aug'} — 'write' means a pure store."""
    for node in ast.walk(kernel):
        if isinstance(node, ast.Subscript):
            if not (isinstance(node.value, ast.Name)
                    and node.value.id in names):
                continue
            parent = sf.parent(node)
            guarded = _when_guarded(sf, node, kernel)
            if isinstance(parent, ast.AugAssign) and parent.target is node:
                kind = "aug"
            elif isinstance(node.ctx, (ast.Store, ast.Del)):
                kind = "write"
            else:
                kind = "read"
            yield node.value.id, node.lineno, node.col_offset, kind, guarded
        elif isinstance(node, ast.Call):
            leaf = _leaf(call_name(node))
            if leaf not in ("load", "store") or not node.args:
                continue
            ref = node.args[0]
            if not (isinstance(ref, ast.Name) and ref.id in names):
                continue
            guarded = _when_guarded(sf, node, kernel)
            kind = "read" if leaf == "load" else "write"
            yield ref.id, node.lineno, node.col_offset, kind, guarded


# ---------------------------------------------------------------------------
# rule bodies
# ---------------------------------------------------------------------------
def _check_lowering(sf: SourceFile, site: _Site) -> list[Finding]:
    out = []
    for node in ast.walk(site.kernel):
        if isinstance(node, ast.Call):
            name = _deny_call(node)
            if name:
                out.append(Finding(
                    sf.rel, node.lineno, node.col_offset, "pallas-lowering",
                    f"{name} inside a pallas_call kernel is interpret-only "
                    "under Mosaic TPU — restructure (iterative argmax / "
                    "masked select) or gate on the ROADMAP TPU-verification "
                    "item",
                    scope_of(sf, node)))
    return out


def _check_anyspace(sf: SourceFile, site: _Site) -> list[Finding]:
    spec_of, _ = _ref_roles(site)
    any_refs = {n for n, bs in spec_of.items() if bs is not None
                and bs.any_space}
    if not any_refs:
        return []
    out, seen = [], set()
    for name, line, col, kind, _g in _accesses(sf, site.kernel, any_refs):
        if (line, name) in seen:
            continue
        seen.add((line, name))
        out.append(Finding(
            sf.rel, line, col, "pallas-anyspace",
            f"direct {kind} of ANY-memory-space ref {name!r} — ANY refs "
            "need explicit DMA (.at[...] + make_async_copy); a direct "
            "access lowers to an unmanaged round trip (or not at all)",
            scope_of(sf, site.kernel)))
    return out


def _check_out_init(sf: SourceFile, site: _Site) -> list[Finding]:
    _, outs = _ref_roles(site)
    targets = {n for n, j in outs.items() if j not in site.aliased_outs}
    if not targets:
        return []
    findings = []
    for name in sorted(targets):
        acc = [a for a in _accesses(sf, site.kernel, {name})]
        reads = [(l, c) for _n, l, c, k, _g in acc if k in ("read", "aug")]
        if not reads:
            continue
        pure = [(l, g) for _n, l, _c, k, g in acc if k == "write"]
        if any(g for _l, g in pure):
            continue  # a pl.when-guarded first-step init exists
        first_read = min(reads)
        if any(l < first_read[0] for l, _g in pure):
            continue  # unconditional store precedes every read
        findings.append(Finding(
            sf.rel, first_read[0], first_read[1], "pallas-out-init",
            f"output ref {name!r} is read before any store and is not "
            "zero-initialized via input_output_aliases — interpret mode's "
            "zero-filled buffers hide the garbage a real TPU would read",
            scope_of(sf, site.kernel)))
    return findings


def _check_blockspec(sf: SourceFile, site: _Site) -> list[Finding]:
    findings = []
    rank = None
    if isinstance(site.grid, ast.Tuple):
        rank = len(site.grid.elts)

    for bs in site.in_specs + site.out_specs:
        if bs is None or not isinstance(bs.index_map, ast.Lambda):
            continue
        lam = bs.index_map
        arity = len(lam.args.posonlyargs + lam.args.args)
        expected = None if rank is None else rank + site.n_prefetch
        if expected is not None and arity != expected:
            findings.append(Finding(
                sf.rel, lam.lineno, lam.col_offset, "pallas-blockspec",
                f"index_map takes {arity} arg(s) but the grid has rank "
                f"{rank}" + (f" plus {site.n_prefetch} scalar-prefetch "
                             "ref(s)" if site.n_prefetch else ""),
                scope_of(sf, bs.node)))
        if bs.block_shape is not None and isinstance(lam.body, ast.Tuple):
            n_blk = len(bs.block_shape.elts)
            n_ret = len(lam.body.elts)
            if n_ret != n_blk:
                findings.append(Finding(
                    sf.rel, lam.lineno, lam.col_offset, "pallas-blockspec",
                    f"index_map returns {n_ret} indices but block_shape "
                    f"has {n_blk} dims",
                    scope_of(sf, bs.node)))
            else:
                lam_params = {a.arg for a in lam.args.args
                              + lam.args.posonlyargs}
                for pos, (ret, dim) in enumerate(
                        zip(lam.body.elts, bs.block_shape.elts)):
                    if not (isinstance(ret, ast.BinOp)
                            and isinstance(ret.op, ast.Mult)):
                        continue
                    for a, b in ((ret.left, ret.right),
                                 (ret.right, ret.left)):
                        if (isinstance(a, ast.Name) and a.id in lam_params
                                and ast.dump(b) == ast.dump(dim)):
                            findings.append(Finding(
                                sf.rel, ret.lineno, ret.col_offset,
                                "pallas-blockspec",
                                f"index_map dim {pos} returns an *element* "
                                "offset (grid index × block size) — Pallas "
                                "index maps are in block units; the blocks "
                                "read would be strided past the array",
                                scope_of(sf, bs.node)))
                            break

    # grid divisibility: a // b in the grid needs an a % b check somewhere
    if site.grid is not None:
        enclosing = sf.parent(site.call)
        while enclosing is not None and not isinstance(
                enclosing, (ast.FunctionDef, ast.AsyncFunctionDef)):
            enclosing = sf.parent(enclosing)
        scope_node = enclosing if enclosing is not None else sf.tree
        mods = {
            (ast.dump(n.left), ast.dump(n.right))
            for n in ast.walk(scope_node)
            if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod)
        }
        seen_divs = set()
        for node in ast.walk(site.grid):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.FloorDiv)):
                continue
            key = (ast.dump(node.left), ast.dump(node.right))
            if key in seen_divs:
                continue
            seen_divs.add(key)
            if key not in mods:
                try:
                    expr = ast.unparse(node)
                except Exception:  # pragma: no cover - unparse is py3.9+
                    expr = "a // b"
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset, "pallas-blockspec",
                    f"grid entry {expr} floor-divides with no matching "
                    "divisibility check (assert a % b == 0) in the wrapper "
                    "— trailing remainder rows are silently never visited",
                    scope_of(sf, site.call)))
    return findings


def run(sf: SourceFile) -> list[Finding]:
    if "pallas_call" not in sf.text:
        return []
    findings: list[Finding] = []
    for site in _collect_sites(sf):
        findings.extend(_check_lowering(sf, site))
        if site.specs_known:
            findings.extend(_check_anyspace(sf, site))
            findings.extend(_check_out_init(sf, site))
            findings.extend(_check_blockspec(sf, site))
    return findings
