"""Pass 2 — retrace hazards in jit-compiled functions.

The serving tier's latency model rests on `trace_count()`-pinned kernels:
a publish must cost a buffer swap, never a recompile.  The hazards that
silently defeat that pinning are all visible in the AST:

``traced-branch``
    A `jit`-compiled function whose body branches *in Python* (`if` /
    `while` / `for` / ternary / `assert`) on a traced argument.  At best
    the branch bakes one path per concrete value into the cache (a retrace
    per distinct value); at worst it raises ConcretizationTypeError in
    production.  Static arguments (`static_argnums` / `static_argnames`),
    `x is None` checks (resolved at trace time), shape/dtype attribute
    tests (`x.shape[0] > 0`, `len(x)` — static under tracing), and params
    annotated as pytree containers (`arrays: tuple` — the structure is part
    of the cache key, only leaves are tracers) are exempt.

``shape-leak``
    `int(...)` / `float(...)` / `bool(...)` or an f-string applied to a
    traced argument inside a jit body: each concretizes the tracer, which
    forces a device sync at best and keys the jit cache on the *value* at
    worst.  Shape/dtype projections stay exempt as above.

``static-args``
    `static_argnums` that is not a literal int/tuple-of-ints,
    `static_argnames` naming a parameter the function does not have (the
    argument silently stays traced — the pin never existed), and same-file
    call sites that pass a list/dict/set literal or an `np.*`/`jnp.*` array
    expression in a static position (unhashable → TypeError, or a cache
    entry per array object).

jit roots recognized: `@jax.jit` / `@functools.partial(jax.jit, ...)`
decorators, `f = jax.jit(g, ...)` module/method assignments (including the
`self._sweep = jax.jit(self._sweep_impl)` bound-method idiom — `self` is
closure state there, not a traced arg), and `jax.jit(lambda ...: ...)`.
"""
from __future__ import annotations

import ast

from repro.analysis.common import (
    Finding,
    SourceFile,
    call_name,
    dotted_name,
    scope_of,
    self_attr,
)

RULES = ("traced-branch", "shape-leak", "static-args")

_JIT_NAMES = {"jax.jit", "jit"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval"}
# container-annotated params are pytrees whose STRUCTURE is part of the jit
# cache key: iterating / truth-testing / len()-ing them is resolved at trace
# time (only the leaves are tracers) — `arrays: tuple` in serve/foldin.py
_CONTAINER_ANNOTS = {"tuple", "list", "dict", "Tuple", "List", "Dict",
                     "Sequence", "Mapping", "FrozenSet", "frozenset"}
_SHAPE_SAFE_CALLS = {"len", "isinstance", "type", "callable", "hasattr",
                     "getattr"}
_CONCRETIZERS = {"int", "float", "bool", "complex"}


def _is_jit_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and call_name(node) in _JIT_NAMES)


def _partial_jit(deco: ast.AST) -> ast.Call | None:
    """`functools.partial(jax.jit, ...)` → the partial Call node."""
    if (isinstance(deco, ast.Call)
            and call_name(deco) in ("functools.partial", "partial")
            and deco.args and dotted_name(deco.args[0]) in _JIT_NAMES):
        return deco
    return None


def _const_str_tuple(node: ast.AST) -> list[str] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            out.append(elt.value)
        return out
    return None


def _const_int_tuple(node: ast.AST) -> list[int] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, int)):
                return None
            out.append(elt.value)
        return out
    return None


def _param_names(func: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
                 ) -> list[str]:
    a = func.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


class _JitSite:
    """One jit-compiled function to analyze."""

    def __init__(self, func, statics: set[str], jit_call: ast.Call | None,
                 alias: str | None, bound_self: bool):
        self.func = func            # FunctionDef / Lambda
        self.statics = statics      # static param names
        self.jit_call = jit_call    # the jax.jit(...) call node, if any
        self.alias = alias          # name call sites use, for static-args
        self.bound_self = bound_self


def _statics_from_kwargs(kwargs: list[ast.keyword],
                         func, sf: SourceFile,
                         findings: list[Finding]) -> set[str]:
    """static param names from static_argnums/static_argnames keywords,
    emitting `static-args` findings for malformed specs."""
    params = _param_names(func) if func is not None else []
    statics: set[str] = set()
    for kw in kwargs:
        if kw.arg == "static_argnames":
            names = _const_str_tuple(kw.value)
            if names is None:
                continue
            for n in names:
                if func is not None and n not in params:
                    findings.append(Finding(
                        path=sf.rel, line=kw.value.lineno,
                        col=kw.value.col_offset, rule="static-args",
                        scope=scope_of(sf, kw.value),
                        message=(f"static_argnames entry '{n}' is not a "
                                 "parameter — the argument stays traced"),
                    ))
                statics.add(n)
        elif kw.arg == "static_argnums":
            nums = _const_int_tuple(kw.value)
            if nums is None:
                findings.append(Finding(
                    path=sf.rel, line=kw.value.lineno,
                    col=kw.value.col_offset, rule="static-args",
                    scope=scope_of(sf, kw.value),
                    message=("static_argnums must be a literal int or "
                             "tuple of ints (a computed/array value cannot "
                             "pin anything)"),
                ))
                continue
            for i in nums:
                if func is None:
                    continue
                if 0 <= i < len(params):
                    statics.add(params[i])
                else:
                    findings.append(Finding(
                        path=sf.rel, line=kw.value.lineno,
                        col=kw.value.col_offset, rule="static-args",
                        scope=scope_of(sf, kw.value),
                        message=(f"static_argnums index {i} is out of range "
                                 f"for a {len(params)}-parameter function"),
                    ))
    return statics


def _collect_sites(sf: SourceFile, findings: list[Finding]) -> list[_JitSite]:
    sites: list[_JitSite] = []
    class_methods: dict[str, dict[str, ast.FunctionDef]] = {}
    module_funcs: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef):
            class_methods[node.name] = {
                m.name: m for m in node.body
                if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            module_funcs.setdefault(node.name, node)

    # decorator form
    for node in ast.walk(sf.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for deco in node.decorator_list:
            if dotted_name(deco) in _JIT_NAMES:
                sites.append(_JitSite(node, set(), None, node.name, False))
            elif _is_jit_call(deco):
                statics = _statics_from_kwargs(
                    deco.keywords, node, sf, findings)
                sites.append(_JitSite(node, statics, deco, node.name, False))
            elif (p := _partial_jit(deco)) is not None:
                statics = _statics_from_kwargs(p.keywords, node, sf, findings)
                sites.append(_JitSite(node, statics, p, node.name, False))

    # assignment form: name = jax.jit(target, ...)
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Assign) and _is_jit_call(node.value)):
            continue
        call = node.value
        if not call.args:
            continue
        target_expr = call.args[0]
        alias = None
        if len(node.targets) == 1:
            alias = (self_attr(node.targets[0])
                     or dotted_name(node.targets[0]))
        func = None
        bound_self = False
        if isinstance(target_expr, ast.Lambda):
            func = target_expr
        elif (attr := self_attr(target_expr)) is not None:
            # self._impl: resolve within the lexically enclosing class
            cur = sf.parent(node)
            while cur is not None and not isinstance(cur, ast.ClassDef):
                cur = sf.parent(cur)
            if cur is not None:
                func = class_methods.get(cur.name, {}).get(attr)
                bound_self = func is not None
        elif (name := dotted_name(target_expr)) is not None:
            func = module_funcs.get(name)
        if func is None:
            continue
        statics = _statics_from_kwargs(call.keywords, func, sf, findings)
        if bound_self:
            statics.add("self")
        sites.append(_JitSite(func, statics, call, alias, bound_self))
    return sites


def _container_params(func) -> set[str]:
    """Params annotated as pytree containers (`arrays: tuple`) — their
    structure is trace-time static."""
    out: set[str] = set()
    a = func.args
    for p in (*a.posonlyargs, *a.args, *a.kwonlyargs):
        ann = getattr(p, "annotation", None)
        if ann is None:
            continue
        base = ann.value if isinstance(ann, ast.Subscript) else ann
        name = dotted_name(base)
        if name is not None and name.rsplit(".", 1)[-1] in _CONTAINER_ANNOTS:
            out.add(p.arg)
    return out


def _traced_params(site: _JitSite) -> set[str]:
    params = set(_param_names(site.func))
    params.discard("self")
    return params - site.statics - _container_params(site.func)


def _hazard_names(sf: SourceFile, expr: ast.AST, traced: set[str]
                  ) -> list[ast.Name]:
    """Traced-param Name loads in `expr` that are NOT behind a static
    projection (`.shape` etc.), a `len()`-style static call, or an
    `is None` check."""
    out: list[ast.Name] = []
    for node in ast.walk(expr):
        if not (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id in traced):
            continue
        safe = False
        cur = node
        parent = sf.parent(cur)
        # climb to (and including) `expr` — the test may itself be the
        # exempting node, e.g. `if y is None:` where expr IS the Compare
        while parent is not None:
            if (isinstance(parent, ast.Attribute)
                    and parent.attr in _STATIC_ATTRS):
                safe = True
                break
            if (isinstance(parent, ast.Call)
                    and call_name(parent) in _SHAPE_SAFE_CALLS):
                safe = True
                break
            if isinstance(parent, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot))
                for op in parent.ops
            ):
                safe = True
                break
            if parent is expr:
                break
            cur = parent
            parent = sf.parent(cur)
        if not safe:
            out.append(node)
    return out


def _check_body(sf: SourceFile, site: _JitSite, findings: list[Finding]):
    traced = _traced_params(site)
    if not traced:
        return
    body = site.func.body
    nodes = (ast.walk(site.func) if not isinstance(body, list)
             else (n for stmt in body for n in ast.walk(stmt)))
    scope = None
    for node in nodes:
        tests: list[tuple[ast.AST, str]] = []
        if isinstance(node, (ast.If, ast.While)):
            tests.append((node.test, "branches in Python on"))
        elif isinstance(node, ast.IfExp):
            tests.append((node.test, "branches (ternary) in Python on"))
        elif isinstance(node, ast.Assert):
            tests.append((node.test, "asserts in Python on"))
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            tests.append((node.iter, "iterates in Python over"))
        for test, verb in tests:
            for nm in _hazard_names(sf, test, traced):
                if scope is None:
                    scope = scope_of(sf, node)
                findings.append(Finding(
                    path=sf.rel, line=test.lineno, col=test.col_offset,
                    rule="traced-branch", scope=scope,
                    message=(
                        f"jit-compiled function {verb} traced argument "
                        f"'{nm.id}' — one retrace per concrete value (mark "
                        "it static or use lax.cond/select)"
                    ),
                ))
        # shape-leak: concretizing calls and f-strings
        if (isinstance(node, ast.Call)
                and call_name(node) in _CONCRETIZERS and node.args):
            for nm in _hazard_names(sf, node.args[0], traced):
                findings.append(Finding(
                    path=sf.rel, line=node.lineno, col=node.col_offset,
                    rule="shape-leak", scope=scope_of(sf, node),
                    message=(
                        f"{call_name(node)}(...) concretizes traced "
                        f"argument '{nm.id}' inside a jit body — device "
                        "sync + value-keyed retrace"
                    ),
                ))
        if isinstance(node, ast.JoinedStr):
            for part in node.values:
                if not isinstance(part, ast.FormattedValue):
                    continue
                for nm in _hazard_names(sf, part.value, traced):
                    findings.append(Finding(
                        path=sf.rel, line=node.lineno, col=node.col_offset,
                        rule="shape-leak", scope=scope_of(sf, node),
                        message=(
                            f"f-string formats traced argument '{nm.id}' "
                            "inside a jit body — concretization / retrace "
                            "hazard"
                        ),
                    ))


def _is_unhashable_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.SetComp, ast.DictComp)):
        return True
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name is not None and (name.startswith("np.")
                                 or name.startswith("jnp.")
                                 or name.startswith("numpy.")
                                 or name.startswith("jax.numpy.")):
            return True
    return False


def _check_call_sites(sf: SourceFile, site: _JitSite,
                      findings: list[Finding]):
    """Same-file calls passing unhashable/array expressions in static
    positions."""
    if site.alias is None or not site.statics or site.func is None:
        return
    if isinstance(site.func, ast.Lambda):
        return
    params = _param_names(site.func)
    offset = 1 if site.bound_self else 0
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func) or self_attr(node.func)
        if callee != site.alias and self_attr(node.func) != site.alias:
            continue
        for i, arg in enumerate(node.args):
            pidx = i + offset
            if pidx < len(params) and params[pidx] in site.statics \
                    and _is_unhashable_expr(arg):
                findings.append(Finding(
                    path=sf.rel, line=arg.lineno, col=arg.col_offset,
                    rule="static-args", scope=scope_of(sf, node),
                    message=(
                        f"unhashable/array-valued expression passed for "
                        f"static argument '{params[pidx]}' of "
                        f"'{site.alias}' — TypeError or a cache entry per "
                        "object"
                    ),
                ))
        for kw in node.keywords:
            if kw.arg in site.statics and _is_unhashable_expr(kw.value):
                findings.append(Finding(
                    path=sf.rel, line=kw.value.lineno,
                    col=kw.value.col_offset, rule="static-args",
                    scope=scope_of(sf, node),
                    message=(
                        f"unhashable/array-valued expression passed for "
                        f"static argument '{kw.arg}' of '{site.alias}' — "
                        "TypeError or a cache entry per object"
                    ),
                ))


def run(sf: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    for site in _collect_sites(sf, findings):
        _check_body(sf, site, findings)
        _check_call_sites(sf, site, findings)
    return findings
