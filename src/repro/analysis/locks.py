"""Pass 1 — lock discipline for the hand-rolled concurrency in `serve/`.

Three rules, all per-lexical-class:

``guarded-field``
    Infer each lock's *guarded set*: every `self.<field>` that is written
    (stored, aug-assigned, container-slot-assigned, or mutated via
    `.append`-style calls) while that lock is held — inside a
    ``with self.<lock>:`` block or inside a ``*_locked`` method (the suffix
    convention promises `self._lock`).  Then flag any read or write of a
    guarded field outside a context holding its lock.  Constructor methods
    (`__init__` et al.) are exempt: they run before the object is shared.

``locked-call``
    A call to a ``*_locked`` method from a caller that neither holds
    ``self._lock`` lexically nor is itself ``*_locked``.  The callee skips
    acquisition by contract; calling it unlocked is a data race.

``lock-reacquire``
    A ``*_locked`` method that re-enters ``with self._lock:`` — with the
    plain (non-reentrant) `threading.Lock` the tier uses, that is a
    self-deadlock the moment the convention is honored by the caller.
    RLock-backed locks are exempt.

Known limits (by design, documented in docs/concurrency.md): inference is
lexical and per-class — inherited guarded sets and attributes of *other*
objects (`host.staged = ...`) are out of scope; `serve.faults.assert_holds`
is the runtime cross-check that covers the dynamic side.
"""
from __future__ import annotations

import ast

from repro.analysis.common import (
    CONSTRUCTOR_METHODS,
    CONVENTION_LOCK,
    LOCKED_SUFFIX,
    ClassInfo,
    Finding,
    SourceFile,
    access_kind,
    collect_classes,
    iter_with_held,
    self_attr,
    with_locks,
)

RULES = ("guarded-field", "locked-call", "lock-reacquire")


def _guarded_sets(info: ClassInfo) -> dict[str, set[str]]:
    """lock attr -> set of self.<field> names written while holding it."""
    guarded: dict[str, set[str]] = {}
    skip = info.lock_attrs | set(info.cond_aliases)
    for name, meth in info.methods.items():
        if name in CONSTRUCTOR_METHODS:
            continue
        sf = info._sf  # attached by run()
        for node, held in iter_with_held(meth, info):
            if not held or not isinstance(node, ast.Attribute):
                continue
            attr = self_attr(node)
            if attr is None or attr in skip:
                continue
            if access_kind(sf, node) == "write":
                for lock in held:
                    guarded.setdefault(lock, set()).add(attr)
    return guarded


def run(sf: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    for info in collect_classes(sf):
        if not info.lock_attrs and not info.cond_aliases:
            continue
        info._sf = sf  # let helpers reach the parent map
        guarded = _guarded_sets(info)
        field_to_locks: dict[str, set[str]] = {}
        for lock, fields in guarded.items():
            for f in fields:
                field_to_locks.setdefault(f, set()).add(lock)
        skip = info.lock_attrs | set(info.cond_aliases)

        for name, meth in info.methods.items():
            is_ctor = name in CONSTRUCTOR_METHODS
            is_locked_meth = name.endswith(LOCKED_SUFFIX)
            scope = f"{info.name}.{name}"
            for node, held in iter_with_held(meth, info):
                # -- guarded-field ----------------------------------------
                if (not is_ctor and isinstance(node, ast.Attribute)):
                    attr = self_attr(node)
                    if (attr is not None and attr not in skip
                            and attr in field_to_locks
                            and not (field_to_locks[attr] & held)):
                        locks = "/".join(
                            f"self.{l}" for l in sorted(field_to_locks[attr]))
                        kind = access_kind(sf, node)
                        findings.append(Finding(
                            path=sf.rel, line=node.lineno,
                            col=node.col_offset, rule="guarded-field",
                            scope=scope,
                            message=(
                                f"{kind} of 'self.{attr}' outside {locks} "
                                "(field is written under that lock elsewhere "
                                f"in {info.name})"
                            ),
                        ))
                # -- locked-call ------------------------------------------
                if isinstance(node, ast.Call):
                    callee = self_attr(node.func)
                    if (callee is not None and callee.endswith(LOCKED_SUFFIX)
                            and callee in info.methods
                            and CONVENTION_LOCK not in held):
                        findings.append(Finding(
                            path=sf.rel, line=node.lineno,
                            col=node.col_offset, rule="locked-call",
                            scope=scope,
                            message=(
                                f"call to 'self.{callee}()' without holding "
                                f"'self.{CONVENTION_LOCK}' (callers of "
                                f"*{LOCKED_SUFFIX} methods must hold the "
                                "lock or be *_locked themselves)"
                            ),
                        ))
                # -- lock-reacquire ---------------------------------------
                if (is_locked_meth
                        and isinstance(node, (ast.With, ast.AsyncWith))):
                    for lock in with_locks(node, info):
                        if (lock == CONVENTION_LOCK
                                and lock not in info.rlock_attrs):
                            findings.append(Finding(
                                path=sf.rel, line=node.lineno,
                                col=node.col_offset, rule="lock-reacquire",
                                scope=scope,
                                message=(
                                    f"'{name}' re-acquires 'self.{lock}' it "
                                    "already holds by the *_locked "
                                    "convention — self-deadlock on a "
                                    "non-reentrant Lock"
                                ),
                            ))
    return findings
