"""repro-lint CLI: `python -m repro.analysis [paths...]`.

Runs the seven AST passes (lock discipline, retrace hazards, device-sync-
under-lock, PRNG discipline, collective discipline, sharding layout, Pallas
lowerability) over the given files/directories (default: ``src tests``),
applies per-line suppressions and the checked-in baseline, and exits
non-zero on any new finding — the blocking CI gate.

    python -m repro.analysis src tests                 # text output
    python -m repro.analysis --format json src tests   # machine-readable
    python -m repro.analysis --changed-only src tests  # git-diff-scoped
    python -m repro.analysis --out lint-report.json    # JSON artifact
    python -m repro.analysis --write-baseline          # grandfather current
    python -m repro.analysis --list-rules              # rule catalogue

Exit codes: 0 clean (modulo baseline), 1 new findings, 2 usage/parse error.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from collections import Counter
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis import (collectives, locks, pallas, prng, retrace,
                            sharding, syncs)
from repro.analysis.common import Finding, SourceFile

PASSES = (locks, retrace, syncs, prng, collectives, sharding, pallas)

RULE_DOCS = {
    "guarded-field": "read/write of a lock-guarded attribute outside the lock",
    "locked-call": "*_locked method called without holding self._lock",
    "lock-reacquire": "*_locked method re-acquires its own non-reentrant lock",
    "traced-branch": "jit body branches/iterates in Python on a traced arg",
    "shape-leak": "int()/float()/f-string concretizes a traced arg in a jit body",
    "static-args": "malformed or unhashable static_argnums/static_argnames",
    "sync-under-lock": "device dispatch/sync while holding a coordinator lock",
    "prng-reuse": "PRNG key consumed twice without an intervening split",
    "ppermute-perm": "ppermute permutation is not a bijection on the axis",
    "collective-branch": "collective reachable from only one cond/switch arm",
    "collective-axis": "collective axis_name not declared by any mesh/spec",
    "state-sharding": "shard_map state assembled in init without explicit shardings",
    "donated-reuse": "buffer read again after being donated to a jitted call",
    "pallas-lowering": "interpret-only op (top_k/sort/gather) in a Pallas kernel",
    "pallas-blockspec": "index_map arity/units or grid divisibility inconsistent",
    "pallas-anyspace": "direct load/store on an ANY-memory-space ref (needs DMA)",
    "pallas-out-init": "output ref read before initialize without aliasing",
}

ALL_RULES = tuple(RULE_DOCS)


def discover(paths: list[Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def analyze_file(sf: SourceFile, rules: frozenset[str]
                 ) -> list[tuple[Finding, str]]:
    """All unsuppressed findings for one parsed file, paired with their
    baseline keys and sorted by position."""
    out: list[tuple[Finding, str]] = []
    for pass_mod in PASSES:
        if not rules & frozenset(pass_mod.RULES):
            continue
        for f in pass_mod.run(sf):
            if f.rule not in rules:
                continue
            if sf.suppressed(f.line, f.rule):
                continue
            out.append((f, f.baseline_key(sf.source_line(f.line))))
    out.sort(key=lambda fk: fk[0].sort_key())
    return out


def analyze_paths(paths: list[Path], root: Path,
                  rules: frozenset[str] = frozenset(ALL_RULES),
                  ) -> tuple[list[tuple[Finding, str]], list[str]]:
    """(findings-with-keys, parse_errors) over every .py under `paths`."""
    findings: list[tuple[Finding, str]] = []
    errors: list[str] = []
    for path in discover(paths):
        try:
            sf = SourceFile.load(path, root)
        except SyntaxError as e:
            errors.append(f"{path}: {e.msg} (line {e.lineno})")
            continue
        findings.extend(analyze_file(sf, rules))
    return findings, errors


def git_changed_files(root: Path) -> set[Path] | None:
    """Files touched vs HEAD (staged + unstaged + untracked), resolved
    absolute; None when git is unavailable / not a repository."""
    out: set[Path] = set()
    for cmd in (["git", "diff", "--name-only", "HEAD"],
                ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            res = subprocess.run(cmd, cwd=root, capture_output=True,
                                 text=True, check=True)
        except (OSError, subprocess.CalledProcessError):
            return None
        out.update((root / line).resolve()
                   for line in res.stdout.splitlines() if line.strip())
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to analyze (default: src tests)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: ./{baseline_mod.DEFAULT_BASELINE} "
                         "when it exists)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record every current finding as grandfathered and exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset to run")
    ap.add_argument("--changed-only", action="store_true",
                    help="only analyze files changed vs git HEAD "
                         "(staged, unstaged, untracked) — fast pre-commit runs")
    ap.add_argument("--out", default=None, metavar="FILE",
                    help="also write the JSON report to FILE "
                         "(independent of --format)")
    ap.add_argument("--root", default=".",
                    help="paths in output/baseline are relative to this")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, doc in RULE_DOCS.items():
            print(f"{rule:16s} {doc}")
        return 0

    rules = frozenset(ALL_RULES)
    if args.rules:
        rules = frozenset(r.strip() for r in args.rules.split(",") if r.strip())
        unknown = rules - frozenset(ALL_RULES)
        if unknown:
            print(f"unknown rules: {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2

    root = Path(args.root)
    paths = [Path(p) for p in (args.paths or ["src", "tests"])]
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"no such path: {', '.join(map(str, missing))}", file=sys.stderr)
        return 2

    if args.changed_only:
        changed = git_changed_files(root)
        if changed is None:
            print("--changed-only: git unavailable or not a repository",
                  file=sys.stderr)
            return 2
        paths = [p for p in discover(paths) if p.resolve() in changed]

    t0 = time.perf_counter()
    findings, errors = analyze_paths(paths, root, rules)
    elapsed = time.perf_counter() - t0

    baseline_path = Path(args.baseline) if args.baseline else (
        root / baseline_mod.DEFAULT_BASELINE)
    if args.write_baseline:
        baseline_mod.save(baseline_path, Counter(k for _, k in findings))
        print(f"wrote {len(findings)} grandfathered finding(s) -> "
              f"{baseline_path}")
        return 0

    base = Counter()
    if baseline_path.exists():
        try:
            base = baseline_mod.load(baseline_path)
        except (ValueError, json.JSONDecodeError) as e:
            print(f"bad baseline {baseline_path}: {e}", file=sys.stderr)
            return 2
    new, suppressed, stale = baseline_mod.apply(findings, base)

    payload = {
        "findings": [vars(f) for f in new],
        "summary": dict(Counter(f.rule for f in new)),
        "baseline": {"suppressed": suppressed, "stale": stale},
        "parse_errors": errors,
        "files_analyzed": len(discover(paths)),
        "elapsed_s": round(elapsed, 4),
    }
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=1) + "\n")
    if args.format == "json":
        print(json.dumps(payload, indent=1))
    else:
        for f in new:
            print(f.render())
        for e in errors:
            print(f"PARSE ERROR {e}", file=sys.stderr)
        if stale:
            print(f"note: {len(stale)} stale baseline entr"
                  f"{'y' if len(stale) == 1 else 'ies'} (finding fixed — "
                  "regenerate with --write-baseline to shrink the file)",
                  file=sys.stderr)
        print(f"repro-lint: {len(new)} new finding(s), {suppressed} "
              f"baselined, {len(discover(paths))} files in {elapsed:.2f}s",
              file=sys.stderr)
    if errors:
        return 2
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
