"""Pass 4 — PRNG key discipline (``prng-reuse``).

BPMF's correctness story leans on its key ledger: the fused fold-in
pre-draws noise "with the loop's key sequence so sampling matches
bit-for-bit", and the distributed parity tests pin exact random bits.
Reusing a consumed key silently correlates draws that the math assumes
independent — no test fails, the posterior is just wrong.

The rule: a key variable passed to two *consuming* calls without an
intervening `split`/reassignment is flagged.  Consuming = any call that
receives the key as an argument (samplers, `jax.random.split` itself,
helper functions taking a key) — except `jax.random.fold_in`, which
derives without consuming (the per-item `vmap(fold_in)` pattern in
core/distributed.py is the sanctioned way to fan one key out), and
argument-checking helpers (`_check*`/`assert*`/`validate*`), which
inspect the key without drawing from it.

Key variables are tracked by provenance (assigned from `PRNGKey` / `key` /
`split` / `fold_in`, including tuple unpacking of `split`) and by naming
convention for function parameters (`key`, `rng`, `*_key`).

Control flow is approximated abstractly: `if`/`else` branches are analyzed
independently and merged consumed-if-either (consumption in one arm taints
later straight-line use, but sibling arms never flag each other; an arm
that ends in `return`/`raise` is excluded from the merge — its
consumptions never reach the fall-through code); loop and
comprehension bodies are analyzed twice, so a consumption that survives its
own iteration (`for _ in ...: normal(key)`) is caught while the idiomatic
`key, k = split(key)`-per-iteration ledger stays clean.  Nested `def`s are
separate scopes; lambdas passed to `vmap` get their own parameter state.
"""
from __future__ import annotations

import ast

from repro.analysis.common import Finding, SourceFile, call_name, scope_of

RULES = ("prng-reuse",)

_PRODUCERS = ("random.PRNGKey", "random.key", "random.split",
              "random.fold_in", "random.wrap_key_data", "random.clone")
_NONCONSUMING = ("random.fold_in", "random.key_data", "random.clone")
_IGNORED_CALLEES = {"print", "repr", "str", "id", "len", "type", "hash",
                    "isinstance"}
_PARAM_NAMES = {"key", "rng", "prng", "prng_key", "rng_key"}

FRESH, CONSUMED = "fresh", "consumed"


def _is_producer(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = call_name(node)
    return name is not None and name.endswith(_PRODUCERS)


def _is_key_param(name: str) -> bool:
    return name in _PARAM_NAMES or name.endswith("_key")


def _is_validator(name: str) -> bool:
    """Argument-checking helpers (`_check_fold_in_args(key, ...)`) inspect
    the key without drawing from it."""
    leaf = name.rsplit(".", 1)[-1].lstrip("_")
    return leaf.startswith(("check", "assert", "validate", "verify"))


def _terminates(stmts: list[ast.stmt]) -> bool:
    """True when a block can never fall through to the statement after it."""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue)
    )


class _ScopeState:
    def __init__(self):
        # var -> (state, line-of-consumption)
        self.keys: dict[str, tuple[str, int]] = {}

    def copy(self) -> "_ScopeState":
        s = _ScopeState()
        s.keys = dict(self.keys)
        return s

    def merge(self, *others: "_ScopeState") -> None:
        for other in others:
            for var, (st, line) in other.keys.items():
                cur = self.keys.get(var)
                if cur is None or (st == CONSUMED and cur[0] == FRESH):
                    self.keys[var] = (st, line)


class _FunctionAnalyzer:
    def __init__(self, sf: SourceFile, func, scope: str,
                 findings: list[Finding]):
        self.sf = sf
        self.func = func
        self.scope = scope
        self.findings = findings
        self.seen: set[tuple[int, str]] = set()

    def analyze(self) -> None:
        state = _ScopeState()
        args = self.func.args
        for p in (*args.posonlyargs, *args.args, *args.kwonlyargs):
            if _is_key_param(p.arg):
                state.keys[p.arg] = (FRESH, p.lineno)
        if isinstance(self.func, ast.Lambda):
            self._visit_expr(self.func.body, state)
        else:
            self._visit_block(self.func.body, state)

    # -- statements ----------------------------------------------------
    def _visit_block(self, stmts, state: _ScopeState) -> None:
        for stmt in stmts:
            self._visit_stmt(stmt, state)

    def _visit_stmt(self, stmt: ast.stmt, state: _ScopeState) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _FunctionAnalyzer(
                self.sf, stmt, f"{self.scope}.{stmt.name}".lstrip("."),
                self.findings,
            ).analyze()
            return
        if isinstance(stmt, ast.ClassDef):
            run_on_scope(self.sf, stmt, self.scope, self.findings)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._visit_expr(value, state)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            self._assign(targets, value, state)
            return
        if isinstance(stmt, ast.If):
            self._visit_expr(stmt.test, state)
            body_state = state.copy()
            else_state = state.copy()
            self._visit_block(stmt.body, body_state)
            self._visit_block(stmt.orelse, else_state)
            # only fall-through arms flow into the post-If state: an arm
            # ending in return/raise never reaches the code after the If,
            # so its consumptions are mutually exclusive with later use
            # (the `if mode == "async": ... return` pattern in
            # core/distributed.py)
            live = [s for s, arm in ((body_state, stmt.body),
                                     (else_state, stmt.orelse))
                    if not _terminates(arm)]
            if live:
                state.keys = {}
                state.merge(*live)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._visit_expr(stmt.iter, state)
            # two abstract iterations: catches loop-carried reuse while a
            # per-iteration split keeps the ledger clean
            for _ in range(2):
                self._visit_block(stmt.body, state)
            self._visit_block(stmt.orelse, state)
            return
        if isinstance(stmt, ast.While):
            for _ in range(2):
                self._visit_expr(stmt.test, state)
                self._visit_block(stmt.body, state)
            self._visit_block(stmt.orelse, state)
            return
        if isinstance(stmt, ast.Try):
            body_state = state.copy()
            self._visit_block(stmt.body, body_state)
            merged = [body_state]
            for handler in stmt.handlers:
                h_state = state.copy()
                self._visit_block(handler.body, h_state)
                merged.append(h_state)
            state.keys = {}
            state.merge(*merged)
            self._visit_block(stmt.orelse, state)
            self._visit_block(stmt.finalbody, state)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._visit_expr(item.context_expr, state)
            self._visit_block(stmt.body, state)
            return
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._visit_expr(child, state)
            elif isinstance(child, ast.stmt):
                self._visit_stmt(child, state)

    def _assign(self, targets, value, state: _ScopeState) -> None:
        produced = value is not None and _is_producer(value)
        # a key-ish NAME bound to some other call's result is not a key we
        # can reason about: `rng = np.random.default_rng(0)` is a *stateful*
        # generator (reuse is the point), `key = make_key(...)` is opaque.
        # Name-convention tracking only applies to non-call values
        # (`key = state.key` — reading a stored key) and parameters.
        opaque_call = isinstance(value, ast.Call) and not produced
        for tgt in targets:
            names = []
            if isinstance(tgt, ast.Name):
                names = [tgt.id]
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                names = [e.id for e in tgt.elts if isinstance(e, ast.Name)]
            for n in names:
                if produced or (_is_key_param(n) and not opaque_call):
                    state.keys[n] = (FRESH, tgt.lineno)
                elif n in state.keys:
                    del state.keys[n]  # rebound to a non-key value

    # -- expressions ---------------------------------------------------
    def _visit_expr(self, expr: ast.expr, state: _ScopeState) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Lambda):
                _FunctionAnalyzer(self.sf, node, self.scope,
                                  self.findings).analyze()
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                # the element expr runs once per iteration
                elts = ([node.key, node.value]
                        if isinstance(node, ast.DictComp) else [node.elt])
                for elt in elts:
                    for sub in ast.walk(elt):
                        if isinstance(sub, ast.Call):
                            self._consume_call(sub, state, repeat=True)
            elif isinstance(node, ast.Call):
                self._consume_call(node, state)

    def _consume_call(self, node: ast.Call, state: _ScopeState,
                      repeat: bool = False) -> None:
        name = call_name(node)
        if name is not None:
            if (name.endswith(_NONCONSUMING) or name in _IGNORED_CALLEES
                    or _is_validator(name)):
                return
        key_args = []
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name) and arg.id in state.keys:
                key_args.append(arg)
        for arg in key_args:
            st, line = state.keys[arg.id]
            if st == CONSUMED or repeat:
                self._flag(arg, line)
            state.keys[arg.id] = (CONSUMED, arg.lineno)
        if repeat:
            # inside a comprehension, even a first consumption repeats
            return

    def _flag(self, arg: ast.Name, prev_line: int) -> None:
        dedup = (arg.lineno, arg.id)
        if dedup in self.seen:
            return
        self.seen.add(dedup)
        self.findings.append(Finding(
            path=self.sf.rel, line=arg.lineno, col=arg.col_offset,
            rule="prng-reuse", scope=self.scope,
            message=(
                f"PRNG key '{arg.id}' (consumed near line {prev_line}) is "
                "passed to another sampling call without an intervening "
                "split — draws will be correlated"
            ),
        ))


def run_on_scope(sf: SourceFile, node: ast.AST, prefix: str,
                 findings: list[Finding]) -> None:
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scope = f"{prefix}.{child.name}".lstrip(".")
            _FunctionAnalyzer(sf, child, scope, findings).analyze()
        elif isinstance(child, ast.ClassDef):
            run_on_scope(sf, child, f"{prefix}.{child.name}".lstrip("."),
                         findings)


def run(sf: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    run_on_scope(sf, sf.tree, "", findings)
    return findings
