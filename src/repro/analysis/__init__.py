"""repro-lint: repo-custom static analysis for the concurrency and
retrace invariants the async serving/training stack depends on.

Seven stdlib-`ast` passes (no runtime deps — the analyzer never imports
the code it checks):

* ``locks``       — lock discipline: inferred guarded-field sets, the
  ``*_locked`` calling convention, re-acquisition deadlocks.
* ``retrace``     — jit retrace hazards: Python branches on traced args,
  malformed/unhashable statics, concretizing shape leaks.
* ``syncs``       — device dispatch/sync under a coordinator lock.
* ``prng``        — PRNG key reuse without an intervening split.
* ``collectives`` — SPMD discipline: ppermute bijectivity, collectives
  unbalanced across cond/switch arms (deadlock), axis_name validity.
* ``sharding``    — init-vs-step layout drift (the silent-recompile bug
  class) and donated-buffer reuse-after-donation.
* ``pallas``      — Mosaic lowerability pre-checks for pallas_call
  kernels: interpret-only ops, BlockSpec/grid arithmetic, ANY-space ref
  access, output-ref read-before-initialize.

CLI: ``python -m repro.analysis [paths...]`` (see `repro.analysis.cli`).
Docs: ``docs/static-analysis.md`` — rule catalogue, Mosaic allowlist
rationale, suppression & baseline workflow; ``docs/concurrency.md`` keeps
the runtime cross-check (`serve.faults.assert_holds`).
"""
from repro.analysis.cli import ALL_RULES, RULE_DOCS, analyze_paths, main
from repro.analysis.common import Finding, SourceFile

__all__ = ["ALL_RULES", "RULE_DOCS", "Finding", "SourceFile",
           "analyze_paths", "analyze_source", "main"]


def analyze_source(code: str, rules=None, filename: str = "<snippet>"):
    """Analyze a source string — the fixture seam tests/test_analysis.py
    uses. Returns unsuppressed findings sorted by position."""
    from pathlib import Path

    from repro.analysis.cli import analyze_file

    sf = SourceFile(Path(filename), filename, code)
    ruleset = frozenset(rules) if rules is not None else frozenset(ALL_RULES)
    return [f for f, _ in analyze_file(sf, ruleset)]
