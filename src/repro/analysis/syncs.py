"""Pass 3 — device work under a coordinator lock (``sync-under-lock``).

The serving tier's locks guard *metadata*: epoch counters, binding
pointers, health tables.  Every request thread and every per-host
subscriber loop takes them.  A jax dispatch — let alone a blocking
`.block_until_ready()` or a `np.asarray(device_array)` copy — executed
while one is held turns that lock into a device-latency convoy: one slow
kernel stalls every request on the host.  The discipline (see
serve/cluster.py: staging happens *off* the lock, the barrier-side flip is
pointer swaps only) is lexical and therefore machine-checkable:

Flag any call lexically inside a ``with self.<lock>:`` block whose callee
is `jnp.*` / `jax.*` (minus host-side helpers like `jax.tree_util`),
`np.asarray` / `np.array` (the host-transfer idiom in this repo),
`.block_until_ready()`, `.scoring_matrices()` (the repo's ensemble →
device-tables build, the single heaviest serving-path operation), or
`jax.device_put` / `jax.device_get`.

Intentional stop-the-world sections (the coordinated `_reshard`) carry a
per-line ``# repro-lint: disable=sync-under-lock`` with justification.
"""
from __future__ import annotations

import ast

from repro.analysis.common import (
    Finding,
    SourceFile,
    call_name,
    collect_classes,
    iter_with_held,
    scope_of,
)

RULES = ("sync-under-lock",)

# dotted-prefix triggers
_PREFIXES = ("jnp.", "jax.numpy.")
_JAX_PREFIX = "jax."
_JAX_ALLOW = ("jax.tree_util.", "jax.tree.", "jax.typing.")
# exact dotted names
_EXACT = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
# method names that imply device sync wherever the receiver lives
_METHODS = {"block_until_ready", "scoring_matrices"}


def _is_sync_call(name: str | None, node: ast.Call) -> str | None:
    """A short reason when the call is a device dispatch/sync, else None."""
    if name is not None:
        if name in _EXACT:
            return f"'{name}' copies device data to host"
        if name.startswith(_PREFIXES):
            return f"'{name}' dispatches device work"
        if name.startswith(_JAX_PREFIX) and not name.startswith(_JAX_ALLOW):
            return f"'{name}' dispatches device work"
    if isinstance(node.func, ast.Attribute) and node.func.attr in _METHODS:
        return f"'.{node.func.attr}()' blocks on / builds device buffers"
    return None


def run(sf: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    for info in collect_classes(sf):
        if not info.lock_attrs and not info.cond_aliases:
            continue
        for name, meth in info.methods.items():
            scope = f"{info.name}.{name}"
            for node, held in iter_with_held(meth, info):
                if not held or not isinstance(node, ast.Call):
                    continue
                reason = _is_sync_call(call_name(node), node)
                if reason is None:
                    continue
                locks = ", ".join(f"self.{lk}" for lk in sorted(held))
                findings.append(Finding(
                    path=sf.rel, line=node.lineno, col=node.col_offset,
                    rule="sync-under-lock", scope=scope,
                    message=(
                        f"{reason} while holding {locks} — a device sync "
                        "under a coordinator lock stalls every thread "
                        "waiting on it (stage off the lock, flip under it)"
                    ),
                ))
    return findings
