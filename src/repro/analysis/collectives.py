"""Collective-discipline pass: SPMD hazards inside shard_map/sweep bodies.

The distributed trainer's correctness rests on three properties that no
cheap test covers (a wrong permutation or a one-armed collective only
deadlocks/corrupts at real shard counts, and the CPU simulation happily
computes *something*):

* **ppermute-perm** — every ``lax.ppermute`` permutation must be a
  bijection on the axis: duplicate sources or destinations drop/duplicate
  a block, and a destination outside ``[0, n)`` (a ring shift with the
  wraparound ``% n`` forgotten) hangs the collective.  Literal pair lists
  are checked directly; the repo's ring idiom
  ``[(i, (i + 1) % n) for i in range(n)]`` is probe-evaluated at several
  concrete shard counts, so any arithmetic over the loop variable and the
  ring size is covered without a real tracer.

* **collective-branch** — a collective reachable from only one arm of
  ``lax.cond`` / ``lax.switch`` is an SPMD deadlock: shards that take the
  other arm never enter the rendezvous.  Arms are compared as the ordered
  sequence of collective ops each one issues (lambdas inlined, same-file
  function references expanded two levels deep).  Arms that cannot be
  resolved to same-file code are skipped rather than guessed at.

* **collective-axis** — ``axis_name`` arguments must name an axis the
  file actually declares (``jax.make_mesh``/``Mesh`` axis tuples,
  ``PartitionSpec``/``P`` entries, resolved through module-level string
  constants like ``AXIS = "items"``).  Only literal/constant-resolvable
  axis arguments in files that declare at least one axis are checked —
  parameters and imported names are someone else's contract.
"""
from __future__ import annotations

import ast

from repro.analysis.common import Finding, SourceFile, call_name, scope_of

RULES = ("ppermute-perm", "collective-branch", "collective-axis")

# ops that synchronize across an axis (deadlock-relevant, axis-checked)
COMM_OPS = frozenset({
    "psum", "pmean", "pmax", "pmin", "ppermute", "pshuffle",
    "all_gather", "all_to_all", "psum_scatter",
})
# axis-checked but free of cross-shard synchronization
AXIS_ONLY_OPS = frozenset({"axis_index"})
AXIS_OPS = COMM_OPS | AXIS_ONLY_OPS

# shard counts the ring arithmetic is probed at; 4 catches parity bugs,
# 3/5 catch anything tuned to even counts
_PROBE_COUNTS = (3, 4, 5)
_EVAL_LIMIT = 64  # AST-size cap for the probe evaluator


def _leaf(name: str | None) -> str | None:
    return name.rsplit(".", 1)[-1] if name else None


def _collective_call(node: ast.Call) -> str | None:
    """Leaf op name when `node` calls a jax.lax collective, else None."""
    name = call_name(node)
    leaf = _leaf(name)
    if leaf in AXIS_OPS and name != leaf:  # require a dotted lax./jax.lax. base
        return leaf
    return None


# ---------------------------------------------------------------------------
# tiny constant/arith evaluator for permutation probing
# ---------------------------------------------------------------------------
def _probe_eval(node: ast.AST, env: dict[str, int]) -> int | None:
    """Evaluate integer arithmetic over Names bound in `env`. None = give up."""
    if sum(1 for _ in ast.walk(node)) > _EVAL_LIMIT:
        return None
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) else None
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _probe_eval(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs = _probe_eval(node.left, env)
        rhs = _probe_eval(node.right, env)
        if lhs is None or rhs is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return lhs + rhs
            if isinstance(node.op, ast.Sub):
                return lhs - rhs
            if isinstance(node.op, ast.Mult):
                return lhs * rhs
            if isinstance(node.op, ast.Mod):
                return lhs % rhs
            if isinstance(node.op, ast.FloorDiv):
                return lhs // rhs
        except (ZeroDivisionError, ValueError):
            return None
    return None


def _pair_elts(node: ast.AST) -> tuple[ast.AST, ast.AST] | None:
    if isinstance(node, (ast.Tuple, ast.List)) and len(node.elts) == 2:
        return node.elts[0], node.elts[1]
    return None


def _check_pairs(pairs: list[tuple[int, int]], n: int | None) -> str | None:
    """Human-readable defect in a concrete (src, dst) pair list, or None."""
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    if len(set(srcs)) != len(srcs):
        return "duplicate source shard (a block is sent twice)"
    if len(set(dsts)) != len(dsts):
        return "duplicate destination shard (two blocks collide)"
    if n is not None:
        bad = [x for x in srcs + dsts if not 0 <= x < n]
        if bad:
            return (f"shard id {bad[0]} outside [0, {n}) — missing '% "
                    "n_shards' ring wraparound?")
    elif any(x < 0 for x in srcs + dsts):
        return "negative shard id in permutation"
    return None


class _Scopes:
    """Name -> assigned value expression, innermost enclosing scope first."""

    def __init__(self, sf: SourceFile):
        self.sf = sf

    def lookup(self, use_site: ast.AST, name: str) -> ast.AST | None:
        cur = self.sf.parent(use_site)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Module)):
                for sub in ast.walk(cur):
                    if not isinstance(sub, ast.Assign):
                        continue
                    for tgt in sub.targets:
                        if isinstance(tgt, ast.Name) and tgt.id == name:
                            return sub.value
            cur = self.sf.parent(cur)
        return None


def _check_perm(sf: SourceFile, call: ast.Call, perm: ast.AST,
                scopes: _Scopes) -> str | None:
    """Defect message for a ppermute perm argument, or None when it is a
    provable bijection / not statically evaluable."""
    if isinstance(perm, ast.Name):
        resolved = scopes.lookup(call, perm.id)
        if resolved is None:
            return None
        perm = resolved

    if isinstance(perm, (ast.List, ast.Tuple)):
        pairs: list[tuple[int, int]] = []
        for elt in perm.elts:
            pe = _pair_elts(elt)
            if pe is None:
                return "permutation entry is not a (source, dest) pair"
            src = _probe_eval(pe[0], {})
            dst = _probe_eval(pe[1], {})
            if src is None or dst is None:
                return None  # dynamic entries: out of static reach
            pairs.append((src, dst))
        return _check_pairs(pairs, None) if pairs else None

    if isinstance(perm, ast.ListComp) and len(perm.generators) == 1:
        gen = perm.generators[0]
        if gen.ifs or not isinstance(gen.target, ast.Name):
            return None
        it = gen.iter
        if not (isinstance(it, ast.Call) and _leaf(call_name(it)) == "range"
                and len(it.args) == 1):
            return None
        pe = _pair_elts(perm.elt)
        if pe is None:
            return "permutation entry is not a (source, dest) pair"
        size = it.args[0]
        if isinstance(size, ast.Constant) and isinstance(size.value, int):
            probe_ns, size_name = [size.value], None
        elif isinstance(size, ast.Name):
            probe_ns, size_name = list(_PROBE_COUNTS), size.id
        else:
            return None
        loop = gen.target.id
        for n in probe_ns:
            env = {loop: 0}
            if size_name is not None:
                env[size_name] = n
            pairs = []
            for i in range(n):
                env[loop] = i
                src = _probe_eval(pe[0], env)
                dst = _probe_eval(pe[1], env)
                if src is None or dst is None:
                    return None  # arithmetic beyond the evaluator: skip
                pairs.append((src, dst))
            defect = _check_pairs(pairs, n)
            if defect:
                return f"at {n} shards: {defect}"
    return None


# ---------------------------------------------------------------------------
# collective-branch: arm comparison for lax.cond / lax.switch
# ---------------------------------------------------------------------------
def _functions_by_name(sf: SourceFile) -> dict[str, ast.FunctionDef]:
    out: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


def _arm_callable(node: ast.AST) -> ast.AST | str | None:
    """A branch argument as Lambda node, function-name string, or None."""
    if isinstance(node, ast.Lambda):
        return node
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Call) and _leaf(call_name(node)) == "partial":
        if node.args and isinstance(node.args[0], ast.Name):
            return node.args[0].id
    return None


def _collective_seq(body: ast.AST, funcs: dict[str, ast.FunctionDef],
                    depth: int) -> list[str] | None:
    """Ordered collective leaf names issued by `body`, expanding same-file
    callees `depth` levels; None when an arm calls an unresolvable helper
    that might itself collect (stay silent rather than guess)."""
    seq: list[str] = []
    for node in ast.walk(body):
        if not isinstance(node, ast.Call):
            continue
        op = _collective_call(node)
        if op is not None and op in COMM_OPS:
            seq.append(op)
            continue
        name = call_name(node)
        leaf = _leaf(name)
        if leaf and name == leaf and leaf in funcs and depth > 0:
            sub = _collective_seq(funcs[leaf], funcs, depth - 1)
            if sub is None:
                return None
            seq.extend(sub)
    return seq


def _branch_arms(node: ast.Call) -> list[ast.AST] | None:
    leaf = _leaf(call_name(node))
    if leaf == "cond" and len(node.args) >= 3:
        return [node.args[1], node.args[2]]
    if leaf == "switch" and len(node.args) >= 2:
        branches = node.args[1]
        if isinstance(branches, (ast.List, ast.Tuple)) and branches.elts:
            return list(branches.elts)
    return None


def _is_lax_branch(node: ast.Call) -> bool:
    name = call_name(node)
    return bool(name and "lax" in name.split(".")[:-1]
                and _leaf(name) in ("cond", "switch"))


# ---------------------------------------------------------------------------
# collective-axis: declared-axes table
# ---------------------------------------------------------------------------
def _module_str_consts(sf: SourceFile) -> dict[str, tuple[str, ...]]:
    """Module-level NAME = "axis" / ("a", "b") constants."""
    out: dict[str, tuple[str, ...]] = {}
    for node in sf.tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        val = node.value
        if isinstance(val, ast.Constant) and isinstance(val.value, str):
            out[node.targets[0].id] = (val.value,)
        elif (isinstance(val, (ast.Tuple, ast.List)) and val.elts
              and all(isinstance(e, ast.Constant) and isinstance(e.value, str)
                      for e in val.elts)):
            out[node.targets[0].id] = tuple(e.value for e in val.elts)
    return out


def _resolve_axes(node: ast.AST, consts: dict[str, tuple[str, ...]]
                  ) -> tuple[str, ...] | None:
    """Axis-name strings an expression denotes; None = unresolvable."""
    if isinstance(node, ast.Constant):
        return (node.value,) if isinstance(node.value, str) else None
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: list[str] = []
        for e in node.elts:
            sub = _resolve_axes(e, consts)
            if sub is None:
                return None
            out.extend(sub)
        return tuple(out)
    return None


def _declared_axes(sf: SourceFile, consts: dict[str, tuple[str, ...]]
                   ) -> set[str]:
    declared: set[str] = set()
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        leaf = _leaf(call_name(node))
        if leaf in ("make_mesh", "Mesh", "AbstractMesh"):
            cands = list(node.args[1:2])
            cands += [kw.value for kw in node.keywords
                      if kw.arg == "axis_names"]
            for cand in cands:
                axes = _resolve_axes(cand, consts)
                if axes:
                    declared.update(axes)
        elif leaf in ("P", "PartitionSpec"):
            for arg in node.args:
                axes = _resolve_axes(arg, consts)
                if axes:
                    declared.update(axes)
    return declared


def _axis_arg(node: ast.Call, op: str) -> ast.AST | None:
    for kw in node.keywords:
        if kw.arg == "axis_name":
            return kw.value
    pos = 0 if op in AXIS_ONLY_OPS else 1
    if len(node.args) > pos:
        return node.args[pos]
    return None


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def run(sf: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    scopes = _Scopes(sf)
    funcs = _functions_by_name(sf)
    consts = _module_str_consts(sf)
    declared = _declared_axes(sf, consts)

    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue

        op = _collective_call(node)
        if op is not None:
            # -------- ppermute-perm
            if op == "ppermute":
                perm = None
                if len(node.args) >= 3:
                    perm = node.args[2]
                else:
                    for kw in node.keywords:
                        if kw.arg == "perm":
                            perm = kw.value
                if perm is not None:
                    defect = _check_perm(sf, node, perm, scopes)
                    if defect:
                        findings.append(Finding(
                            sf.rel, node.lineno, node.col_offset,
                            "ppermute-perm",
                            f"ppermute permutation is not a bijection: "
                            f"{defect}",
                            scope_of(sf, node)))

            # -------- collective-axis
            if declared:
                axis = _axis_arg(node, op)
                axes = (_resolve_axes(axis, consts)
                        if axis is not None else None)
                if axes:
                    unknown = [a for a in axes if a not in declared]
                    if unknown:
                        findings.append(Finding(
                            sf.rel, node.lineno, node.col_offset,
                            "collective-axis",
                            f"{op} over axis {unknown[0]!r} but this file "
                            f"declares axes {sorted(declared)} — collective "
                            "will fail or silently no-op",
                            scope_of(sf, node)))

        # -------- collective-branch
        if _is_lax_branch(node):
            arms = _branch_arms(node)
            if not arms:
                continue
            seqs: list[list[str]] = []
            resolvable = True
            for arm in arms:
                target = _arm_callable(arm)
                if isinstance(target, str):
                    fn = funcs.get(target)
                    if fn is None:
                        resolvable = False
                        break
                    seq = _collective_seq(fn, funcs, depth=2)
                elif target is not None:
                    seq = _collective_seq(target, funcs, depth=2)
                else:
                    resolvable = False
                    break
                if seq is None:
                    resolvable = False
                    break
                seqs.append(seq)
            if not resolvable or not seqs:
                continue
            if any(seq != seqs[0] for seq in seqs[1:]):
                desc = " vs ".join(
                    "[" + ", ".join(s) + "]" if s else "[none]" for s in seqs)
                findings.append(Finding(
                    sf.rel, node.lineno, node.col_offset,
                    "collective-branch",
                    "cond/switch arms issue different collective sequences "
                    f"({desc}) — shards taking the quiet arm deadlock the "
                    "rendezvous",
                    scope_of(sf, node)))

    return findings
