"""Sharding-layout pass: init-vs-step layout drift and donation hazards.

* **state-sharding** — the PR 6 bug class: `DistributedBPMF.init()` once
  assembled the sweep state without explicit shardings, so the state the
  first jitted sweep *returned* carried different layouts than the state
  `init()` produced — and the second sweep silently recompiled, putting
  XLA compile time inside fig5's timed window.  The pass finds the state
  types that flow through ``shard_map`` (constructor calls returned by the
  mapped function), then flags any field of such a constructor inside an
  ``init*`` function whose value is not layout-pinned: accepted forms are
  ``jax.device_put(...)`` / ``with_sharding_constraint(...)`` calls, local
  names bound to one, ``None``, and conditionals over those.  Spec-tree
  constructions (``DistState(u=P(AXIS), ...)``) live outside ``init*``
  functions and are not touched.

* **donated-reuse** — a jitted callable built with ``donate_argnums`` /
  ``donate_argnames`` invalidates the donated operand buffers at the call;
  reading such an argument after the call is use-after-free that XLA only
  sometimes rejects.  The pass tracks names bound to donating ``jax.jit``
  results and flags loads of a donated argument on lines after the call
  within the same function.  Only direct calls of the jitted name count —
  ``jitted.lower(...)`` does not execute and donates nothing.
"""
from __future__ import annotations

import ast

from repro.analysis.common import Finding, SourceFile, call_name, scope_of

RULES = ("state-sharding", "donated-reuse")

_PIN_CALLS = frozenset({
    "device_put", "device_put_replicated", "device_put_sharded",
    "with_sharding_constraint",
})


def _leaf(name: str | None) -> str | None:
    return name.rsplit(".", 1)[-1] if name else None


def _is_pin_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and _leaf(call_name(node)) in _PIN_CALLS)


# ---------------------------------------------------------------------------
# state-sharding
# ---------------------------------------------------------------------------
def _mapped_functions(sf: SourceFile) -> list[ast.AST]:
    """Function bodies passed as the first argument of *shard_map calls —
    Lambda nodes inline, Names resolved to same-file defs."""
    by_name: dict[str, ast.AST] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, node)
    out: list[ast.AST] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        leaf = _leaf(call_name(node))
        if leaf is None or not leaf.lstrip("_").startswith("shard_map"):
            continue
        if not node.args:
            continue
        fn = node.args[0]
        if isinstance(fn, ast.Lambda):
            out.append(fn)
        elif isinstance(fn, ast.Name) and fn.id in by_name:
            out.append(by_name[fn.id])
    return out


def _state_types(mapped: list[ast.AST]) -> set[str]:
    """Capitalized constructor names the mapped functions return — the
    pytree state types whose layout must match between init and step."""
    types: set[str] = set()
    for fn in mapped:
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Return) and node.value is not None):
                continue
            vals = (node.value.elts
                    if isinstance(node.value, (ast.Tuple, ast.List))
                    else [node.value])
            for val in vals:
                if (isinstance(val, ast.Call)
                        and isinstance(val.func, ast.Name)
                        and val.func.id[:1].isupper()):
                    types.add(val.func.id)
    return types


def _local_assigns(func: ast.AST) -> dict[str, list[ast.AST]]:
    out: dict[str, list[ast.AST]] = {}
    for node in ast.walk(func):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.setdefault(tgt.id, []).append(node.value)
    return out


def _pinned(value: ast.AST, assigns: dict[str, list[ast.AST]],
            depth: int = 3) -> bool | None:
    """True: value carries an explicit sharding.  False: provably does not.
    None: can't tell (parameters, attributes, imports) — stay silent."""
    if depth <= 0:
        return None
    if _is_pin_call(value):
        return True
    if isinstance(value, ast.Constant) and value.value is None:
        return True  # absent optional field, no buffer to mislay
    if isinstance(value, ast.IfExp):
        a = _pinned(value.body, assigns, depth - 1)
        b = _pinned(value.orelse, assigns, depth - 1)
        if a is True and b is True:
            return True
        if a is False or b is False:
            return False
        return None
    if isinstance(value, ast.Name):
        srcs = assigns.get(value.id)
        if not srcs:
            return None  # parameter / closure / import: unknown provenance
        verdicts = [_pinned(s, assigns, depth - 1) for s in srcs]
        if all(v is True for v in verdicts):
            return True
        if any(v is False for v in verdicts):
            return False
        return None
    if isinstance(value, (ast.Call, ast.BinOp, ast.UnaryOp)):
        return False  # computed on the fly, layout left to XLA's default
    return None


def _check_state_sharding(sf: SourceFile) -> list[Finding]:
    mapped = _mapped_functions(sf)
    if not mapped:
        return []
    types = _state_types(mapped)
    if not types:
        return []
    mapped_ids = {id(m) for m in mapped}

    findings: list[Finding] = []
    for func in ast.walk(sf.tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not func.name.startswith("init"):
            continue
        # an init nested inside the mapped body is traced, not host-side
        cur = sf.parent(func)
        inside_mapped = False
        while cur is not None:
            if id(cur) in mapped_ids:
                inside_mapped = True
                break
            cur = sf.parent(cur)
        if inside_mapped:
            continue
        assigns = _local_assigns(func)
        for node in ast.walk(func):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in types):
                continue
            fields = [(kw.arg, kw.value) for kw in node.keywords if kw.arg]
            fields += [(f"<arg{i}>", a) for i, a in enumerate(node.args)]
            for fname, fval in fields:
                if _pinned(fval, assigns) is False:
                    findings.append(Finding(
                        sf.rel, fval.lineno, fval.col_offset,
                        "state-sharding",
                        f"field {fname!r} of shard_map state "
                        f"{node.func.id!r} is built in {func.name}() without "
                        "an explicit sharding (device_put / "
                        "with_sharding_constraint) — init and step layouts "
                        "diverge and the second step silently recompiles",
                        scope_of(sf, fval)))
    return findings


# ---------------------------------------------------------------------------
# donated-reuse
# ---------------------------------------------------------------------------
def _donating_jit(node: ast.AST) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """(donated positions, donated names) when `node` is a jax.jit call with
    donation configured; empty tuples otherwise."""
    if not (isinstance(node, ast.Call) and _leaf(call_name(node)) == "jit"):
        return (), ()
    nums: tuple[int, ...] = ()
    names: tuple[str, ...] = ()
    for kw in node.keywords:
        if kw.arg == "donate_argnums":
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, int):
                nums = (kw.value.value,)
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                vals = [e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)]
                nums = tuple(vals)
        elif kw.arg == "donate_argnames":
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, str):
                names = (kw.value.value,)
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                names = tuple(e.value for e in kw.value.elts
                              if isinstance(e, ast.Constant)
                              and isinstance(e.value, str))
    return nums, names


def _check_donated_reuse(sf: SourceFile) -> list[Finding]:
    findings: list[Finding] = []
    for func in ast.walk(sf.tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        donors: dict[str, tuple[tuple[int, ...], tuple[str, ...]]] = {}
        for node in ast.walk(func):
            if not isinstance(node, ast.Assign):
                continue
            nums, names = _donating_jit(node.value)
            if not nums and not names:
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    donors[tgt.id] = (nums, names)
        if not donors:
            continue
        for node in ast.walk(func):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in donors):
                continue
            nums, names = donors[node.func.id]
            donated: list[str] = []
            for pos in nums:
                if pos < len(node.args) and isinstance(node.args[pos],
                                                       ast.Name):
                    donated.append(node.args[pos].id)
            for kw in node.keywords:
                if kw.arg in names and isinstance(kw.value, ast.Name):
                    donated.append(kw.value.id)
            if not donated:
                continue
            call_line = node.end_lineno or node.lineno
            # `state = step(state)` rebinds the name to the *result*; later
            # loads see the fresh buffer, not the donated one
            rebound = {
                tgt.id
                for sub in ast.walk(func) if isinstance(sub, ast.Assign)
                for tgt in sub.targets
                if isinstance(tgt, ast.Name) and tgt.lineno >= node.lineno
            }
            donated = [d for d in donated if d not in rebound]
            for later in ast.walk(func):
                if (isinstance(later, ast.Name)
                        and isinstance(later.ctx, ast.Load)
                        and later.id in donated
                        and later.lineno > call_line):
                    findings.append(Finding(
                        sf.rel, later.lineno, later.col_offset,
                        "donated-reuse",
                        f"{later.id!r} was donated to {node.func.id}() on "
                        f"line {node.lineno} and read again here — the "
                        "buffer may already be reused by XLA",
                        scope_of(sf, later)))
                    break  # one finding per donated call is enough
    return findings


def run(sf: SourceFile) -> list[Finding]:
    return _check_state_sharding(sf) + _check_donated_reuse(sf)
