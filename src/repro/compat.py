"""Cross-version jax shims shared by the library and the test suite.

`shard_map` moved twice across the jax versions this repo runs on: 0.4.x
exposes it as `jax.experimental.shard_map.shard_map` with the replication
check spelled `check_rep`; newer releases hoist it to `jax.shard_map` and
rename the flag `check_vma`. Everything here routes through one shim so no
caller (library code or a test's subprocess script) hard-codes either
spelling.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` with the `check_vma` spelling on every jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
