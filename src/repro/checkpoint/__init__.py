from repro.checkpoint.store import CheckpointStore
from repro.checkpoint.elastic import restore_resharded

__all__ = ["CheckpointStore", "restore_resharded"]
