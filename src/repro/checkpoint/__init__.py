from repro.checkpoint.store import CheckpointStore
from repro.checkpoint.elastic import restore_resharded
from repro.checkpoint.samples import (
    SAMPLE_KEYS,
    RetainedSample,
    SampleStore,
    as_retained_sample,
)

__all__ = [
    "CheckpointStore",
    "restore_resharded",
    "SAMPLE_KEYS",
    "RetainedSample",
    "SampleStore",
    "as_retained_sample",
]
