"""Elastic rescale: restore any checkpoint onto a different mesh.

Parameter shapes are mesh-independent (only shardings change), so elastic
up/down-scaling is: load host arrays -> device_put with the NEW mesh's
NamedShardings. The sharding rules in models/api.py are pure functions of
(config, mesh), so the target shardings are always reconstructable. For BPMF
states, whose (P, n_loc, K) layout bakes in the shard count, factors are
re-partitioned through the host-order (M, K) representation.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding

from repro.checkpoint.store import CheckpointStore


def restore_resharded(
    store: CheckpointStore,
    like: Any,
    pspecs: Any,
    mesh,
    step: int | None = None,
) -> Any:
    """Restore a checkpoint onto `mesh` using PartitionSpec pytree `pspecs`."""
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    return store.restore(like, step=step, shardings=shardings)
