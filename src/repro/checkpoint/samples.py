"""Retained posterior samples — the deployable artifact of BPMF training.

BPMF's output is not one factor matrix but a set of post-burn-in Gibbs draws
(U_s, V_s, hyper_s); posterior-predictive serving averages over them. The
SampleStore maps each retained draw onto one CheckpointStore step, so sample
retention inherits the store's atomicity and keep-last-N pruning: `keep`
bounds the ensemble size, and a crash mid-save never corrupts an already
retained draw.

Readers (repro.serve) see retained draws on two paths sharing one contract —
the flat key schema below, never the trainer's pytree structure:

  * durable: list and load draws from a SampleStore directory (the original
    pull path; survives trainer restarts, feeds cold server starts), or
  * in-memory: receive the same draws as `RetainedSample`s pushed through a
    `serve.publish.PublicationChannel` by a co-running trainer
    (`as_retained_sample` validates the schema at the publish boundary).

A draw published in memory and the same draw re-loaded from the store are
interchangeable; serving code must not assume arrays are host-resident
(publishes may carry device arrays).

Schema per retained draw (flat dict of host arrays):

    u           (M, K) user factors
    v           (N, K) item factors
    hyper_u_mu  (K,)   user hyper mean        hyper_u_lam  (K, K) precision
    hyper_v_mu  (K,)   item hyper mean        hyper_v_lam  (K, K) precision
    global_mean ()     rating offset subtracted before training
    alpha       ()     observation precision
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.checkpoint.store import CheckpointStore

SAMPLE_KEYS = (
    "u", "v", "hyper_u_mu", "hyper_u_lam", "hyper_v_mu", "hyper_v_lam",
    "global_mean", "alpha",
)


@dataclass(frozen=True, eq=False)
class RetainedSample:
    """One post-burn-in Gibbs draw. Arrays are host np.ndarrays when loaded
    from a SampleStore, and may be device (jax) arrays when the draw arrived
    through an in-memory PublicationChannel publish — consumers stack them
    with jnp.asarray either way (PosteriorEnsemble)."""

    step: int
    u: np.ndarray
    v: np.ndarray
    hyper_u_mu: np.ndarray
    hyper_u_lam: np.ndarray
    hyper_v_mu: np.ndarray
    hyper_v_lam: np.ndarray
    global_mean: float
    alpha: float


def as_retained_sample(step: int, sample: dict) -> RetainedSample:
    """Validate a flat SAMPLE_KEYS dict into a RetainedSample — the shared
    schema gate of both publication paths (SampleStore.retain writes the
    same keys to disk; PublicationChannel.publish hands them to readers
    directly)."""
    missing = set(SAMPLE_KEYS) - set(sample)
    if missing:
        raise ValueError(f"sample missing keys: {sorted(missing)}")
    return RetainedSample(
        step=int(step),
        u=sample["u"],
        v=sample["v"],
        hyper_u_mu=sample["hyper_u_mu"],
        hyper_u_lam=sample["hyper_u_lam"],
        hyper_v_mu=sample["hyper_v_mu"],
        hyper_v_lam=sample["hyper_v_lam"],
        global_mean=float(sample["global_mean"]),
        alpha=float(sample["alpha"]),
    )


class SampleStore:
    """Keep-last-N store of retained Gibbs draws on top of CheckpointStore.

    Async by default: retention happens every post-burn-in sweep, so the
    host-side write overlaps the next sweep instead of stalling the chain
    (GibbsSampler.run calls wait() before returning). Readers are unaffected
    — the executor's worker thread is only spawned on first write.
    """

    def __init__(self, root: str | Path, *, keep: int = 16, use_async: bool = True):
        self.store = CheckpointStore(root, keep=keep, use_async=use_async)

    def retain(self, step: int, sample: dict) -> None:
        """Persist one draw. `sample` must carry exactly SAMPLE_KEYS."""
        missing = set(SAMPLE_KEYS) - set(sample)
        if missing:
            raise ValueError(f"sample missing keys: {sorted(missing)}")
        self.store.save(step, {k: sample[k] for k in SAMPLE_KEYS})

    def wait(self) -> None:
        self.store.wait()

    def steps(self) -> list[int]:
        return self.store.all_steps()

    def load(self, step: int) -> RetainedSample:
        raw = self.store.read_arrays(step)
        # CheckpointStore keys are jax keystrs over the dict: ['u'] etc.
        flat = {k.strip("[']"): v for k, v in raw.items()}
        return RetainedSample(
            step=step,
            u=flat["u"],
            v=flat["v"],
            hyper_u_mu=flat["hyper_u_mu"],
            hyper_u_lam=flat["hyper_u_lam"],
            hyper_v_mu=flat["hyper_v_mu"],
            hyper_v_lam=flat["hyper_v_lam"],
            global_mean=float(flat["global_mean"]),
            alpha=float(flat["alpha"]),
        )

    def load_all(self, max_samples: int | None = None) -> list[RetainedSample]:
        """The newest `max_samples` retained draws (all if None), oldest
        first. The serving epoch is the newest step number — a cheap
        monotone cache key (see serve/frontend.py).

        Draws that vanish between listing and loading are skipped: a
        co-running trainer's keep-last-N prune runs in *its* process (the
        store lock is per-process), so a reader can lose a race for the
        oldest steps. Newest steps are never pruned first, so the ensemble
        stays valid — just one draw smaller.
        """
        steps = self.steps()
        if max_samples is not None:
            steps = steps[-max_samples:]
        out = []
        for s in steps:
            try:
                out.append(self.load(s))
            except FileNotFoundError:
                continue  # pruned by the trainer after we listed it
        return out

    def epoch(self) -> int | None:
        """Newest retained step, or None when nothing is retained yet."""
        return self.store.latest_step()
