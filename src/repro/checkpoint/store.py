"""Atomic, async, keep-last-N checkpointing without external dependencies.

Layout:   <root>/step_<N>/manifest.json + leaf_<i>.npy
Atomicity: written into step_<N>.tmp, fsync'd, then os.rename — a reader
never observes a partial checkpoint, and a crash mid-save leaves the previous
checkpoint intact (the fault-tolerance contract runtime/trainer.py relies on).
Async mode hands the host-side write to a worker thread so the train loop
only blocks for the device->host copy.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import ml_dtypes
import numpy as np


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _to_host(v) -> np.ndarray:
    """Device->host with bf16 handled (numpy exposes it as void-2)."""
    a = np.asarray(v)
    if a.dtype == np.dtype("V2"):
        a = a.view(ml_dtypes.bfloat16)
    return a


def _from_host(a: np.ndarray) -> np.ndarray:
    if a.dtype == np.dtype("V2"):
        a = a.view(ml_dtypes.bfloat16)
    return a


class CheckpointStore:
    def __init__(self, root: str | Path, *, keep: int = 3, use_async: bool = False):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.use_async = use_async
        self._pool = (
            concurrent.futures.ThreadPoolExecutor(max_workers=1) if use_async else None
        )
        self._pending: concurrent.futures.Future | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        # Device->host copy happens synchronously (consistent snapshot) ...
        host_leaves = [(p, _to_host(v)) for p, v in leaves]
        if self.use_async:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host_leaves)
        else:
            self._write(step, host_leaves)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_leaves) -> None:
        with self._lock:
            final = self.root / f"step_{step:010d}"
            tmp = self.root / f"step_{step:010d}.tmp"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": []}
            for i, (path, arr) in enumerate(host_leaves):
                fn = f"leaf_{i:05d}.npy"
                np.save(tmp / fn, arr)
                manifest["leaves"].append(
                    {"path": _path_str(path), "file": fn,
                     "shape": list(arr.shape), "dtype": str(arr.dtype)}
                )
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            # fsync the directory entry for crash consistency
            fd = os.open(tmp, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.root / f"step_{s:010d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in self.root.glob("step_*"):
            if d.suffix == ".tmp" or not (d / "manifest.json").exists():
                continue
            out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_arrays(self, step: int | None = None) -> dict[str, np.ndarray]:
        """Read one checkpoint as {keystr path: host array} without a `like`
        tree — the serving loader's entry point (the server does not know the
        trainer's pytree structure, only the manifest)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.root}")
        d = self.root / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        out = {
            meta["path"]: _from_host(np.load(d / meta["file"]))
            for meta in manifest["leaves"]
        }
        # insertion order == manifest order; paths are unique by construction
        assert len(out) == len(manifest["leaves"])
        return out

    def restore(self, like: Any, step: int | None = None, shardings: Any = None) -> Any:
        """Restore into the structure of `like` (shapes validated).

        `shardings`: optional pytree of jax.sharding.Sharding — enables
        restoring onto a different mesh (see checkpoint/elastic.py).
        """
        raw = self.read_arrays(step)
        leaves, treedef = jax.tree_util.tree_flatten(like)
        assert len(leaves) == len(raw), (len(leaves), len(raw))
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
        )
        out = []
        for i, (leaf, (path, arr)) in enumerate(zip(leaves, raw.items())):
            expected = tuple(getattr(leaf, "shape", arr.shape))
            assert tuple(arr.shape) == expected, (path, arr.shape, expected)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
