"""Fault-tolerant training loop.

Cluster posture for thousands of nodes:
  - checkpoint/restart: atomic keep-N checkpoints (checkpoint/store.py),
    auto-resume from the latest on any failure;
  - failure handling: every step is wrapped; a failing step (injected here
    via `fail_at_steps`, real-world: device loss, preemption) triggers
    restore-from-checkpoint and replay — the data pipeline is seekable, so
    replayed batches are identical;
  - straggler mitigation: per-step wall-time watchdog; steps slower than
    `straggler_factor` x the running median are counted and surfaced — on a
    real cluster this signal drives re-slicing / hot-spare swap (SPMD steps
    are deterministic, so persistent stragglers are hardware, not data);
  - elastic rescale: `Trainer.rescale(new_mesh)` reshards the live state via
    checkpoint/elastic.py (exercised in tests with differing device counts).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointStore


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    use_async_ckpt: bool = True
    max_retries: int = 3
    straggler_factor: float = 3.0
    fail_at_steps: tuple[int, ...] = ()   # failure injection (tests/demos)


class Trainer:
    def __init__(
        self,
        train_step: Callable,
        init_state: Any,
        data_fn: Callable[[int], dict],
        cfg: TrainerConfig = TrainerConfig(),
        state_shardings: Any = None,
    ):
        self.train_step = train_step
        self.data_fn = data_fn
        self.cfg = cfg
        self.store = CheckpointStore(
            cfg.ckpt_dir, keep=cfg.keep, use_async=cfg.use_async_ckpt
        )
        self.state_shardings = state_shardings
        latest = self.store.latest_step()
        if latest is not None:
            self.state = self.store.restore(
                jax.eval_shape(lambda: init_state), step=latest,
                shardings=state_shardings,
            )
            self.step = latest
            print(f"[trainer] resumed from step {latest}")
        else:
            self.state = init_state
            self.step = 0
        self._failed = set()
        self._durations: list[float] = []
        self.straggler_events = 0
        self.recoveries = 0

    # ------------------------------------------------------------------
    def _maybe_inject_failure(self, step: int) -> None:
        if step in self.cfg.fail_at_steps and step not in self._failed:
            self._failed.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")

    def _recover(self) -> None:
        self.store.wait()
        latest = self.store.latest_step()
        if latest is None:
            raise RuntimeError("failure before first checkpoint — cannot recover")
        self.state = self.store.restore(
            jax.eval_shape(lambda: self.state), step=latest,
            shardings=self.state_shardings,
        )
        self.step = latest
        self.recoveries += 1
        print(f"[trainer] recovered from checkpoint at step {latest}")

    # ------------------------------------------------------------------
    def run(self, n_steps: int, *, log_every: int = 10) -> dict:
        history = []
        target = self.step + n_steps
        retries = 0
        # step-0 checkpoint so the first failure window is covered
        if self.store.latest_step() is None:
            self.store.save(self.step, self.state)
        while self.step < target:
            try:
                t0 = time.time()
                self._maybe_inject_failure(self.step)
                batch = self.data_fn(self.step)
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                self.state, metrics = self.train_step(self.state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                self._watch_straggler(dt)
                self.step += 1
                retries = 0
                history.append(loss)
                if self.step % log_every == 0:
                    print(f"[trainer] step {self.step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
                if self.step % self.cfg.ckpt_every == 0:
                    self.store.save(self.step, self.state)
            except SimulatedFailure as e:
                print(f"[trainer] {e}")
                retries += 1
                if retries > self.cfg.max_retries:
                    raise
                self._recover()
        self.store.save(self.step, self.state)
        self.store.wait()
        return {
            "final_step": self.step,
            "loss_history": history,
            "recoveries": self.recoveries,
            "straggler_events": self.straggler_events,
        }

    def _watch_straggler(self, dt: float) -> None:
        if len(self._durations) >= 5:
            med = statistics.median(self._durations)
            if dt > self.cfg.straggler_factor * med:
                self.straggler_events += 1
                print(f"[trainer] straggler step: {dt:.3f}s vs median {med:.3f}s")
        self._durations.append(dt)
        if len(self._durations) > 100:
            self._durations.pop(0)
