from repro.runtime.trainer import Trainer, TrainerConfig, SimulatedFailure

__all__ = ["Trainer", "TrainerConfig", "SimulatedFailure"]
