"""Pallas TPU kernels for the BPMF hot spots and attention.

The paper optimizes the per-item update (outer-product accumulation + a
Cholesky-based solve, Sec 3.1); these are the corresponding TPU kernels:

  bpmf_syrk.py        masked batched syrk (precision-matrix accumulation)
  bpmf_gather_syrk.py fused gather+syrk — V stays in HBM, gathered in-kernel
                      (halves the update sweep's dominant traffic)
  chol_solve.py       fused batched Cholesky factor + solve + sample
  bpmf_topn.py        tiled U @ V^T scoring + streaming top-k (BPMF serving)
  flash_attention.py  tiled online-softmax attention (LM serving/training)

Each kernel ships three layers:
  <name>.py  -- pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     -- jit'd public wrapper (padding, backend dispatch)
  ref.py     -- pure-jnp oracle used by the allclose test sweeps
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
