"""Pallas TPU kernel: FUSED gather + masked syrk + segment reduce for BPMF.

The training sweep's hot loop is, per bucket row r with counterpart ids
idx[r, :] and ratings val[r, :]:

    prec_r = sum_w  V[idx[r,w]] V[idx[r,w]]^T * mask[r,w]
    rhs_r  = sum_w  V[idx[r,w]] * val[r,w] * mask[r,w]

followed by a per-item segment reduction over rows (long-tail items are
split across rows). The two-step path (`bpmf_syrk.py`) makes the gathered
(R, W, K) factor block round-trip through HBM (gather write + kernel read)
and then materializes the row-level (R, K, K) precision intermediate for a
separate `segment_sum` — on the BPMF roofline those two are the dominant
memory terms. This kernel eliminates both:

  * V stays in HBM/ANY space; rows are gathered *inside* the kernel with
    double-buffered per-row DMA into a (2, BR, BW, K) VMEM scratch — the
    W axis is tiled, and tile t+1's row DMAs are issued before tile t is
    consumed, so the gather streams HBM exactly once.
  * The masked outer-product sum runs on the MXU (`dot_general` over the
    W tile) into fp32 accumulators. With a bf16 V the caller passes the
    factor matrix pre-cast (one cast amortized over every gathered row
    read) and only the accumulation is fp32 — halving the gather traffic.
  * Segment reduction happens *in kernel*: bucket rows are ordered by
    segment (nondecreasing, dense 0..n_segments-1 — the planner invariant),
    so the rows of one grid step span at most `block_rows` consecutive
    segments. A one-hot (BR, BR) matmul collapses the row block to
    per-segment partials which are accumulated into the output range
    [seg0, seg0 + BR) — per-segment (prec, rhs) exit the kernel directly
    and the (R, K, K) row-level intermediate never exists.

A leading stacked-draw axis (V of shape (S, N, K), e.g. the serving
fold-in's S retained draws) becomes the slow grid dimension: the same plan
block is swept against every draw's factors.

The accumulating output writes rely on the TPU grid being sequential
(default dimension semantics — no "parallel" annotation); outputs are
zero-initialized through `input_output_aliases`. Validated in interpret
mode against the einsum reference; on real hardware the ANY-space
load/store pair on the output range lowers to a VMEM round trip per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_syrk_seg_kernel(
    seg_ref,                      # scalar prefetch: (R,) int32, nondecreasing
    idx_ref, val_ref, msk_ref,    # (BR, W) VMEM row blocks
    v_ref,                        # ANY: (N, K) or (S, N, K) — gathered in-kernel
    pz_ref, rz_ref,               # zero inits, aliased onto the outputs
    prec_ref, rhs_ref,            # ANY outputs: (..., P, K, K), (..., P, K)
    gather_buf,                   # VMEM scratch: (2, BR, BW, K)
    dma_sem,                      # DMA semaphores: (2,)
    *, width: int, block_w: int, block_rows: int, stacked: bool,
):
    del pz_ref, rz_ref  # aliased zero-init buffers; written via prec/rhs refs
    i = pl.program_id(1) if stacked else pl.program_id(0)
    s = pl.program_id(0) if stacked else None
    br = idx_ref.shape[0]
    k = v_ref.shape[-1]
    n_wt = width // block_w

    def row_dma(slot, wt, t):
        """Async copy of one gathered V row into the tile's scratch slot."""
        r = t // block_w
        w = t % block_w
        j = idx_ref[r, wt * block_w + w]
        src = (v_ref.at[s, pl.dslice(j, 1), :] if stacked
               else v_ref.at[pl.dslice(j, 1), :])
        return pltpu.make_async_copy(
            src, gather_buf.at[slot, r, pl.dslice(w, 1), :], dma_sem.at[slot]
        )

    def tile_start(slot, wt):
        jax.lax.fori_loop(
            0, br * block_w, lambda t, _: (row_dma(slot, wt, t).start(), 0)[1], 0
        )

    def tile_wait(slot, wt):
        jax.lax.fori_loop(
            0, br * block_w, lambda t, _: (row_dma(slot, wt, t).wait(), 0)[1], 0
        )

    # double-buffered W tiles: issue tile t+1's row DMAs before consuming t
    tile_start(0, 0)
    acc_p = jnp.zeros((br, k, k), jnp.float32)
    acc_r = jnp.zeros((br, k), jnp.float32)
    for wt in range(n_wt):  # static unroll: width // block_w is small
        if wt + 1 < n_wt:
            tile_start((wt + 1) % 2, wt + 1)
        tile_wait(wt % 2, wt)
        g = gather_buf[wt % 2]                                 # (BR, BW, K)
        m = msk_ref[:, wt * block_w:(wt + 1) * block_w]        # (BR, BW)
        vv = val_ref[:, wt * block_w:(wt + 1) * block_w]
        gm = g * m[..., None].astype(g.dtype)
        # fp32 accumulation over a possibly-bf16 gathered block (MXU shapes)
        acc_p += jax.lax.dot_general(
            gm, g, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        acc_r += jax.lax.dot_general(
            (vv * m)[:, None, :], gm.astype(jnp.float32),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )[:, 0, :]

    # in-kernel segment reduction: rows are segment-sorted and dense, so this
    # block's segments span [seg0, seg0 + BR); collapse with a one-hot matmul
    seg_blk = seg_ref[pl.dslice(i * block_rows, block_rows)]
    seg0 = seg_blk[0]
    local = seg_blk - seg0                                     # (BR,) in [0, BR)
    onehot = (
        local[None, :] == jax.lax.broadcasted_iota(jnp.int32, (br, br), 0)
    ).astype(jnp.float32)
    part_p = jax.lax.dot_general(
        onehot, acc_p.reshape(br, k * k), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(br, k, k)
    part_r = jax.lax.dot_general(
        onehot, acc_r, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # accumulate into the owned/overlapping output range (sequential grid)
    if stacked:
        pidx = (s, pl.dslice(seg0, br), slice(None), slice(None))
        ridx = (s, pl.dslice(seg0, br), slice(None))
    else:
        pidx = (pl.dslice(seg0, br), slice(None), slice(None))
        ridx = (pl.dslice(seg0, br), slice(None))
    # the ANY-space ranged read-modify-write is this kernel's documented
    # Mosaic hazard (module docstring + ROADMAP "TPU hardware verification"
    # item): correct under the sequential grid in interpret mode, pending a
    # hardware check / alternative accumulation layout on real TPUs.
    pl.store(prec_ref, pidx, pl.load(prec_ref, pidx) + part_p)  # repro-lint: disable=pallas-anyspace
    pl.store(rhs_ref, ridx, pl.load(rhs_ref, ridx) + part_r)  # repro-lint: disable=pallas-anyspace


@functools.partial(
    jax.jit,
    static_argnames=("n_seg_padded", "block_rows", "block_w", "interpret"),
)
def gather_syrk_seg_pallas(
    indices: jax.Array,   # (R, W) int32 — rows of v to gather
    values: jax.Array,    # (R, W) f32
    mask: jax.Array,      # (R, W) f32 (0/1)
    seg_ids: jax.Array,   # (R,) int32 — nondecreasing dense segment per row
    v: jax.Array,         # (N, K) or (S, N, K); f32 or bf16 (bf16-gather mode)
    *,
    n_seg_padded: int,    # >= max(seg_ids) + block_rows, tile-aligned
    block_rows: int = 8,
    block_w: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused gather→syrk→segment-reduce. Returns per-SEGMENT statistics

        prec (..., n_seg_padded, K, K), rhs (..., n_seg_padded, K)

    with a leading draw axis iff ``v`` carried one. Rows must arrive
    segment-sorted (callers: `kernels.ops.gather_syrk_seg` pads + checks).
    """
    r, w = indices.shape
    stacked = v.ndim == 3
    k = v.shape[-1]
    assert r % block_rows == 0 and w % block_w == 0, (r, w, block_rows, block_w)
    kernel = functools.partial(
        _gather_syrk_seg_kernel, width=w, block_w=block_w,
        block_rows=block_rows, stacked=stacked,
    )
    grid = (v.shape[0], r // block_rows) if stacked else (r // block_rows,)
    lead = (v.shape[0],) if stacked else ()

    # index maps receive (*grid_indices, seg_prefetch_ref); the row-block
    # index is always the fastest-varying grid axis
    def row_block(*args):
        *ids, _seg = args
        i = ids[-1]
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, w), row_block),
            pl.BlockSpec((block_rows, w), row_block),
            pl.BlockSpec((block_rows, w), row_block),
            pl.BlockSpec(memory_space=pltpu.ANY),   # v: gathered in-kernel
            pl.BlockSpec(memory_space=pltpu.ANY),   # zero init (aliased)
            pl.BlockSpec(memory_space=pltpu.ANY),   # zero init (aliased)
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, block_rows, block_w, k), v.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    pz = jnp.zeros(lead + (n_seg_padded, k, k), jnp.float32)
    rz = jnp.zeros(lead + (n_seg_padded, k), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(pz.shape, jnp.float32),
            jax.ShapeDtypeStruct(rz.shape, jnp.float32),
        ],
        # indices count the scalar-prefetch arg: 5/6 are the zero inits
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(seg_ids, indices, values, mask, v, pz, rz)
