"""Pallas TPU kernel: FUSED gather + masked syrk for BPMF (perf variant).

`bpmf_syrk.py` consumes a pre-gathered (R, W, K) block of counterpart
factors — which the caller had to materialize in HBM first (gather write +
kernel read = 2x the gathered bytes, the dominant traffic of the BPMF
roofline cells). This kernel keeps the factor matrix V in HBM/ANY space and
gathers rows *inside* the kernel while accumulating the outer products in
VMEM, so the gathered block never round-trips through HBM:

    per row r:  prec_r = sum_w  V[idx[r,w]] V[idx[r,w]]^T * mask[r,w]
                rhs_r  = sum_w  V[idx[r,w]] * val[r,w]

Grid: one step per row block; the W loop runs inside the kernel with
dynamic-index loads from the V ref (scalar-prefetch style). Validated in
interpret mode against the two-step reference (`ops.masked_syrk` on a
host-side gather).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_syrk_kernel(idx_ref, val_ref, msk_ref, v_ref, prec_ref, rhs_ref,
                        *, width: int):
    br = idx_ref.shape[0]
    k = v_ref.shape[1]

    def w_step(w, carry):
        prec, rhs = carry

        def r_step(r, carry2):
            prec, rhs = carry2
            j = idx_ref[r, w]
            row = pl.load(v_ref, (pl.dslice(j, 1), slice(None)))[0]   # (K,)
            m = msk_ref[r, w]
            vv = val_ref[r, w]
            rowm = row * m
            outer = rowm[:, None] * row[None, :]
            prec = jax.lax.dynamic_update_slice(
                prec, (jax.lax.dynamic_slice(prec, (r, 0, 0), (1, k, k))[0]
                       + outer)[None], (r, 0, 0))
            rhs = jax.lax.dynamic_update_slice(
                rhs, (jax.lax.dynamic_slice(rhs, (r, 0), (1, k))[0]
                      + row * (vv * m))[None], (r, 0))
            return prec, rhs

        return jax.lax.fori_loop(0, br, r_step, (prec, rhs))

    prec0 = jnp.zeros((br, k, k), jnp.float32)
    rhs0 = jnp.zeros((br, k), jnp.float32)
    prec, rhs = jax.lax.fori_loop(0, width, w_step, (prec0, rhs0))
    prec_ref[...] = prec
    rhs_ref[...] = rhs


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gather_syrk_pallas(
    indices: jax.Array,   # (R, W) int32 — rows of v to gather
    values: jax.Array,    # (R, W) f32
    mask: jax.Array,      # (R, W) f32
    v: jax.Array,         # (N, K) f32 — stays in HBM/ANY space
    *,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    r, w = indices.shape
    n, k = v.shape
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    kernel = functools.partial(_gather_syrk_kernel, width=w)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # V: gathered in-kernel
        ],
        out_specs=[
            pl.BlockSpec((block_rows, k, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.float32),
        ],
        interpret=interpret,
    )(indices, values, mask, v)
