"""Public jit'd wrappers around the Pallas kernels.

Handles shape padding to kernel tile multiples and selects interpret mode on
non-TPU backends (this container is CPU-only; TPU is the deployment target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bpmf_syrk import masked_syrk_pallas
from repro.kernels.chol_solve import chol_solve_sample_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def masked_syrk(vm: jax.Array, rv: jax.Array, *, interpret: bool | None = None):
    """(..., R, W, K) x (..., R, W) -> (prec (...,R,K,K), rhs (...,R,K)).

    Pads W/R/K to tiles. Extra leading axes (e.g. the fold-in's stacked-draw
    axis S) are flattened into the row axis — every row is independent, so
    the kernel sees one (S*R, W, K) launch instead of S separate ones.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if vm.ndim > 3:
        lead = vm.shape[:-2]
        prec, rhs = masked_syrk(
            vm.reshape((-1,) + vm.shape[-2:]), rv.reshape((-1, rv.shape[-1])),
            interpret=interpret,
        )
        return (prec.reshape(lead + prec.shape[1:]),
                rhs.reshape(lead + rhs.shape[1:]))
    r, w, k = vm.shape
    block_rows = 8
    block_w = min(128, max(8, w))
    vm_p = _pad_to(_pad_to(_pad_to(vm, 0, block_rows), 1, block_w), 2, 8)
    rv_p = _pad_to(_pad_to(rv, 0, block_rows), 1, block_w)
    prec, rhs = masked_syrk_pallas(
        vm_p, rv_p, block_rows=block_rows, block_w=block_w, interpret=interpret
    )
    kp = vm_p.shape[2]
    return prec[:r, :k, :k], rhs[:r, :k]


def chol_solve_sample(prec: jax.Array, rhs: jax.Array, z: jax.Array,
                      *, interpret: bool | None = None):
    """Batched x = Lambda^-1 rhs + L^-T z. Pads the batch to the tile size.

    Any leading axes — (B,), or the fold-in's stacked (S, B) — are flattened
    into one kernel batch: an (S, B, K, K) precision stack becomes a single
    (S*B) launch, which is the fused serving solve. The K axis is NOT padded
    (a zero-padded precision matrix is singular); callers keep K at an
    MXU-friendly size (BPMF uses K=64).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if prec.ndim > 3:
        lead = prec.shape[:-2]
        out = chol_solve_sample(
            prec.reshape((-1,) + prec.shape[-2:]),
            rhs.reshape((-1, rhs.shape[-1])),
            z.reshape((-1, z.shape[-1])),
            interpret=interpret,
        )
        return out.reshape(lead + out.shape[1:])
    bsz = prec.shape[0]
    # always tile: an unaligned batch is padded with identity systems below
    # rather than degrading to one-row tiles
    block_b = 16 if bsz >= 16 else 8
    if bsz % block_b:
        pad = (-bsz) % block_b
        eye = jnp.broadcast_to(jnp.eye(prec.shape[-1], dtype=prec.dtype), (pad,) + prec.shape[1:])
        prec = jnp.concatenate([prec, eye], 0)
        rhs = jnp.concatenate([rhs, jnp.zeros((pad, rhs.shape[1]), rhs.dtype)], 0)
        z = jnp.concatenate([z, jnp.zeros((pad, z.shape[1]), z.dtype)], 0)
    out = chol_solve_sample_pallas(prec, rhs, z, block_b=block_b, interpret=interpret)
    return out[:bsz]


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: int = 0, softcap: float = 0.0,
    scale: float | None = None, interpret: bool | None = None,
):
    """(BH, S, D) flash attention; pads S to tile multiples, masks the pad."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(128, max(16, sq))
    bk = min(128, max(16, sk))
    q_p = _pad_to(q, 1, bq)
    k_p = _pad_to(k, 1, bk)
    v_p = _pad_to(v, 1, bk)
    # padded KV columns are masked inside the kernel only by causal/window;
    # rely on causal (qpos < padded kpos) for the tail. For non-causal use,
    # pad K with -inf-producing zeros is insufficient -> explicitly guard:
    if not causal and k_p.shape[1] != sk:
        raise ValueError("non-causal flash path requires S_k % block == 0")
    out = flash_attention_pallas(
        q_p, k_p, v_p, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :sq]


def topn_scores(u: jax.Array, v: jax.Array, topk: int,
                *, interpret: bool | None = None):
    """Batched top-k of U @ V^T without materialising the (B, N) score matrix.

    u: (B, K) user factors, v: (N, K) item factors -> (values (B, topk),
    indices (B, topk)). Pads B/N to tile multiples; padded items are masked
    to -inf inside the kernel so they are never recommended. Matches
    `jax.lax.top_k` over the full score row bit-for-bit (stable ties) when
    B is a tile multiple; a padded batch can flip last-bit score rounding
    (XLA picks a different gemm micro-kernel per M) but never the selection.
    """
    from repro.kernels.bpmf_topn import topn_scores_pallas

    interpret = (not _on_tpu()) if interpret is None else interpret
    b, k = u.shape
    n = v.shape[0]
    if not 0 < topk <= n:
        raise ValueError(f"topk must be in [1, {n}], got {topk}")
    block_b = 8
    block_n = 128
    while block_n < topk:
        block_n *= 2
    u_p = _pad_to(u, 0, block_b)
    v_p = _pad_to(v, 0, block_n)
    vals, idx = topn_scores_pallas(
        u_p, v_p, topk=topk, n_valid=n,
        block_b=block_b, block_n=block_n, interpret=interpret,
    )
    return vals[:b], idx[:b]


def gather_syrk(indices: jax.Array, values: jax.Array, mask: jax.Array,
                v: jax.Array, *, interpret: bool | None = None):
    """Fused gather+syrk: V stays in HBM, rows gathered in-kernel (R % 8 pad).

    Eliminates the (R, W, K) gathered-block round trip of the two-step path
    — on the BPMF roofline the gathered bytes are the dominant traffic, so
    this halves the memory term of the update sweep.
    """
    from repro.kernels.bpmf_gather_syrk import gather_syrk_pallas

    interpret = (not _on_tpu()) if interpret is None else interpret
    r, w = indices.shape
    block_rows = 8
    pad = (-r) % block_rows
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)))
        values = jnp.pad(values, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    prec, rhs = gather_syrk_pallas(indices, values, mask, v,
                                   block_rows=block_rows, interpret=interpret)
    return prec[:r], rhs[:r]
