"""Public jit'd wrappers around the Pallas kernels.

Handles shape padding to kernel tile multiples and selects interpret mode on
non-TPU backends (this container is CPU-only; TPU is the deployment target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bpmf_syrk import masked_syrk_pallas
from repro.kernels.chol_solve import chol_solve_sample_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _block_w_for(w: int) -> int:
    """W-tile size for a bucket of width w: 8-lane aligned (the balanced
    planner emits non-pow2 widths; the kernels always see lane-aligned
    tiles — the pad columns carry mask 0 and contribute exact zeros)."""
    return min(128, max(8, -(-w // 8) * 8))


def masked_syrk(vm: jax.Array, rv: jax.Array, *, interpret: bool | None = None):
    """(..., R, W, K) x (..., R, W) -> (prec (...,R,K,K), rhs (...,R,K)).

    Pads W/R/K to tiles. Extra leading axes (e.g. the fold-in's stacked-draw
    axis S) are flattened into the row axis — every row is independent, so
    the kernel sees one (S*R, W, K) launch instead of S separate ones.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if vm.ndim > 3:
        lead = vm.shape[:-2]
        prec, rhs = masked_syrk(
            vm.reshape((-1,) + vm.shape[-2:]), rv.reshape((-1, rv.shape[-1])),
            interpret=interpret,
        )
        return (prec.reshape(lead + prec.shape[1:]),
                rhs.reshape(lead + rhs.shape[1:]))
    r, w, k = vm.shape
    block_rows = 8
    block_w = _block_w_for(w)
    vm_p = _pad_to(_pad_to(_pad_to(vm, 0, block_rows), 1, block_w), 2, 8)
    rv_p = _pad_to(_pad_to(rv, 0, block_rows), 1, block_w)
    prec, rhs = masked_syrk_pallas(
        vm_p, rv_p, block_rows=block_rows, block_w=block_w, interpret=interpret
    )
    return prec[:r, :k, :k], rhs[:r, :k]


def chol_solve_sample(prec: jax.Array, rhs: jax.Array, z: jax.Array,
                      *, interpret: bool | None = None):
    """Batched x = Lambda^-1 rhs + L^-T z. Pads the batch to the tile size.

    Any leading axes — (B,), or the fold-in's stacked (S, B) — are flattened
    into one kernel batch: an (S, B, K, K) precision stack becomes a single
    (S*B) launch, which is the fused serving solve. The K axis is NOT padded
    (a zero-padded precision matrix is singular); callers keep K at an
    MXU-friendly size (BPMF uses K=64).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    if prec.ndim > 3:
        lead = prec.shape[:-2]
        out = chol_solve_sample(
            prec.reshape((-1,) + prec.shape[-2:]),
            rhs.reshape((-1, rhs.shape[-1])),
            z.reshape((-1, z.shape[-1])),
            interpret=interpret,
        )
        return out.reshape(lead + out.shape[1:])
    bsz = prec.shape[0]
    # always tile: an unaligned batch is padded with identity systems below
    # rather than degrading to one-row tiles
    block_b = 16 if bsz >= 16 else 8
    if bsz % block_b:
        pad = (-bsz) % block_b
        eye = jnp.broadcast_to(jnp.eye(prec.shape[-1], dtype=prec.dtype), (pad,) + prec.shape[1:])
        prec = jnp.concatenate([prec, eye], 0)
        rhs = jnp.concatenate([rhs, jnp.zeros((pad, rhs.shape[1]), rhs.dtype)], 0)
        z = jnp.concatenate([z, jnp.zeros((pad, z.shape[1]), z.dtype)], 0)
    out = chol_solve_sample_pallas(prec, rhs, z, block_b=block_b, interpret=interpret)
    return out[:bsz]


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: int = 0, softcap: float = 0.0,
    scale: float | None = None, interpret: bool | None = None,
):
    """(BH, S, D) flash attention; pads S to tile multiples, masks the pad."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq = min(128, max(16, sq))
    bk = min(128, max(16, sk))
    q_p = _pad_to(q, 1, bq)
    k_p = _pad_to(k, 1, bk)
    v_p = _pad_to(v, 1, bk)
    # padded KV columns are masked inside the kernel only by causal/window;
    # rely on causal (qpos < padded kpos) for the tail. For non-causal use,
    # pad K with -inf-producing zeros is insufficient -> explicitly guard:
    if not causal and k_p.shape[1] != sk:
        raise ValueError("non-causal flash path requires S_k % block == 0")
    out = flash_attention_pallas(
        q_p, k_p, v_p, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=bq, block_k=bk, interpret=interpret,
    )
    return out[:, :sq]


def topn_scores(u: jax.Array, v: jax.Array, topk: int,
                *, interpret: bool | None = None):
    """Batched top-k of U @ V^T without materialising the (B, N) score matrix.

    u: (B, K) user factors, v: (N, K) item factors -> (values (B, topk),
    indices (B, topk)). Pads B/N to tile multiples; padded items are masked
    to -inf inside the kernel so they are never recommended. Matches
    `jax.lax.top_k` over the full score row bit-for-bit (stable ties) when
    B is a tile multiple; a padded batch can flip last-bit score rounding
    (XLA picks a different gemm micro-kernel per M) but never the selection.
    """
    from repro.kernels.bpmf_topn import topn_scores_pallas

    interpret = (not _on_tpu()) if interpret is None else interpret
    b, k = u.shape
    n = v.shape[0]
    if not 0 < topk <= n:
        raise ValueError(f"topk must be in [1, {n}], got {topk}")
    block_b = 8
    block_n = 128
    while block_n < topk:
        block_n *= 2
    u_p = _pad_to(u, 0, block_b)
    v_p = _pad_to(v, 0, block_n)
    vals, idx = topn_scores_pallas(
        u_p, v_p, topk=topk, n_valid=n,
        block_b=block_b, block_n=block_n, interpret=interpret,
    )
    return vals[:b], idx[:b]


def _gather_syrk_seg_jnp(
    indices, values, mask, seg_ids, n_segments, v,
    *, bf16_gather, identity_segments,
):
    """Fused-semantics jnp path (the off-TPU engine and the XLA fallback).

    Same contraction order as the kernel: gather → masked MXU-style
    dot_general with fp32 accumulation → sorted segment reduction (skipped
    when every row is its own segment — the common narrow-bucket case, where
    the "reduction" is the identity).
    """
    stacked = v.ndim == 3
    if bf16_gather:
        v = v.astype(jnp.bfloat16)
    g = v[:, indices] if stacked else v[indices]      # (..., R, W, K)
    gm = g * mask[..., None].astype(g.dtype)
    rv = values * mask
    nb = g.ndim - 2                                    # batch dims: (...,) + R
    batch = tuple(range(nb))
    prec_rows = jax.lax.dot_general(
        gm, g, (((nb,), (nb,)), (batch, batch)),
        preferred_element_type=jnp.float32,
    )
    rhs_rows = jax.lax.dot_general(
        gm.astype(jnp.float32),
        jnp.broadcast_to(rv, gm.shape[:-1])[..., None],
        (((nb,), (nb,)), (batch, batch)),
        preferred_element_type=jnp.float32,
    )[..., 0]
    # one shared definition of the segment reduction (lazy import: gibbs
    # imports this module lazily too, so neither import is circular)
    from repro.core.gibbs import segment_reduce_rows

    prec = segment_reduce_rows(
        prec_rows, seg_ids, n_segments,
        stacked=stacked, identity=identity_segments,
    )
    rhs = segment_reduce_rows(
        rhs_rows, seg_ids, n_segments,
        stacked=stacked, identity=identity_segments,
    )
    return prec, rhs


def gather_syrk_seg(
    indices: jax.Array,    # (R, W) int32
    values: jax.Array,     # (R, W) f32
    mask: jax.Array,       # (R, W) f32
    seg_ids: jax.Array,    # (R,) int32 — NONDECREASING dense 0..n_segments-1
    n_segments: int,
    v: jax.Array,          # (N, K) counterpart factors, or (S, N, K) stacked
    *,
    bf16_gather: bool = False,
    identity_segments: bool = False,
    interpret: bool | None = None,
):
    """Fused gather→syrk→segment-reduce: per-SEGMENT (prec, rhs) directly.

    The sweep's fused engine. On TPU this is the Pallas kernel (V gathered
    from ANY space, in-kernel segment reduction — the gathered block and the
    row-level (R, K, K) intermediate never touch HBM); elsewhere a
    fused-semantics jnp path with identical contraction order. Pass
    ``interpret=True`` to force the real kernel in interpret mode (the
    equivalence tests); None/False off-TPU both mean the jnp path — a
    compiled Mosaic kernel does not exist there. Rows must be
    segment-sorted — the bucket/grid planner invariant; `bf16_gather`
    halves the dominant gather traffic and keeps fp32 accumulation
    (tolerance documented in docs/architecture.md).

    Returns prec (..., n_segments, K, K), rhs (..., n_segments, K), with the
    leading stacked-draw axis present iff ``v`` carried one.
    """
    use_pallas = interpret is True or _on_tpu()
    if not use_pallas:
        return _gather_syrk_seg_jnp(
            indices, values, mask, seg_ids, n_segments, v,
            bf16_gather=bf16_gather, identity_segments=identity_segments,
        )

    from repro.kernels.bpmf_gather_syrk import gather_syrk_seg_pallas

    interpret = (not _on_tpu()) if interpret is None else bool(interpret)
    r, w = indices.shape
    block_rows = 8
    block_w = _block_w_for(w)
    pad_r = (-r) % block_rows
    if pad_r:
        indices = jnp.pad(indices, ((0, pad_r), (0, 0)))
        values = jnp.pad(values, ((0, pad_r), (0, 0)))
        mask = jnp.pad(mask, ((0, pad_r), (0, 0)))
        # pad rows carry mask 0 and repeat the LAST segment id, keeping the
        # nondecreasing invariant while contributing exact zeros
        seg_ids = jnp.pad(seg_ids, (0, pad_r), mode="edge")
    indices = _pad_to(indices, 1, block_w)
    values = _pad_to(values, 1, block_w)
    mask = _pad_to(mask, 1, block_w)
    if bf16_gather:
        v = v.astype(jnp.bfloat16)   # one cast; every gathered read is half-width
    n_seg_padded = n_segments + block_rows
    n_seg_padded += (-n_seg_padded) % 8
    prec, rhs = gather_syrk_seg_pallas(
        indices, values, mask, seg_ids, v,
        n_seg_padded=n_seg_padded, block_rows=block_rows, block_w=block_w,
        interpret=interpret,
    )
    return prec[..., :n_segments, :, :], rhs[..., :n_segments, :]


def gather_syrk(indices: jax.Array, values: jax.Array, mask: jax.Array,
                v: jax.Array, *, interpret: bool | None = None):
    """Row-level fused gather+syrk (no segment reduction): each row is its
    own segment. Kept for callers that need per-row statistics; the sweep
    engines use `gather_syrk_seg`.
    """
    r = indices.shape[0]
    seg = jnp.arange(r, dtype=jnp.int32)
    return gather_syrk_seg(
        indices, values, mask, seg, r, v,
        identity_segments=True, interpret=interpret,
    )
