"""Pallas TPU kernel: causal flash attention with online softmax.

Tiled (BQ x BK) attention with running (max, denom, acc) carried in VMEM
scratch across the KV grid axis — the quadratic score tensor never touches
HBM. Supports causal masking, sliding windows (gemma2 local layers) and
logit softcaps. This is the TPU fast path; `ref.flash_attention_ref` and the
jnp chunked scan in models/layers.py are the oracles.

Layout: (BH, S, D) with batch*heads flattened into the leading grid axis.
Grid: (BH, Sq/BQ, Sk/BK) — KV fastest, so scratch accumulates sequentially.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, softcap: float, bq: int, bk: int,
):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)               # (BQ, D)
    k = k_ref[0].astype(jnp.float32)               # (BK, D)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                       # (BQ, BK)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    qpos = pl.program_id(1) * bq + jax.lax.iota(jnp.int32, bq)[:, None]
    kpos = j * bk + jax.lax.iota(jnp.int32, bk)[None, :]
    mask = jnp.ones(s.shape, bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # (BQ,)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (BH, S, D) -> (BH, S, D). S % block == 0 (ops.py pads)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (bh, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=block_q, bk=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # running max
            pltpu.VMEM((block_q,), jnp.float32),     # running denom
        ],
        interpret=interpret,
    )(q, k, v)
