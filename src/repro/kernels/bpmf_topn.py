"""Pallas TPU kernel: tiled U @ V^T scoring with streaming top-k.

The BPMF serving hot loop scores a user batch against the full item catalogue
and keeps only the N best items per user:

    scores = U_batch @ V^T            (B, N) — never materialised
    top-k over the item axis          (B, TOPK) values + indices

Materialising (B, N) for millions of items blows HBM and wastes bandwidth on
scores that are immediately discarded. Instead the grid tiles the item axis:
each step computes one (B_blk, N_blk) score tile on the MXU and folds it into
a running (B_blk, TOPK) candidate list held in the output refs, so only the
candidates ever leave VMEM. The item axis is the fastest-varying grid
dimension (sequential on TPU), which makes the in-place merge race-free.

Tie-breaking matches `jax.lax.top_k` bit-for-bit: the running list (earlier,
i.e. lower, item indices) is placed before the fresh tile in the merge and
`lax.top_k` is stable, so equal scores resolve to the lowest item index —
the same order a monolithic top_k over the full score row would produce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topn_kernel(u_ref, v_ref, val_ref, idx_ref, *, topk: int, n_valid: int,
                 block_n: int):
    j = pl.program_id(1)
    u = u_ref[...]                                 # (BB, K)
    v = v_ref[...]                                 # (BN, K)
    scores = jax.lax.dot_general(
        u, v,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (BB, BN)
    cols = j * block_n + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(cols < n_valid, scores, -jnp.inf)

    # top_k / take_along_axis are interpret-only today: the known Mosaic
    # gap tracked by the ROADMAP "TPU hardware verification" item (the
    # planned restructure is iterative argmax selection). Validated in
    # interpret mode; suppressions come out when the kernel is reshaped.
    @pl.when(j == 0)
    def _first():
        vals, pos = jax.lax.top_k(scores, topk)  # repro-lint: disable=pallas-lowering
        val_ref[...] = vals
        idx_ref[...] = jnp.take_along_axis(cols, pos, axis=1)  # repro-lint: disable=pallas-lowering

    @pl.when(j > 0)
    def _merge():
        cand_v = jnp.concatenate([val_ref[...], scores], axis=1)
        cand_i = jnp.concatenate([idx_ref[...], cols], axis=1)
        vals, pos = jax.lax.top_k(cand_v, topk)  # repro-lint: disable=pallas-lowering
        val_ref[...] = vals
        idx_ref[...] = jnp.take_along_axis(cand_i, pos, axis=1)  # repro-lint: disable=pallas-lowering


_trace_count = 0


def trace_count() -> int:
    """How many times the top-N kernel has been (re)traced this process.

    The body of `topn_scores_pallas` bumps the counter at trace time only,
    so the count moves exactly when the jit cache misses — a new
    (shape, static-arg) combination. Serving publishes with unchanged
    (S, N, K) must leave it flat (tests/test_publish.py asserts this);
    compare before/after a swap to prove executable reuse.
    """
    return _trace_count


@functools.partial(
    jax.jit,
    static_argnames=("topk", "n_valid", "block_b", "block_n", "interpret"),
)
def topn_scores_pallas(
    u: jax.Array,
    v: jax.Array,
    *,
    topk: int,
    n_valid: int,
    block_b: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """u: (B, K), v: (N, K) -> (values (B, topk) f32, indices (B, topk) i32).

    B must divide by block_b and N by block_n; rows of v at index >= n_valid
    are padding and never selected (ops.py pads). topk <= block_n so the
    first tile alone can seed the candidate list.
    """
    global _trace_count
    _trace_count += 1  # executes at trace time only: one bump per jit miss
    b, k = u.shape
    n = v.shape[0]
    assert b % block_b == 0 and n % block_n == 0, (b, n, block_b, block_n)
    assert topk <= block_n, (topk, block_n)
    assert topk <= n_valid <= n, (topk, n_valid, n)
    grid = (b // block_b, n // block_n)
    kernel = functools.partial(
        _topn_kernel, topk=topk, n_valid=n_valid, block_n=block_n
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, topk), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, topk), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, topk), jnp.float32),
            jax.ShapeDtypeStruct((b, topk), jnp.int32),
        ],
        interpret=interpret,
    )(u, v)
