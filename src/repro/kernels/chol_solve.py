"""Pallas TPU kernel: fused batched Cholesky factor + solve + sample.

BPMF never needs the precision inverse (paper Sec 3.1): the sampler needs

    x = Lambda^-1 b + L^-T z           with Lambda = L L^T.

This kernel fuses, per VMEM-resident batch tile of K x K matrices:
  1. right-looking Cholesky (column loop, vectorized over the batch tile),
  2. forward substitution  L y = b,
  3. one back substitution L^T x = (y + z)  — mean and noise share it.

K is small (64 padded), so a whole (BB, K, K) tile lives in VMEM and the
column loop is a lax.fori_loop of masked rank-1 updates — no HBM traffic
between the three stages, which is the point of fusing them.

The batch axis is one flat leading dimension; callers with stacked batches
— the serving fold-in's (S draws, B users) solve — flatten them into a
single (S*B) launch through the `kernels.ops.chol_solve_sample` wrapper,
which also pads the batch to the tile size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chol_solve_kernel(prec_ref, rhs_ref, z_ref, out_ref):
    a = prec_ref[...].astype(jnp.float32)          # (B, K, K)
    b = rhs_ref[...].astype(jnp.float32)           # (B, K)
    z = z_ref[...].astype(jnp.float32)             # (B, K)
    bb, k, _ = a.shape
    idx = jax.lax.iota(jnp.int32, k)

    # --- Cholesky, column by column. Invariant: cols >= j of l are zero. ---
    def chol_col(j, l):
        lj_row = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=1)[:, 0, :]  # (B, K) row j
        s = jnp.einsum("bik,bk->bi", l, lj_row)    # cols >= j are zero in l
        col = jax.lax.dynamic_slice_in_dim(a, j, 1, axis=2)[:, :, 0] - s
        dj = jnp.sqrt(jnp.maximum(
            jax.lax.dynamic_slice_in_dim(col, j, 1, axis=1)[:, 0], 1e-20
        ))
        newcol = col / dj[:, None]
        newcol = jnp.where(idx[None, :] >= j, newcol, 0.0)
        return jax.lax.dynamic_update_slice_in_dim(
            l, newcol[:, :, None], j, axis=2
        )

    l = jax.lax.fori_loop(0, k, chol_col, jnp.zeros_like(a))

    # --- forward substitution: L y = b ---
    def fwd(j, y):
        lrow = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=1)[:, 0, :]     # (B, K)
        ljj = jax.lax.dynamic_slice_in_dim(lrow, j, 1, axis=1)[:, 0]
        lrow = jnp.where(idx[None, :] < j, lrow, 0.0)
        bj = jax.lax.dynamic_slice_in_dim(b, j, 1, axis=1)[:, 0]
        yj = (bj - jnp.einsum("bk,bk->b", lrow, y)) / ljj
        return jax.lax.dynamic_update_slice_in_dim(y, yj[:, None], j, axis=1)

    y = jax.lax.fori_loop(0, k, fwd, jnp.zeros_like(b))
    y = y + z                                       # mean + noise share L^-T

    # --- back substitution: L^T x = y  (uses column j of L below diag) ---
    def bwd(t, x):
        j = k - 1 - t
        lcol = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=2)[:, :, 0]     # (B, K)
        ljj = jax.lax.dynamic_slice_in_dim(lcol, j, 1, axis=1)[:, 0]
        lcol = jnp.where(idx[None, :] > j, lcol, 0.0)
        yj = jax.lax.dynamic_slice_in_dim(y, j, 1, axis=1)[:, 0]
        xj = (yj - jnp.einsum("bk,bk->b", lcol, x)) / ljj
        return jax.lax.dynamic_update_slice_in_dim(x, xj[:, None], j, axis=1)

    x = jax.lax.fori_loop(0, k, bwd, jnp.zeros_like(b))
    out_ref[...] = x


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def chol_solve_sample_pallas(
    prec: jax.Array,
    rhs: jax.Array,
    z: jax.Array,
    *,
    block_b: int = 16,
    interpret: bool = False,
) -> jax.Array:
    """prec: (B, K, K), rhs/z: (B, K) -> x (B, K). B % block_b == 0."""
    bsz, k, _ = prec.shape
    assert bsz % block_b == 0, (bsz, block_b)
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _chol_solve_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k), jnp.float32),
        interpret=interpret,
    )(prec, rhs, z)
