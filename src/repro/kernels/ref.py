"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_syrk_ref(vm: jax.Array, rv: jax.Array) -> tuple[jax.Array, jax.Array]:
    """vm: (R, W, K) pre-masked gathered factors; rv: (R, W) masked ratings.

    Returns (prec (R,K,K) = vm^T vm, rhs (R,K) = rv @ vm) per row.
    """
    prec = jnp.einsum("rwk,rwl->rkl", vm, vm, preferred_element_type=jnp.float32)
    rhs = jnp.einsum("rwk,rw->rk", vm, rv)
    return prec, rhs


def chol_solve_sample_ref(prec: jax.Array, rhs: jax.Array, z: jax.Array) -> jax.Array:
    """x = Lambda^-1 rhs + L^-T z with Lambda = L L^T (batched)."""
    chol = jnp.linalg.cholesky(prec)
    y = jax.lax.linalg.triangular_solve(chol, rhs[..., None], left_side=True, lower=True)
    x = jax.lax.linalg.triangular_solve(
        chol, y + z[..., None], left_side=True, lower=True, transpose_a=True
    )
    return x[..., 0]


def topn_scores_ref(
    u: jax.Array, v: jax.Array, topk: int
) -> tuple[jax.Array, jax.Array]:
    """Monolithic U @ V^T then jax.lax.top_k — the bit-for-bit oracle."""
    scores = jax.lax.dot_general(
        u, v,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return jax.lax.top_k(scores, topk)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    """q,k,v: (BH, S, D). Direct softmax attention in f32."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
