"""Pallas TPU kernel: masked batched syrk for the BPMF precision matrices.

The hot loop of the BPMF item update is, per bucket row,

    prec_r = sum_w vm[r, w, :] vm[r, w, :]^T        (K x K outer-product sum)
    rhs_r  = sum_w rv[r, w] * vm[r, w, :]

i.e. a batch of (W x K)^T (W x K) products — exactly the MXU's shape. The
kernel tiles rows into VMEM blocks and (for wide buckets) blocks the W axis
with in-VMEM accumulation, so the gathered factor block streams HBM->VMEM
once. K is padded to the 64/128 lane width by the caller (ops.py).

Grid: (rows / BR, W / BW); the W axis is the fastest-varying (sequential on
TPU), so output tiles accumulate in place across W steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _syrk_kernel(vm_ref, rv_ref, prec_ref, rhs_ref):
    j = pl.program_id(1)
    vm = vm_ref[...]                     # (BR, BW, K)
    rv = rv_ref[...]                     # (BR, BW)
    prec = jax.lax.dot_general(
        vm, vm,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                    # (BR, K, K)
    rhs = jnp.einsum("rwk,rw->rk", vm, rv)

    @pl.when(j == 0)
    def _init():
        prec_ref[...] = prec
        rhs_ref[...] = rhs

    @pl.when(j > 0)
    def _acc():
        prec_ref[...] += prec
        rhs_ref[...] += rhs


@functools.partial(jax.jit, static_argnames=("block_rows", "block_w", "interpret"))
def masked_syrk_pallas(
    vm: jax.Array,
    rv: jax.Array,
    *,
    block_rows: int = 8,
    block_w: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """vm: (R, W, K) f32, rv: (R, W) f32 -> (prec (R,K,K), rhs (R,K)).

    R must divide by block_rows and W by block_w (ops.py pads).
    """
    r, w, k = vm.shape
    assert r % block_rows == 0 and w % block_w == 0, (r, w, block_rows, block_w)
    grid = (r // block_rows, w // block_w)
    return pl.pallas_call(
        _syrk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_w, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_rows, block_w), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, k, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_rows, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.float32),
        ],
        interpret=interpret,
    )(vm, rv)
