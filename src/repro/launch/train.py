"""Train-step construction: loss + grad + AdamW, with mesh-aware shardings."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import build_model, param_pspecs
from repro.models.layers import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(cfg: ModelConfig, key: jax.Array, opt_cfg: AdamWConfig) -> TrainState:
    model = build_model(cfg)
    params = model.init(key)
    return TrainState(
        params=params, opt=adamw_init(params, opt_cfg), step=jnp.zeros((), jnp.int32)
    )


def train_state_shapes(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """ShapeDtypeStructs of the train state — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg)
    )


def train_state_pspecs(cfg: ModelConfig, state_shapes: TrainState, mesh) -> TrainState:
    return TrainState(
        params=param_pspecs(cfg, state_shapes.params, mesh),
        opt=AdamWState(
            m=param_pspecs(cfg, state_shapes.opt.m, mesh),
            v=param_pspecs(cfg, state_shapes.opt.v, mesh),
            step=P(),
        ),
        step=P(),
    )


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *, total_steps: int = 100_000):
    model = build_model(cfg)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        def loss_of(p):
            return model.loss_fn(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(state.params)
        lr = cosine_schedule(
            state.step, peak_lr=opt_cfg.lr, warmup_steps=min(2000, total_steps // 10),
            total_steps=total_steps,
        )
        new_params, new_opt, om = adamw_update(grads, state.opt, state.params, opt_cfg, lr=lr)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step


def shardings_of(pspecs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
