"""Train-step construction: loss + grad + AdamW, with mesh-aware shardings.

Also the BPMF training launcher. Plain training retains post-burn-in draws
durably:

    PYTHONPATH=src python -m repro.launch.train --bpmf --samples samples/ \
        --sweeps 24 --k 16

and --co-serve additionally runs a live RecommendFrontend in the same
process, fed by the asynchronous sample-publication channel
(serve/publish.py) — the trainer pushes each retained draw to serving
while the next sweep runs, the overlap the paper makes between computation
and communication (Sec 4), applied to the train -> serve hand-off:

    PYTHONPATH=src python -m repro.launch.train --bpmf --co-serve --sweeps 24

The co-serve path shares its driver with `repro.launch.serve --bpmf
--co-train` (the two entry points are the trainer's and the server's view
of the same overlapped process).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import build_model, param_pspecs
from repro.models.layers import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(cfg: ModelConfig, key: jax.Array, opt_cfg: AdamWConfig) -> TrainState:
    model = build_model(cfg)
    params = model.init(key)
    return TrainState(
        params=params, opt=adamw_init(params, opt_cfg), step=jnp.zeros((), jnp.int32)
    )


def train_state_shapes(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """ShapeDtypeStructs of the train state — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0), opt_cfg)
    )


def train_state_pspecs(cfg: ModelConfig, state_shapes: TrainState, mesh) -> TrainState:
    return TrainState(
        params=param_pspecs(cfg, state_shapes.params, mesh),
        opt=AdamWState(
            m=param_pspecs(cfg, state_shapes.opt.m, mesh),
            v=param_pspecs(cfg, state_shapes.opt.v, mesh),
            step=P(),
        ),
        step=P(),
    )


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *, total_steps: int = 100_000):
    model = build_model(cfg)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        def loss_of(p):
            return model.loss_fn(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(state.params)
        lr = cosine_schedule(
            state.step, peak_lr=opt_cfg.lr, warmup_steps=min(2000, total_steps // 10),
            total_steps=total_steps,
        )
        new_params, new_opt, om = adamw_update(grads, state.opt, state.params, opt_cfg, lr=lr)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return TrainState(params=new_params, opt=new_opt, step=state.step + 1), metrics

    return train_step


def shardings_of(pspecs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# BPMF training CLI (train -> retain; optionally train-while-serve)
# ---------------------------------------------------------------------------
def bpmf_train_main(args) -> None:
    if args.co_serve:
        from repro.launch.serve import run_train_and_serve

        run_train_and_serve(
            scale=args.scale, sweeps=args.sweeps, k=args.k,
            burn_in=args.burn_in, window=args.keep, samples=args.samples,
            seed=args.seed,
        )
        return

    import tempfile

    from repro.checkpoint import SampleStore
    from repro.core import GibbsSampler
    from repro.data import movielens_like, train_test_split

    root = args.samples or tempfile.mkdtemp(prefix="bpmf_samples_")
    ratings, _, _ = movielens_like(scale=args.scale, seed=args.seed)
    train, test = train_test_split(ratings, 0.1, seed=args.seed + 1)
    print(f"training {train.shape[0]} x {train.shape[1]} ({train.nnz} ratings), "
          f"k={args.k}, {args.sweeps} sweeps (burn-in {args.burn_in}) -> {root}")

    if args.mode != "single":
        # multi-device path over all local devices; sgld rides the same
        # grid partition and exchange modes as the Gibbs trainer
        from repro.core.distributed import DistributedBPMF
        from repro.core.sgld import DistributedSGLD

        width = "auto" if args.plan == "balanced" else 32
        if args.engine == "sgld":
            d = DistributedSGLD(train, test, k=args.k, alpha=4.0,
                                mode=args.mode, width=width,
                                minibatch=args.minibatch,
                                step_size=args.step_size)
        else:
            d = DistributedBPMF(train, test, k=args.k, alpha=4.0,
                                mode=args.mode, width=width,
                                engine="fused" if args.engine == "fused" else "einsum")
        state = d.run(args.sweeps, seed=args.seed, verbose=True)
        print(f"test rmse {d.rmse(state):.4f} "
              f"({d.n_shards} shards, engine={args.engine or 'einsum'}, "
              f"mode={args.mode}, plan={args.plan})")
        return

    widths = "balanced" if args.plan == "balanced" else (8, 32, 128)
    if args.engine == "sgld":
        from repro.core.sgld import SGLDSampler

        sampler = SGLDSampler(train, test, k=args.k, alpha=4.0,
                              burn_in=args.burn_in, widths=widths,
                              minibatch=args.minibatch,
                              step_size=args.step_size)
    else:
        sampler = GibbsSampler(train, test, k=args.k, alpha=4.0,
                               burn_in=args.burn_in, widths=widths,
                               engine=args.engine)
    store = SampleStore(root, keep=args.keep)
    state = sampler.run(args.sweeps, seed=args.seed, store=store,
                        thin=args.thin, verbose=True)
    print(f"test rmse {sampler.rmse(state):.4f}; retained "
          f"{len(store.steps())} draws; serve them with: "
          f"python -m repro.launch.serve --bpmf --samples {root}")


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--bpmf", action="store_true",
                    help="train BPMF (the only CLI mode; LM training is a "
                         "library — see make_train_step)")
    ap.add_argument("--samples", default=None,
                    help="SampleStore directory for retained draws "
                         "(default: a fresh temp dir)")
    ap.add_argument("--sweeps", type=int, default=40)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--burn-in", type=int, default=6)
    ap.add_argument("--keep", type=int, default=4,
                    help="retained-draw window (store keep / channel window)")
    ap.add_argument("--scale", type=float, default=0.01,
                    help="movielens_like dataset scale")
    ap.add_argument("--seed", type=int, default=0)
    from repro.core.gibbs import TRAIN_ENGINES

    ap.add_argument("--engine", default=None, choices=list(TRAIN_ENGINES),
                    help="trainer engine, one of: "
                         "'reference' (seed Gibbs data flow, equivalence "
                         "oracle), 'einsum' (restructured Gibbs, the "
                         "default), 'kernel' (two-step Pallas Gibbs), "
                         "'fused' (gather-syrk kernel Gibbs), 'sgld' "
                         "(minibatch SG-MCMC: per-step cost set by "
                         "--minibatch, not dataset size; --sweeps then "
                         "counts SGLD steps)")
    ap.add_argument("--minibatch", type=int, default=4096,
                    help="sgld engine: padded-lane budget per half-step "
                         "(per shard when --mode is distributed)")
    ap.add_argument("--step-size", type=float, default=0.3,
                    help="sgld engine: peak Langevin step size (decays "
                         "polynomially; see optim.schedule.sgld_step_schedule)")
    ap.add_argument("--thin", type=int, default=1,
                    help="retain every thin-th post-burn-in draw (sgld "
                         "publishes far more often than Gibbs — thin keeps "
                         "store/channel traffic bounded)")
    ap.add_argument("--plan", default="balanced",
                    choices=["balanced", "pow2"],
                    help="bucket planner: 'balanced' fits variable widths to "
                         "the degree profile (work-stealing-equivalent load "
                         "balance); 'pow2' is the legacy fixed ladder")
    ap.add_argument("--mode", default="single",
                    choices=["single", "ring", "allgather", "async"],
                    help="'single' = one-device GibbsSampler; otherwise a "
                         "DistributedBPMF exchange mode ('async' = "
                         "stale-tolerant fused ring pipeline)")
    ap.add_argument("--co-serve", action="store_true",
                    help="serve live recommendations from this process while "
                         "training, via the async publication channel")
    args = ap.parse_args()
    if not args.bpmf:
        raise SystemExit("only --bpmf has a CLI; LM training is library-only")
    bpmf_train_main(args)


if __name__ == "__main__":
    main()
