"""HLO-text cost model with loop-trip-count multiplication.

XLA's `compiled.cost_analysis()` counts every while-loop body ONCE — for a
scan-over-layers transformer that understates FLOPs by ~n_layers and hides
per-layer collectives entirely. This module walks the optimized
(post-SPMD-partitioning) HLO text instead:

  - dot flops = 2 * result_elems * contracted_elems, multiplied through the
    call graph (while bodies x known_trip_count from backend_config, fusions,
    calls);
  - HBM traffic at fusion granularity: each non-trivial op contributes
    (operand bytes + result bytes), matching how fused kernels actually touch
    HBM; fusion-internal ops are skipped for bytes but traversed for flops;
  - collective bytes = operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, with loop multipliers.

Shapes in the partitioned module are per-device shards, so all totals are
per-device; the roofline divides by per-chip peaks directly.

Known approximations (documented in EXPERIMENTS.md): elementwise /
transcendental flops are ignored (dot-dominated workloads); conditional
branches are summed; custom-call flops (LAPACK cholesky etc. on the CPU
backend) are ignored.
"""
from __future__ import annotations

import dataclasses
import json
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# Ops that are views/bookkeeping — no HBM traffic of their own.
_FREE_OPS = {
    "bitcast", "tuple", "get-tuple-element", "parameter", "constant",
    "after-all", "iota", "reshape", "broadcast", "copy-start", "copy-done",
    "partition-id", "replica-id", "add-dependency", "opt-barrier",
}

# Elementwise / layout ops the TPU compiler fuses into producers/consumers.
# The CPU backend leaves them as standalone ops (1000+ converts in a bf16
# model); counting their traffic would model a machine with no fusion at all.
# Their inputs/outputs are still charged at the surrounding dot/fusion/
# reduce boundaries.
_FUSED_AWAY_OPS = {
    "convert", "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "compare", "select", "and", "or", "not", "xor", "negate", "abs", "sign",
    "tanh", "exponential", "log", "sqrt", "rsqrt", "power", "cosine", "sine",
    "floor", "ceil", "round-nearest-even", "round-nearest-afz", "clamp",
    "is-finite", "exponential-minus-one", "log-plus-one", "logistic", "atan2",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "reduce-precision", "real", "imag", "slice", "reverse", "transpose",
    "copy", "pad",
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:\S+))\s+([\w\-]+)\("
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_BRANCH_RE = re.compile(r"(?:branch_computations|true_computation|false_computation)=\{?([^,}]+)\}?")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _all_shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_seg: str
    operand_seg: str
    attr_seg: str


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[_Op]] = {}
        self.entry: str | None = None
        self._shapes: dict[tuple[str, str], str] = {}  # (comp, op) -> result seg
        self._parse(hlo_text)
        self._memo: dict[str, tuple[float, float, dict, dict]] = {}
        self.warnings: list[str] = []

    # ---------------- parsing ----------------
    _COMMENT_RE = re.compile(r"/\*.*?\*/")

    def _parse(self, text: str) -> None:
        comp = None
        for raw in text.splitlines():
            line = self._COMMENT_RE.sub("", raw).rstrip()
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                comp = hdr.group(2)
                self.computations[comp] = []
                if hdr.group(1):
                    self.entry = comp
                continue
            if comp is None or "=" not in line:
                continue
            m = _OPLINE_RE.match(line)
            if not m:
                continue
            name, result_seg, opcode = m.group(1), m.group(2), m.group(3)
            rest = line[m.end() - 1 :]
            depth, end = 0, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_seg = rest[: end + 1]
            attr_seg = rest[end + 1 :]
            self.computations[comp].append(
                _Op(name, opcode, result_seg, operand_seg, attr_seg)
            )
            self._shapes[(comp, name)] = result_seg

    # ---------------- cost walking ----------------
    @staticmethod
    def _group_size(attr_seg: str) -> int:
        m = _GROUPS_LIST_RE.search(attr_seg)
        if m:
            return len([x for x in m.group(1).split(",") if x.strip()])
        m = _GROUPS_IOTA_RE.search(attr_seg)
        if m:
            return int(m.group(2))  # [n_groups, group_size]
        return 2

    @staticmethod
    def _wire_factor(op: str, g: int) -> float:
        """Bytes on the wire per device, as a multiple of operand bytes."""
        if g <= 1:
            return 0.0
        return {
            "all-gather": g - 1.0,               # operand is the local shard
            "reduce-scatter": (g - 1.0) / g,     # operand is the full buffer
            "all-reduce": 2.0 * (g - 1.0) / g,   # ring: reduce + broadcast
            "all-to-all": (g - 1.0) / g,
            "collective-permute": 1.0,
        }.get(op, 1.0)

    def _dot_flops(self, comp: str, op: _Op) -> float:
        result_elems = 0
        for dt, dims in _SHAPE_RE.findall(op.result_seg):
            if dt in _DTYPE_BYTES:
                result_elems += _shape_elems(dims)
        cm = _LHS_CDIMS_RE.search(op.attr_seg)
        contract = 1
        if cm:
            idxs = [int(x) for x in cm.group(1).split(",") if x.strip()]
            opnames = _NAME_RE.findall(op.operand_seg)
            if opnames:
                lhs_seg = self._shapes.get((comp, opnames[0]), "")
                sm = _SHAPE_RE.search(lhs_seg)
                if sm:
                    dims = [int(x) for x in sm.group(2).split(",") if x.strip()]
                    for i in idxs:
                        if i < len(dims):
                            contract *= dims[i]
        return 2.0 * result_elems * contract

    def _operand_bytes(self, comp: str, op: _Op) -> int:
        total = 0
        for nm in _NAME_RE.findall(op.operand_seg):
            seg = self._shapes.get((comp, nm))
            if seg:
                total += _all_shape_bytes(seg)
        return total

    def _fusion_bytes(self, comp: str, op: _Op, called: str | None) -> int:
        """Fusion-boundary traffic with slice-awareness.

        A fusion that merely dynamic-slices a big operand (per-layer weight
        slices out of the scanned stack, one layer's KV out of the stacked
        cache) reads only the slice, not the buffer. For each operand whose
        every consumer inside the fused computation is a dynamic-slice /
        slice / gather, charge the sliced result size instead.
        """
        result = _all_shape_bytes(op.result_seg)
        opnames = _NAME_RE.findall(op.operand_seg)
        full = [
            _all_shape_bytes(self._shapes.get((comp, nm), "")) for nm in opnames
        ]
        charged = list(full)
        if called and called in self.computations:
            body = self.computations[called]
            params: dict[int, str] = {}
            for o in body:
                if o.opcode == "parameter":
                    m = re.match(r"\((\d+)\)", o.operand_seg.strip())
                    if m:
                        params[int(m.group(1))] = o.name
            for i in range(len(opnames)):
                pname = params.get(i)
                if pname is None or full[i] < (1 << 20):
                    continue  # only worth it for big buffers
                slice_bytes = 0
                ok = True
                for o in body:
                    if o.opcode == "parameter":
                        continue
                    if f"%{pname}" in o.operand_seg or f"({pname}" in o.operand_seg:
                        if o.opcode in ("dynamic-slice", "slice", "gather"):
                            slice_bytes = max(
                                slice_bytes, _all_shape_bytes(o.result_seg)
                            )
                        else:
                            ok = False
                            break
                if ok and slice_bytes:
                    charged[i] = slice_bytes
        total = result + sum(charged)
        name_l = op.name.lower()
        if any(h in name_l for h in self._INPLACE_HINTS):
            if result in full:
                total -= 2 * result  # aliased in/out buffer
        return max(total, 0)

    _INPLACE_HINTS = ("dynamic-update-slice", "scatter")

    def _inplace_aware_bytes(self, comp: str, op: _Op) -> int:
        """Operand+result traffic, modeling in-place buffer aliasing.

        dynamic-update-slice / scatter (standalone or as the root of a
        fusion) update a buffer in place on TPU: the big aliased operand is
        neither fully read nor fully rewritten — only the update region
        moves. We subtract the aliased pair (one operand whose size equals
        the result) and charge the remaining operands (the update payload).
        """
        result = _all_shape_bytes(op.result_seg)
        operands = []
        for nm in _NAME_RE.findall(op.operand_seg):
            seg = self._shapes.get((comp, nm))
            if seg:
                operands.append(_all_shape_bytes(seg))
        total = result + sum(operands)
        name_l = op.name.lower()
        if op.opcode in self._INPLACE_HINTS or any(
            h in name_l for h in self._INPLACE_HINTS
        ):
            if result in operands:
                total -= 2 * result  # aliased in/out buffer
        return max(total, 0)

    def _analyze_comp(self, comp: str):
        """Returns (flops, hbm_bytes, coll_bytes, coll_counts, wire_bytes)."""
        if comp in self._memo:
            return self._memo[comp]
        zero = {k: 0.0 for k in COLLECTIVE_OPS}
        self._memo[comp] = (0.0, 0.0, dict(zero), dict(zero), dict(zero))  # cycle guard
        flops = 0.0
        hbm = 0.0
        coll_b = dict(zero)
        coll_n = dict(zero)
        coll_w = dict(zero)

        def merge(mult, bf, bb, bc, bn, bw):
            nonlocal flops, hbm
            flops += mult * bf
            hbm += mult * bb
            for k in COLLECTIVE_OPS:
                coll_b[k] += mult * bc.get(k, 0.0)
                coll_n[k] += mult * bn.get(k, 0.0)
                coll_w[k] += mult * bw.get(k, 0.0)

        for op in self.computations.get(comp, ()):
            base = op.opcode.replace("-start", "").replace("-done", "")
            if op.opcode == "dot":
                flops += self._dot_flops(comp, op)
            if base in COLLECTIVE_OPS:
                if op.opcode.endswith("-done"):
                    continue  # paired with -start
                b = self._operand_bytes(comp, op) or _all_shape_bytes(op.result_seg)
                coll_b[base] += b
                coll_n[base] += 1
                coll_w[base] += b * self._wire_factor(base, self._group_size(op.attr_seg))
                hbm += self._operand_bytes(comp, op) + _all_shape_bytes(op.result_seg)
                continue
            if op.opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.attr_seg)
                tm = _TRIP_RE.search(op.attr_seg)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    self.warnings.append(f"while {op.name}: unknown trip count, using 1")
                if bm:
                    merge(trips, *self._analyze_comp(bm.group(1)))
                continue
            if op.opcode == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.attr_seg)
                if cm:
                    bf, _, _, _, _ = self._analyze_comp(cm.group(1))
                    flops += bf  # fusion internals: flops yes, bytes no
                hbm += self._fusion_bytes(comp, op, cm.group(1) if cm else None)
                continue
            if op.opcode in ("call", "async-start"):
                cm = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", op.attr_seg)
                if cm:
                    merge(1, *self._analyze_comp(cm.group(1)))
                continue
            if op.opcode == "conditional":
                for cm in re.findall(r"%([\w.\-]+)", op.attr_seg):
                    if cm in self.computations:
                        merge(1, *self._analyze_comp(cm))
                continue
            if op.opcode in _FREE_OPS or op.opcode in _FUSED_AWAY_OPS:
                continue
            # generic compute op: operands + result traffic
            hbm += self._inplace_aware_bytes(comp, op)
        out = (flops, hbm, coll_b, coll_n, coll_w)
        self._memo[comp] = out
        return out

    def analyze(self) -> dict:
        if not self.entry:
            raise ValueError("no ENTRY computation found")
        flops, hbm, coll_b, coll_n, coll_w = self._analyze_comp(self.entry)
        return {
            "flops": flops,
            "hbm_bytes": hbm,
            "collective_bytes": {k: int(v) for k, v in coll_b.items()},
            "collective_counts": {k: int(v) for k, v in coll_n.items()},
            "collective_total_bytes": int(sum(coll_b.values())),
            "wire_bytes": {k: int(v) for k, v in coll_w.items()},
            "wire_total_bytes": int(sum(coll_w.values())),
            "warnings": self.warnings[:20],
        }


def parse_collectives(hlo_text: str):
    """Back-compat helper: loop-aware collective stats."""
    model = HloCostModel(hlo_text)
    res = model.analyze()

    @dataclasses.dataclass
    class CollectiveStats:
        bytes_by_op: dict
        count_by_op: dict

        @property
        def total_bytes(self) -> int:
            return sum(self.bytes_by_op.values())

    return CollectiveStats(res["collective_bytes"], res["collective_counts"])


def roofline_terms(
    *,
    flops: float,
    hbm_bytes: float,
    collective_bytes_per_device: float,
    n_devices: int,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float,
) -> dict:
    """All inputs are per-device (the partitioned module's shard shapes)."""
    compute_s = flops / peak_flops
    memory_s = hbm_bytes / hbm_bw
    collective_s = collective_bytes_per_device / ici_bw
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms.update(
        dominant=dom,
        step_lower_bound_s=bound,
        roofline_fraction=(compute_s / bound) if bound > 0 else 0.0,
        global_flops=flops * n_devices,
        global_collective_bytes=collective_bytes_per_device * n_devices,
    )
    return terms
