"""Serving launcher: LM decode serving and BPMF recommendation serving.

LM mode (batched prefill + decode for any architecture):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 64 --max-new 32

BPMF mode (posterior-predictive top-N from retained Gibbs samples):

    PYTHONPATH=src python -m repro.launch.serve --bpmf --samples /path/to/dir \
        --requests 256 --max-batch 32 --topk 10

BPMF serving drives the request-batching frontend (repro.serve): requests
are micro-batched, scored by the Pallas streaming top-k kernel against the
item-factor cache (keyed by sample epoch, sharded over the host mesh), and
the run reports queries/sec plus p50/p99 latency. Without --samples it
trains a small synthetic model first so the command works standalone.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import build_model


def build_serving(cfg, max_new: int):
    model = build_model(cfg)
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, headroom=max_new + 8))
    decode = jax.jit(model.decode_fn)
    return model, prefill, decode


def train_demo_samples(root: str, *, seed: int = 0) -> "SparseRatings":
    """Train a small synthetic BPMF model and retain samples under `root`.

    Returns the training ratings (the serve-side seen-item filter).
    """
    from repro.checkpoint import SampleStore
    from repro.core import GibbsSampler
    from repro.data import movielens_like, train_test_split

    ratings, _, _ = movielens_like(scale=0.002, seed=seed)
    train, test = train_test_split(ratings, 0.1, seed=seed + 1)
    sampler = GibbsSampler(train, test, k=16, alpha=4.0, burn_in=6,
                           widths=(8, 32, 128))
    store = SampleStore(root, keep=8)
    sampler.run(14, seed=seed, store=store)
    return train


def bpmf_main(args) -> None:
    from repro.launch.mesh import make_host_mesh
    from repro.serve import RecommendFrontend

    seen = None
    root = args.samples
    if root is None:
        root = tempfile.mkdtemp(prefix="bpmf_samples_")
        print(f"no --samples given; training a demo model into {root}")
        seen = train_demo_samples(root)

    mesh = make_host_mesh()
    fe = RecommendFrontend(root, seen=seen, max_batch=args.max_batch, mesh=mesh)
    ens = fe.ensemble
    print(f"ensemble: {ens.n_samples} samples, {ens.n_users} users x "
          f"{ens.n_items} items, k={ens.k}, epoch={fe.epoch} "
          f"({len(mesh.devices.flatten())} device(s))")

    rng = np.random.default_rng(0)
    users = rng.integers(0, ens.n_users, args.requests)
    # warm the kernel cache at the *serving* batch shape (jit specialises on
    # the padded batch size, so a batch-of-1 warm-up would leave the first
    # timed flush paying compilation)
    for u in users[: args.max_batch]:
        fe.submit(int(u), topk=args.topk)
    fe.flush()
    fe.latencies_s.clear()
    t0 = time.perf_counter()
    served = 0
    for u in users:
        fe.submit(int(u), topk=args.topk)
        if fe.pending >= args.max_batch:
            served += len(fe.flush())
    served += len(fe.flush())
    dt = time.perf_counter() - t0
    lat = fe.latency_percentiles()
    print(f"served {served} requests in {dt:.3f}s -> {served/dt:,.0f} qps  "
          f"p50 {lat['p50']*1e3:.2f} ms  p99 {lat['p99']*1e3:.2f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--bpmf", action="store_true",
                    help="serve BPMF recommendations instead of an LM")
    ap.add_argument("--samples", default=None,
                    help="SampleStore directory of retained Gibbs draws")
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=32)
    args = ap.parse_args()

    if args.bpmf:
        bpmf_main(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model, prefill, decode = build_serving(cfg, args.max_new)
    params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        s_total = cfg.n_patches + args.prompt_len
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s_total, dtype=jnp.int32), (args.batch, 3, s_total))

    t0 = time.time()
    out = prefill(params, batch)
    jax.block_until_ready(out["logits"])
    t_prefill = time.time() - t0

    cache = out["cache"]
    tok = jnp.argmax(out["logits"], -1)[:, None]
    pos0 = (cfg.n_patches if cfg.family == "vlm" else 0) + args.prompt_len
    toks = [tok]
    t0 = time.time()
    for t in range(args.max_new - 1):
        dbatch = {"tokens": tok}
        if cfg.family == "vlm":
            dbatch["positions"] = jnp.full((args.batch, 3, 1), pos0 + t, jnp.int32)
        cache, logits = decode(params, cache, dbatch)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.asarray(jnp.concatenate(toks, axis=1))

    n_tok = args.batch * (args.max_new - 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.0f} ms   decode: {n_tok/max(t_decode,1e-9):,.0f} tok/s")
    print("sample:", gen[0][:16], "...")


if __name__ == "__main__":
    main()
