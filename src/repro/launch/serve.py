"""Serving launcher: LM decode serving and BPMF recommendation serving.

LM mode (batched prefill + decode for any architecture):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 64 --max-new 32

BPMF mode (posterior-predictive top-N from retained Gibbs samples):

    PYTHONPATH=src python -m repro.launch.serve --bpmf --samples /path/to/dir \
        --requests 256 --max-batch 32 --topk 10

BPMF serving drives the request-batching frontend (repro.serve): requests
are micro-batched, scored by the Pallas streaming top-k kernel against the
item-factor cache (keyed by sample epoch, sharded over the host mesh), and
the run reports queries/sec plus p50/p99 latency. Without --samples it
trains a small synthetic model first so the command works standalone.

Co-train mode (train-while-serve, the paper's async overlap applied to the
train -> serve hand-off):

    PYTHONPATH=src python -m repro.launch.serve --bpmf --co-train \
        --sweeps 24 --topk 10

runs the GibbsSampler and the RecommendFrontend in one process, connected
by a serve.publish.PublicationChannel: each retained post-burn-in draw is
pushed to the live frontend (no disk poll), which swaps its ensemble
atomically — reusing the compiled top-N kernel whenever (S, N, K) shapes
are unchanged — while request traffic keeps flowing. Reports publish
-> first-fresh-recommendation latency alongside the usual qps numbers.
The same driver backs `python -m repro.launch.train --bpmf --co-serve`.

Multi-host tier mode (the pod-scale scatter/gather layer, simulated):

    PYTHONPATH=src python -m repro.launch.serve --bpmf --hosts 2 \
        --requests 256 --topk 10

simulates N serving hosts without hardware: the process re-execs itself
under `XLA_FLAGS=--xla_force_host_platform_device_count=N` when fewer
devices exist, pins one ShardHost (resident V' item shard + routed U
replica, serve/cluster.py) per device with its own channel-subscriber
thread, and drives traffic while a publisher thread pushes fresh epochs
mid-stream. Verifies the tier serves top-N bit-identical to the
single-host TopNRecommender on the same ensemble and that served epochs
stay monotone across publishes (the quorum epoch barrier), then reports
qps, commit count, and publish -> all-shards-fresh latency. Add
`--replicas 2` to give every item shard two owner hosts: the run then
also kills one host and verifies serving stays bit-identical and every
publish still commits (failure semantics in docs/serving.md §6).
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import build_model


def build_serving(cfg, max_new: int):
    model = build_model(cfg)
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, headroom=max_new + 8))
    decode = jax.jit(model.decode_fn)
    return model, prefill, decode


def train_demo_samples(root: str, *, seed: int = 0) -> "SparseRatings":
    """Train a small synthetic BPMF model and retain samples under `root`.

    Returns the training ratings (the serve-side seen-item filter).
    """
    from repro.checkpoint import SampleStore
    from repro.core import GibbsSampler
    from repro.data import movielens_like, train_test_split

    ratings, _, _ = movielens_like(scale=0.002, seed=seed)
    train, test = train_test_split(ratings, 0.1, seed=seed + 1)
    sampler = GibbsSampler(train, test, k=16, alpha=4.0, burn_in=6,
                           widths=(8, 32, 128))
    store = SampleStore(root, keep=8)
    sampler.run(14, seed=seed, store=store)
    return train


def run_train_and_serve(
    *,
    scale: float = 0.01,
    sweeps: int = 60,
    k: int = 16,
    burn_in: int = 6,
    window: int = 4,
    samples: str | None = None,
    topk: int = 10,
    max_batch: int = 8,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Train and serve in one process with overlapped sample publication.

    A trainer thread runs the Gibbs chain, publishing every retained draw
    into a PublicationChannel (and, when `samples` is given, also writing it
    durably through the SampleStore — push and durable paths side by side).
    The main thread serves continuous top-N traffic the whole time; the
    frontend's subscriber thread adopts each publish as it lands. Returns a
    metrics dict (also printed): requests served, draws published, ensemble
    swaps, rebinds (swaps that reused the compiled top-N executables), and
    publish -> first-fresh-recommendation latency percentiles.
    """
    import threading

    from repro.checkpoint import SampleStore
    from repro.core import GibbsSampler
    from repro.data import movielens_like, train_test_split
    from repro.serve import PublicationChannel, RecommendFrontend

    if sweeps <= burn_in:
        raise ValueError(
            f"need sweeps > burn_in to publish anything ({sweeps} <= {burn_in})"
        )
    ratings, _, _ = movielens_like(scale=scale, seed=seed)
    train, test = train_test_split(ratings, 0.1, seed=seed + 1)
    sampler = GibbsSampler(train, test, k=k, alpha=4.0, burn_in=burn_in,
                           widths=(8, 32, 128))
    channel = PublicationChannel(window=window)
    store = SampleStore(samples, keep=window) if samples else None
    if verbose:
        print(f"co-train: {train.shape[0]} x {train.shape[1]} ratings matrix, "
              f"{sweeps} sweeps (burn-in {burn_in}), k={k}, window={window}"
              + (f", durable store {samples}" if samples else ""))

    trainer_error: list[BaseException] = []

    def train_loop():
        try:
            sampler.run(sweeps, seed=seed, store=store, publish=channel)
        except BaseException as e:  # noqa: BLE001 — surfaced after join
            trainer_error.append(e)
        finally:
            channel.close()  # always unblocks the serving loop's drain

    trainer = threading.Thread(target=train_loop, name="gibbs-trainer")
    trainer.start()
    try:
        fe = RecommendFrontend(channel=channel, seen=train, max_batch=max_batch)
    except Exception:
        trainer.join()  # surface the root cause, not the closed channel
        if trainer_error:
            raise trainer_error[0]
        raise

    rng = np.random.default_rng(seed)
    served = 0
    fresh_lat: list[float] = []        # publish -> first fresh recommendation
    seen_epochs: list[int] = []
    t0 = time.perf_counter()
    while True:
        drained = channel.closed and fe.epoch >= (channel.epoch or 0)
        for u in rng.integers(0, train.shape[0], max_batch):
            fe.submit(int(u), topk=topk)
        results = fe.flush()
        served += len(results)
        t_now = time.perf_counter()
        for r in results:
            if not seen_epochs or r.epoch > seen_epochs[-1]:
                seen_epochs.append(r.epoch)
                t_pub = channel.publish_time(r.epoch)
                if t_pub is not None and len(seen_epochs) > 1:
                    fresh_lat.append(t_now - t_pub)
        if drained:
            break
    dt = time.perf_counter() - t0
    trainer.join()
    fe.close()
    if trainer_error:
        raise trainer_error[0]

    lat = fe.latency_percentiles()
    metrics = {
        "served": served,
        "qps": served / dt,
        "published": channel.seq,
        "epochs_served": len(seen_epochs),
        "swaps": fe.swaps,
        "rebinds": fe.rebinds,
        "request_p50_ms": lat["p50"] * 1e3,
        "request_p99_ms": lat["p99"] * 1e3,
        "fresh_p50_ms": float(np.median(fresh_lat) * 1e3) if fresh_lat else float("nan"),
        "fresh_max_ms": float(np.max(fresh_lat) * 1e3) if fresh_lat else float("nan"),
    }
    if verbose:
        print(f"served {served} requests in {dt:.2f}s -> {metrics['qps']:,.0f} qps "
              f"while {channel.seq} draws were published; served "
              f"{len(seen_epochs)} distinct epochs "
              f"({fe.swaps} swaps, {fe.rebinds} rebinds without recompile)")
        print(f"request p50 {metrics['request_p50_ms']:.2f} ms  "
              f"p99 {metrics['request_p99_ms']:.2f} ms;  publish->fresh "
              f"p50 {metrics['fresh_p50_ms']:.1f} ms  "
              f"max {metrics['fresh_max_ms']:.1f} ms")
    return metrics


def _ensure_host_devices(n_hosts: int) -> None:
    """Re-exec under XLA_FLAGS=--xla_force_host_platform_device_count=N
    when fewer devices exist than simulated hosts requested. Device count
    is fixed once the backend initialises, so this must replace the
    process; the guard env var prevents an exec loop when the flag cannot
    produce enough devices (e.g. on real accelerators)."""
    if len(jax.devices()) >= n_hosts:
        return
    if os.environ.get("_REPRO_SERVE_HOSTS_REEXEC") == "1":
        raise RuntimeError(
            f"--hosts {n_hosts} needs {n_hosts} devices but only "
            f"{len(jax.devices())} exist even after forcing host devices"
        )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_hosts}"
    ).strip()
    env["_REPRO_SERVE_HOSTS_REEXEC"] = "1"
    os.execvpe(sys.executable,
               [sys.executable, "-m", "repro.launch.serve", *sys.argv[1:]],
               env)


def run_cluster(
    *,
    hosts: int = 2,
    replicas: int = 1,
    samples: str | None = None,
    requests: int = 256,
    topk: int = 10,
    max_batch: int = 8,
    publishes: int = 4,
    seed: int = 0,
    verbose: bool = True,
) -> dict:
    """Drive the multi-host serving tier against live traffic + publishes.

    Builds an N-host ClusterCoordinator (one simulated host per device)
    and a single-host TopNRecommender over the same ensemble, checks the
    tier's top-N is bit-identical, then serves `requests` warm-user batches
    while a publisher thread pushes `publishes` fresh same-shape epochs —
    asserting served epochs never regress (the quorum epoch barrier).

    With --replicas R > 1 each item shard gets R owner hosts, and the run
    additionally kills one host before the publish stream: serving must
    stay bit-identical (requests route to the surviving replica) and every
    publish must still commit (the dead host is excluded from the quorum).
    Returns a metrics dict (also printed).
    """
    import threading

    import numpy as np

    from repro.checkpoint import SampleStore
    from repro.serve import (
        ClusterCoordinator,
        PosteriorEnsemble,
        PublicationChannel,
        TopNRecommender,
    )

    root = samples
    if root is None:
        root = tempfile.mkdtemp(prefix="bpmf_samples_")
        if verbose:
            print(f"no --samples given; training a demo model into {root}")
        train_demo_samples(root, seed=seed)
    ensemble = PosteriorEnsemble.load(root)
    devices = jax.devices()[:hosts]
    if verbose:
        print(f"cluster: {hosts} simulated hosts over {[str(d) for d in devices]}, "
              f"ensemble S={ensemble.n_samples} {ensemble.n_users}x"
              f"{ensemble.n_items} k={ensemble.k} epoch={ensemble.epoch}")

    single = TopNRecommender(ensemble)
    channel = PublicationChannel(window=ensemble.n_samples)
    for s in ensemble.samples:
        channel.publish(s.step, {
            "u": s.u, "v": s.v,
            "hyper_u_mu": s.hyper_u_mu, "hyper_u_lam": s.hyper_u_lam,
            "hyper_v_mu": s.hyper_v_mu, "hyper_v_lam": s.hyper_v_lam,
            "global_mean": np.float32(s.global_mean),
            "alpha": np.float32(s.alpha),
        })
    cluster = ClusterCoordinator(ensemble, devices=devices, channel=channel,
                                 replicas=replicas)

    # --- acceptance gate: the tier must match the single host bit-for-bit
    rng = np.random.default_rng(seed)
    probe = rng.integers(0, ensemble.n_users, max_batch).astype(np.int32)
    v1, i1 = single.recommend(probe, topk)
    v2, i2 = cluster.recommend(probe, topk)
    identical = bool(np.array_equal(i1, i2) and np.array_equal(v1, v2))
    if not identical:
        raise AssertionError(
            f"cluster top-N diverged from single-host: items equal="
            f"{np.array_equal(i1, i2)} values equal={np.array_equal(v1, v2)}"
        )
    if verbose:
        print(f"parity: {hosts}-host tier bit-identical to single-host "
              f"TopNRecommender over {max_batch} probe users (topk={topk})")

    # --- degraded mode: kill one host, the tier must not notice
    if replicas > 1:
        cluster.health.kill(cluster.hosts[0].host_id)
        v3, i3 = cluster.recommend(probe, topk)
        if not (np.array_equal(i1, i3) and np.array_equal(v1, v3)):
            raise AssertionError(
                "degraded tier (1 host down) diverged from single-host"
            )
        if verbose:
            print(f"degraded parity: host 0 killed, replicas={replicas} — "
                  "still bit-identical; publishes must commit past the dead "
                  "host (quorum barrier)")

    # --- serve while a publisher pushes fresh epochs mid-stream
    base = ensemble.samples[-1]

    def publisher():
        p_rng = np.random.default_rng(seed + 1)
        for i in range(publishes):
            time.sleep(0.05)
            step = ensemble.epoch + 1 + i
            channel.publish(step, {
                "u": base.u + 0.01 * p_rng.normal(size=np.shape(base.u)).astype(np.float32),
                "v": base.v + 0.01 * p_rng.normal(size=np.shape(base.v)).astype(np.float32),
                "hyper_u_mu": base.hyper_u_mu, "hyper_u_lam": base.hyper_u_lam,
                "hyper_v_mu": base.hyper_v_mu, "hyper_v_lam": base.hyper_v_lam,
                "global_mean": np.float32(base.global_mean),
                "alpha": np.float32(base.alpha),
            })
        channel.close()

    pub = threading.Thread(target=publisher, name="cluster-publisher")
    pub.start()
    served = 0
    epochs_seen: list[int] = []
    t0 = time.perf_counter()
    deadline = t0 + 300.0  # a wedged barrier must fail loudly, not hang CI
    while True:
        if time.perf_counter() > deadline:
            raise TimeoutError(
                f"cluster stuck at epoch {cluster.epoch} < {channel.epoch}"
            )
        drained = channel.closed and cluster.epoch >= (channel.epoch or 0)
        users = rng.integers(0, ensemble.n_users, max_batch).astype(np.int32)
        epoch = cluster.epoch
        cluster.recommend(users, topk)
        served += len(users)
        if not epochs_seen or epoch != epochs_seen[-1]:
            epochs_seen.append(epoch)
        if drained and served >= requests:
            break
    dt = time.perf_counter() - t0
    pub.join()
    cluster.close()
    assert epochs_seen == sorted(epochs_seen), (
        f"served epochs regressed: {epochs_seen}"
    )

    fresh = cluster.freshness_percentiles()
    metrics = {
        "hosts": hosts,
        "replicas": replicas,
        "served": served,
        "qps": served / dt,
        "bit_identical": identical,
        "commits": cluster.commits,
        "reassignments": cluster.reassignments,
        "epochs_served": len(epochs_seen),
        "fresh_p50_ms": fresh["p50"] * 1e3,
        "fresh_max_ms": fresh["max"] * 1e3,
    }
    if verbose:
        print(f"served {served} requests in {dt:.2f}s -> {metrics['qps']:,.0f} qps "
              f"across {len(epochs_seen)} monotone epochs "
              f"({cluster.commits} barrier commits)")
        print(f"publish -> all-shards-fresh p50 {metrics['fresh_p50_ms']:.1f} ms  "
              f"max {metrics['fresh_max_ms']:.1f} ms")
    return metrics


def bpmf_main(args) -> None:
    from repro.launch.mesh import make_host_mesh
    from repro.serve import RecommendFrontend

    if args.co_train:
        run_train_and_serve(
            sweeps=args.sweeps, samples=args.samples, topk=args.topk,
            window=args.keep, max_batch=args.max_batch,
        )
        return

    seen = None
    root = args.samples
    if root is None:
        root = tempfile.mkdtemp(prefix="bpmf_samples_")
        print(f"no --samples given; training a demo model into {root}")
        seen = train_demo_samples(root)

    mesh = make_host_mesh()
    fe = RecommendFrontend(root, seen=seen, max_batch=args.max_batch, mesh=mesh)
    ens = fe.ensemble
    print(f"ensemble: {ens.n_samples} samples, {ens.n_users} users x "
          f"{ens.n_items} items, k={ens.k}, epoch={fe.epoch} "
          f"({len(mesh.devices.flatten())} device(s))")

    rng = np.random.default_rng(0)
    users = rng.integers(0, ens.n_users, args.requests)
    # warm the kernel cache at the *serving* batch shape (jit specialises on
    # the padded batch size, so a batch-of-1 warm-up would leave the first
    # timed flush paying compilation)
    for u in users[: args.max_batch]:
        fe.submit(int(u), topk=args.topk)
    fe.flush()
    fe.latencies_s.clear()
    t0 = time.perf_counter()
    served = 0
    for u in users:
        fe.submit(int(u), topk=args.topk)
        if fe.pending >= args.max_batch:
            served += len(fe.flush())
    served += len(fe.flush())
    dt = time.perf_counter() - t0
    lat = fe.latency_percentiles()
    print(f"served {served} requests in {dt:.3f}s -> {served/dt:,.0f} qps  "
          f"p50 {lat['p50']*1e3:.2f} ms  p99 {lat['p99']*1e3:.2f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--bpmf", action="store_true",
                    help="serve BPMF recommendations instead of an LM")
    ap.add_argument("--samples", default=None,
                    help="SampleStore directory of retained Gibbs draws")
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--co-train", action="store_true",
                    help="train and serve in one process; retained draws are "
                         "pushed to the live frontend (no disk poll)")
    ap.add_argument("--hosts", type=int, default=0,
                    help="serve through the multi-host tier with N simulated "
                         "hosts (re-execs under "
                         "--xla_force_host_platform_device_count when needed)")
    ap.add_argument("--publishes", type=int, default=4,
                    help="--hosts mode: fresh epochs pushed mid-stream")
    ap.add_argument("--replicas", type=int, default=1,
                    help="--hosts mode: owners per item shard; with R > 1 "
                         "the run kills one host and verifies serving stays "
                         "bit-identical and publishes still commit")
    ap.add_argument("--sweeps", type=int, default=60,
                    help="co-train: total Gibbs sweeps")
    ap.add_argument("--keep", type=int, default=4,
                    help="co-train: publication window / ensemble size")
    args = ap.parse_args()

    if args.bpmf and args.hosts > 0:
        _ensure_host_devices(args.hosts)
        run_cluster(
            hosts=args.hosts, replicas=args.replicas, samples=args.samples,
            requests=args.requests, topk=args.topk,
            max_batch=min(args.max_batch, 8), publishes=args.publishes,
        )
        return
    if args.bpmf:
        bpmf_main(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model, prefill, decode = build_serving(cfg, args.max_new)
    params = model.init(jax.random.PRNGKey(0))

    key, k_tok, k_frames, k_patch = jax.random.split(jax.random.PRNGKey(1), 4)
    batch = {"tokens": jax.random.randint(
        k_tok, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            k_frames, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            k_patch, (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        s_total = cfg.n_patches + args.prompt_len
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s_total, dtype=jnp.int32), (args.batch, 3, s_total))

    t0 = time.time()
    out = prefill(params, batch)
    jax.block_until_ready(out["logits"])
    t_prefill = time.time() - t0

    cache = out["cache"]
    tok = jnp.argmax(out["logits"], -1)[:, None]
    pos0 = (cfg.n_patches if cfg.family == "vlm" else 0) + args.prompt_len
    toks = [tok]
    t0 = time.time()
    for t in range(args.max_new - 1):
        dbatch = {"tokens": tok}
        if cfg.family == "vlm":
            dbatch["positions"] = jnp.full((args.batch, 3, 1), pos0 + t, jnp.int32)
        cache, logits = decode(params, cache, dbatch)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.asarray(jnp.concatenate(toks, axis=1))

    n_tok = args.batch * (args.max_new - 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.0f} ms   decode: {n_tok/max(t_decode,1e-9):,.0f} tok/s")
    print("sample:", gen[0][:16], "...")


if __name__ == "__main__":
    main()
