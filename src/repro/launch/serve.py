"""Serving launcher: batched prefill + decode steps for any architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 64 --max-new 32

Builds the jitted prefill/decode pair (the same functions the dry-run lowers
onto the production meshes), runs a greedy generation loop, and reports
tokens/sec. With --reduced it runs the smoke-size config on the host; without
it, it expects a TPU slice.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import build_model


def build_serving(cfg, max_new: int):
    model = build_model(cfg)
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, headroom=max_new + 8))
    decode = jax.jit(model.decode_fn)
    return model, prefill, decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model, prefill, decode = build_serving(cfg, args.max_new)
    params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
        s_total = cfg.n_patches + args.prompt_len
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s_total, dtype=jnp.int32), (args.batch, 3, s_total))

    t0 = time.time()
    out = prefill(params, batch)
    jax.block_until_ready(out["logits"])
    t_prefill = time.time() - t0

    cache = out["cache"]
    tok = jnp.argmax(out["logits"], -1)[:, None]
    pos0 = (cfg.n_patches if cfg.family == "vlm" else 0) + args.prompt_len
    toks = [tok]
    t0 = time.time()
    for t in range(args.max_new - 1):
        dbatch = {"tokens": tok}
        if cfg.family == "vlm":
            dbatch["positions"] = jnp.full((args.batch, 3, 1), pos0 + t, jnp.int32)
        cache, logits = decode(params, cache, dbatch)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        toks.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.asarray(jnp.concatenate(toks, axis=1))

    n_tok = args.batch * (args.max_new - 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.0f} ms   decode: {n_tok/max(t_decode,1e-9):,.0f} tok/s")
    print("sample:", gen[0][:16], "...")


if __name__ == "__main__":
    main()
