import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""BPMF production-mesh dry-run: lower + compile the distributed sweep at the
paper's full benchmark scales on 256 and 512 chips, for both communication
modes. Plans enter as ShapeDtypeStructs — the planner's shapes are derived
from real degree statistics of the (synthetic, full-scale) dataset, but no
plan arrays are materialized.

    python -m repro.launch.bpmf_dryrun [--dataset chembl|ml20m] [--mode ring|allgather|both]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import AXIS, DistState, make_sweep
from repro.core.hyper import HyperParams, default_prior
from repro.launch.hlo_analysis import HloCostModel, roofline_terms
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

DATASETS = {
    # (n_users, n_items, nnz) at full paper scale
    "chembl": (483_500, 5_775, 1_023_952),
    "ml20m": (138_493, 27_278, 20_000_000),
}


def plan_shape(m: int, n: int, nnz: int, p: int, width: int) -> tuple[int, int, int]:
    """(m_loc, n_loc, rows) estimate for the (P,P) grid plan of the U update.

    rows per block ~ items-with-ratings-in-block + chunk splits; we provision
    the max block at 3x the mean (power-law skew headroom; the host planner
    reports the true max at run time).
    """
    m_loc = -(-m // p)
    n_loc = -(-n // p)
    mean_rows = max(1.0, nnz / (p * p) / 1.0)  # ~1 row per (item, block) touch
    rows = int(np.ceil(3.0 * mean_rows)) + 4
    return m_loc, n_loc, rows


def run_cell(dataset: str, mode: str, multi_pod: bool, k: int = 64, width: int = 32) -> dict:
    m, n, nnz = DATASETS[dataset]
    p = 512 if multi_pod else 256
    mesh = jax.make_mesh((p,), (AXIS,), devices=jax.devices()[:p])
    rec = {
        "arch": f"bpmf-{dataset}-{mode}",
        "shape": f"K{k}_sweep",
        "kind": "bpmf",
        "mesh": f"{p}x1",
        "n_devices": p,
        "ok": False,
    }
    t0 = time.time()
    try:
        m_loc, n_loc_v, ru = plan_shape(m, n, nnz, p, width)
        _, m_loc_u, rv = plan_shape(n, m, nnz, p, width)

        f32, i32 = jnp.float32, jnp.int32
        sds = jax.ShapeDtypeStruct

        def plan_sds(rows):
            return (
                sds((p, p, rows, width), i32),
                sds((p, p, rows, width), f32),
                sds((p, p, rows, width), f32),
                sds((p, p, rows), i32),
                sds((p, p, rows), i32),      # seg_dense
                sds((p, p, rows), i32),      # seg_map
            )

        if mode == "allgather":
            def plan_sds(rows):  # noqa: F811 — flattened layout
                return (
                    sds((p, p * rows, width), i32),
                    sds((p, p * rows, width), f32),
                    sds((p, p * rows, width), f32),
                    sds((p, p * rows), i32),
                    sds((p, p * rows), i32),  # seg_dense
                    sds((p, p * rows), i32),  # seg_map
                )

        state_sds = DistState(
            u=sds((p, m_loc, k), f32),
            v=sds((p, n_loc_v, k), f32),
            hyper_u=HyperParams(sds((k,), f32), sds((k, k), f32)),
            hyper_v=HyperParams(sds((k,), f32), sds((k, k), f32)),
            key=sds((2,), jnp.uint32),
            step=sds((), i32),
        )
        u_plans = plan_sds(ru)
        v_plans = plan_sds(rv)
        ids_u = sds((p, m_loc), i32)
        ids_v = sds((p, n_loc_v), i32)

        sweep = make_sweep(mesh, mode, alpha=1.5, prior=default_prior(k))
        shard = lambda spec: NamedSharding(mesh, spec)
        state_sh = DistState(
            u=shard(P(AXIS)), v=shard(P(AXIS)),
            hyper_u=HyperParams(shard(P()), shard(P())),
            hyper_v=HyperParams(shard(P()), shard(P())),
            key=shard(P()), step=shard(P()),
        )
        plan_sh = tuple(shard(P(AXIS)) for _ in range(6))
        jitted = jax.jit(
            sweep,
            in_shardings=(state_sh, plan_sh, plan_sh, shard(P(AXIS)), shard(P(AXIS))),
            out_shardings=state_sh,
            donate_argnums=(0,),
        )
        with mesh:
            lowered = jitted.lower(state_sds, u_plans, v_plans, ids_u, ids_v)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        print(mem)
        print({k_: v for k_, v in (compiled.cost_analysis() or {}).items()
               if k_ in ("flops", "bytes accessed")})
        cost = HloCostModel(compiled.as_text()).analyze()
        # useful flops: per item update 2*deg*W... analytic: syrk 2*nnz*W_eff*K^2/W... use
        # 2 * nnz * K^2 (outer products) + (M+N) * (2/3 K^3 + 4K^2) (cholesky+solves)
        model_flops = 2.0 * nnz * k * k + (m + n) * (2 / 3 * k**3 + 4 * k * k)
        terms = roofline_terms(
            flops=float(cost["flops"]),
            hbm_bytes=float(cost["hbm_bytes"]),
            collective_bytes_per_device=float(cost["collective_total_bytes"]),
            n_devices=p,
            peak_flops=PEAK_FLOPS_BF16,
            hbm_bw=HBM_BW,
            ici_bw=ICI_BW,
        )
        rec.update(
            ok=True,
            lower_s=round(t_lower, 1),
            compile_s=round(time.time() - t0 - t_lower, 1),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
            ),
            per_device_flops=float(cost["flops"]),
            per_device_hbm_bytes=float(cost["hbm_bytes"]),
            collective_bytes=cost["collective_bytes"],
            collective_counts=cost["collective_counts"],
            collective_total_bytes=cost["collective_total_bytes"],
            wire_bytes=cost.get("wire_bytes"),
            model_flops=model_flops,
            useful_flops_ratio=model_flops / max(float(cost["flops"]) * p, 1.0),
            roofline=terms,
        )
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = round(time.time() - t0, 1)
    ART_DIR.mkdir(parents=True, exist_ok=True)
    out = ART_DIR / f"bpmf-{dataset}-{mode}__K{k}__{'multi' if multi_pod else 'single'}.json"
    out.write_text(json.dumps(rec, indent=2))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[bpmf-dryrun] {dataset} {mode} {rec['mesh']}: {status} ({rec['total_s']}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="both", choices=["chembl", "ml20m", "both"])
    ap.add_argument("--mode", default="both", choices=["ring", "allgather", "both"])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    args = ap.parse_args()
    datasets = ["chembl", "ml20m"] if args.dataset == "both" else [args.dataset]
    modes = ["ring", "allgather"] if args.mode == "both" else [args.mode]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]
    fails = 0
    for d in datasets:
        for mo in modes:
            for mp in meshes:
                fails += 0 if run_cell(d, mo, mp)["ok"] else 1
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
