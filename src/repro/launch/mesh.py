"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The production target is a TPU v5e-class pod of
16x16 = 256 chips; the multi-pod mesh prepends a 2-wide "pod" axis
(2 x 256 = 512 chips) whose links are the slow inter-pod fabric.
"""
from __future__ import annotations

import math

import numpy as np
import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])


def serving_host_devices(*, mesh=None, n_hosts: int | None = None) -> list:
    """Lead devices for the multi-host serving tier (serve/cluster.py),
    one per shard host.

    With a mesh: hosts follow the *slowest* fabric boundary — one host per
    "pod" slice on a multi-pod mesh (the inter-pod links are where a
    resident shard + routed U replica beat shipping score traffic), else
    one per "data" row. Each host's lead device is the first device of its
    slice; its V' shard and U replica are placed there.

    Without a mesh: the first `n_hosts` local devices (the
    `--xla_force_host_platform_device_count` simulation path), padded by
    cycling when fewer exist than requested.
    """
    if mesh is not None:
        axis = "pod" if "pod" in mesh.axis_names else mesh.axis_names[0]
        k = mesh.axis_names.index(axis)
        devs = mesh.devices
        # one lead device per index along the host axis
        return [
            np.take(devs, i, axis=k).flatten()[0]
            for i in range(devs.shape[k])
        ]
    devices = jax.devices()
    if n_hosts is None:
        n_hosts = len(devices)
    return [devices[i % len(devices)] for i in range(n_hosts)]


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])


# Hardware constants for the roofline (TPU v5e-class, per grading spec).
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
