import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with 512 placeholder host devices standing in for the
TPU slice. Proves the distribution config is coherent: sharding mismatches,
compile-time OOM, or unsupported collectives fail loudly here.

Usage:
    python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mesh single
    python -m repro.launch.dryrun --arch all --mesh both        # full sweep
    python -m repro.launch.dryrun --list                        # cell list

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, get_config
from repro.launch.hlo_analysis import HloCostModel, roofline_terms
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.launch.train import (
    make_train_step,
    shardings_of,
    train_state_pspecs,
    train_state_shapes,
)
from repro.models import (
    build_model,
    cache_pspecs,
    input_pspecs,
    input_specs,
    shape_by_name,
    supported_shapes,
)
from repro.optim import AdamWConfig

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _cache_shapes(model, shape):
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))


def lower_cell(cfg, shape, mesh):
    """Build the jitted step for one cell and return (lowered, n_devices)."""
    from repro.models.layers import active_mesh

    with active_mesh(mesh):
        return _lower_cell_inner(cfg, shape, mesh)


def _lower_cell_inner(cfg, shape, mesh):
    model = build_model(cfg)
    ispecs = input_specs(cfg, shape)
    ips = input_pspecs(cfg, shape, mesh)
    in_batch_shardings = {k: NamedSharding(mesh, ips[k]) for k in ispecs}

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.moment_dtype)
        step_fn = make_train_step(cfg, opt_cfg)
        state_sds = train_state_shapes(cfg, opt_cfg)
        state_ps = train_state_pspecs(cfg, state_sds, mesh)
        state_sh = shardings_of(state_ps, mesh)
        jitted = jax.jit(
            step_fn,
            in_shardings=(state_sh, in_batch_shardings),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        with mesh:
            return jitted.lower(state_sds, ispecs)

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    from repro.models import param_pspecs

    params_sh = shardings_of(param_pspecs(cfg, params_sds, mesh), mesh)

    if shape.kind == "prefill":
        jitted = jax.jit(
            model.prefill_fn, in_shardings=(params_sh, in_batch_shardings)
        )
        with mesh:
            return jitted.lower(params_sds, ispecs)

    # decode: one new token against a seq_len cache
    cache_sds = _cache_shapes(model, shape)
    cache_sh = shardings_of(cache_pspecs(cfg, shape, mesh), mesh)
    jitted = jax.jit(
        model.decode_fn,
        in_shardings=(params_sh, cache_sh, in_batch_shardings),
        out_shardings=(cache_sh, None),
        donate_argnums=(1,),
    )
    with mesh:
        return jitted.lower(params_sds, cache_sds, ispecs)


def run_cell(arch: str, shape_name: str, mesh_name: str, save_hlo: bool = False,
             variant: str = "base") -> dict:
    from repro.configs.variants import apply_variant

    cfg = apply_variant(get_config(arch), variant)
    shape = shape_by_name(shape_name)
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "variant": variant,
        "mesh": f"{'2x16x16' if multi else '16x16'}",
        "n_devices": n_dev,
        "ok": False,
    }
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        print(mem)  # proves it fits
        ca = compiled.cost_analysis() or {}
        print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()
        # XLA's cost_analysis counts while bodies once; our HLO walker applies
        # known_trip_count multipliers (see hlo_analysis.py).
        cost = HloCostModel(hlo).analyze()

        flops = float(cost["flops"])
        hbm_bytes = float(cost["hbm_bytes"])
        # tokens processed per step
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        from repro.models.api import model_flops_per_step

        model_flops = model_flops_per_step(cfg, shape)
        terms = roofline_terms(
            flops=flops,
            hbm_bytes=hbm_bytes,
            collective_bytes_per_device=float(cost["collective_total_bytes"]),
            n_devices=n_dev,
            peak_flops=PEAK_FLOPS_BF16,
            hbm_bw=HBM_BW,
            ici_bw=ICI_BW,
        )
        rec.update(
            ok=True,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                code_bytes=mem.generated_code_size_in_bytes,
            ),
            per_device_flops=flops,
            per_device_hbm_bytes=hbm_bytes,
            xla_cost_analysis=dict(
                flops=float(ca.get("flops", 0.0)),
                bytes_accessed=float(ca.get("bytes accessed", 0.0)),
            ),
            collective_bytes=cost["collective_bytes"],
            collective_counts=cost["collective_counts"],
            collective_total_bytes=cost["collective_total_bytes"],
            wire_bytes=cost.get("wire_bytes"),
            wire_total_bytes=cost.get("wire_total_bytes"),
            cost_warnings=cost["warnings"],
            model_flops=model_flops,
            useful_flops_ratio=(model_flops / (flops * n_dev)) if flops else 0.0,
            tokens_per_step=tokens,
            roofline=terms,
        )
        if save_hlo:
            import gzip

            hp = ART_DIR / f"{arch}__{shape_name}__{rec['mesh']}.hlo.gz"
            hp.parent.mkdir(parents=True, exist_ok=True)
            with gzip.open(hp, "wt") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    ART_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "base" else f"__{variant}"
    out = ART_DIR / f"{arch}__{shape_name}__{'multi' if multi else 'single'}{suffix}.json"
    out.write_text(json.dumps(rec, indent=2))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: {status} ({rec['total_s']}s)")
    return rec


def all_cells():
    cells = []
    for arch, cfg in REGISTRY.items():
        for shape in supported_shapes(cfg):
            cells.append((arch, shape.name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shape in all_cells():
            print(arch, shape)
        return

    cells = all_cells()
    if args.arch != "all":
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape != "all":
        cells = [c for c in cells if c[1] == args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    suffix = "" if args.variant == "base" else f"__{args.variant}"
    for arch, shape in cells:
        for mesh_name in meshes:
            out = ART_DIR / f"{arch}__{shape}__{mesh_name}{suffix}.json"
            if args.skip_done and out.exists() and json.loads(out.read_text()).get("ok"):
                print(f"[dryrun] skip {arch} {shape} {mesh_name} (done)")
                continue
            rec = run_cell(arch, shape, mesh_name, save_hlo=args.save_hlo,
                           variant=args.variant)
            n_fail += 0 if rec["ok"] else 1
    print(f"[dryrun] sweep complete, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
