from repro.data.sparse import SparseRatings, csr_from_coo
from repro.data.datasets import (
    synthetic_lowrank,
    chembl_like,
    movielens_like,
    train_test_split,
)

__all__ = [
    "SparseRatings",
    "csr_from_coo",
    "synthetic_lowrank",
    "chembl_like",
    "movielens_like",
    "train_test_split",
]
