"""Synthetic LM data pipeline.

Deterministic, seekable batch stream (batch i is a pure function of (seed, i)
— a crashed-and-restored trainer resumes mid-epoch with no state). Tokens
follow a zipf marginal with a first-order mixing structure so a model can
actually reduce loss; labels are next-token shifted with -100-style masking
expressed as -1.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.models.layers import ModelConfig


@dataclasses.dataclass
class TokenStream:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def __post_init__(self):
        v = min(self.cfg.vocab_size, 32_768)
        rng = np.random.default_rng(self.seed)
        self._vocab = v
        # bigram mixing table: each token prefers a small successor set
        self._succ = rng.integers(0, v, size=(v, 4))
        p = (np.arange(1, v + 1)) ** -1.1
        self._p = p / p.sum()

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.batch, self.seq
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(self._vocab, size=b, p=self._p)
        follow = rng.random((b, s)) < 0.7
        fresh = rng.choice(self._vocab, size=(b, s), p=self._p)
        pick = rng.integers(0, 4, size=(b, s))
        for t in range(s):
            succ = self._succ[toks[:, t], pick[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], succ, fresh[:, t])
        batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }
        cfg = self.cfg
        if cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (b, cfg.encoder_seq, cfg.d_model), dtype=np.float32
            )
        if cfg.family == "vlm":
            npch = cfg.n_patches
            batch["patch_embeds"] = rng.standard_normal(
                (b, npch, cfg.d_model), dtype=np.float32
            )
            full = npch + s
            pos = np.broadcast_to(np.arange(full, dtype=np.int32), (b, 3, full)).copy()
            batch["positions"] = pos
            batch["labels"] = np.concatenate(
                [np.full((b, npch), -1, np.int32), batch["labels"]], axis=1
            )
        return batch
