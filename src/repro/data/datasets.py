"""Synthetic dataset generators shaped like the paper's benchmarks.

The paper evaluates on ChEMBL (1,023,952 ratings; 483,500 compounds x 5,775
targets; heavy power-law degree skew, Fig 2) and MovieLens ml-20m (20M
ratings; 138,493 users x 27,278 movies). No network access is available here,
so we generate synthetic matrices with matching shapes and degree statistics:
a ground-truth low-rank model plus observation noise, sampled with a power-law
popularity profile so the load-balancing machinery faces the same skew the
paper's Fig 2 shows.
"""
from __future__ import annotations

import numpy as np

from repro.data.sparse import SparseRatings


def synthetic_lowrank(
    n_users: int,
    n_items: int,
    k_true: int,
    nnz: int,
    *,
    noise: float = 0.3,
    popularity_exponent: float = 1.1,
    seed: int = 0,
    clip: tuple[float, float] | None = None,
) -> tuple[SparseRatings, np.ndarray, np.ndarray]:
    """Low-rank + noise ratings with power-law item popularity.

    Returns (ratings, U_true, V_true). Ratings are r_ij = u_i . v_j + eps.
    """
    rng = np.random.default_rng(seed)
    u_true = rng.normal(0.0, 1.0 / np.sqrt(k_true), size=(n_users, k_true))
    v_true = rng.normal(0.0, 1.0 / np.sqrt(k_true), size=(n_items, k_true))

    # Power-law popularity over items, mild skew over users.
    item_p = (np.arange(1, n_items + 1, dtype=np.float64)) ** (-popularity_exponent)
    item_p /= item_p.sum()
    user_p = (np.arange(1, n_users + 1, dtype=np.float64)) ** (-0.6)
    user_p /= user_p.sum()

    # Oversample then dedupe (user, item) pairs to reach ~nnz unique ratings.
    # Cap at half density — beyond that rejection sampling stalls.
    target = min(nnz, n_users * n_items // 2)
    rows_list, cols_list = [], []
    seen: set[int] = set()
    attempts = 0
    while sum(len(r) for r in rows_list) < target and attempts < 8:
        m = int((target - sum(len(r) for r in rows_list)) * 1.4) + 16
        r = rng.choice(n_users, size=m, p=user_p)
        c = rng.choice(n_items, size=m, p=item_p)
        keys = r.astype(np.int64) * n_items + c
        fresh = np.array([k not in seen for k in keys], dtype=bool)
        keys_f = keys[fresh]
        # in-batch dedupe
        _, first = np.unique(keys_f, return_index=True)
        keep = np.zeros(len(keys_f), dtype=bool)
        keep[first] = True
        r2, c2 = r[fresh][keep], c[fresh][keep]
        seen.update(keys_f[keep].tolist())
        rows_list.append(r2)
        cols_list.append(c2)
        attempts += 1
    rows = np.concatenate(rows_list)[:target].astype(np.int32)
    cols = np.concatenate(cols_list)[:target].astype(np.int32)

    vals = np.einsum("nk,nk->n", u_true[rows], v_true[cols]) + rng.normal(
        0.0, noise, size=rows.shape
    )
    if clip is not None:
        vals = np.clip(vals, *clip)
    ratings = SparseRatings(
        rows=rows,
        cols=cols,
        vals=vals.astype(np.float32),
        shape=(n_users, n_items),
    )
    ratings.validate()
    return ratings, u_true, v_true


def chembl_like(
    scale: float = 1.0, seed: int = 0
) -> tuple[SparseRatings, np.ndarray, np.ndarray]:
    """ChEMBL-shaped benchmark: 483,500 x 5,775 with ~1.02M ratings at scale=1.

    IC50-style activities modelled as low-rank (k=16) + noise. `scale` shrinks
    every dimension proportionally for CPU-sized runs.
    """
    n_users = max(32, int(483_500 * scale))
    n_items = max(16, int(5_775 * scale))
    nnz = max(64, int(1_023_952 * scale))
    return synthetic_lowrank(
        n_users, n_items, k_true=16, nnz=nnz, noise=0.4,
        popularity_exponent=1.2, seed=seed,
    )


def movielens_like(
    scale: float = 1.0, seed: int = 0
) -> tuple[SparseRatings, np.ndarray, np.ndarray]:
    """ml-20m-shaped benchmark: 138,493 x 27,278 with ~20M ratings at scale=1."""
    n_users = max(32, int(138_493 * scale))
    n_items = max(16, int(27_278 * scale))
    nnz = max(64, int(20_000_000 * scale))
    return synthetic_lowrank(
        n_users, n_items, k_true=16, nnz=nnz, noise=0.5,
        popularity_exponent=1.0, seed=seed, clip=(-2.5, 2.5),
    )


def train_test_split(
    ratings: SparseRatings, test_frac: float = 0.1, seed: int = 0
) -> tuple[SparseRatings, SparseRatings]:
    rng = np.random.default_rng(seed)
    nnz = ratings.nnz
    perm = rng.permutation(nnz)
    n_test = int(nnz * test_frac)
    test_idx, train_idx = perm[:n_test], perm[n_test:]

    def take(idx: np.ndarray) -> SparseRatings:
        return SparseRatings(
            rows=ratings.rows[idx],
            cols=ratings.cols[idx],
            vals=ratings.vals[idx],
            shape=ratings.shape,
        )

    return take(train_idx), take(test_idx)
