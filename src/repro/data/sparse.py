"""Host-side sparse rating-matrix containers.

All planning (bucketing, partitioning, reordering) happens on the host in
numpy; only the padded dense plan arrays ever reach a device. This mirrors the
paper's setup where the sparsity structure of R is analysed once up front
(cache reordering, 2-D distribution) and the sampler then runs on a fixed
layout.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SparseRatings:
    """COO ratings with both orientations derivable.

    rows  -- user index per rating   (nnz,) int32
    cols  -- item index per rating   (nnz,) int32
    vals  -- rating value            (nnz,) float32
    shape -- (n_users, n_items)
    """

    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    def validate(self) -> None:
        assert self.rows.shape == self.cols.shape == self.vals.shape
        assert self.rows.min(initial=0) >= 0 and (
            self.nnz == 0 or self.rows.max() < self.shape[0]
        )
        assert self.cols.min(initial=0) >= 0 and (
            self.nnz == 0 or self.cols.max() < self.shape[1]
        )

    def transpose(self) -> "SparseRatings":
        return SparseRatings(
            rows=self.cols, cols=self.rows, vals=self.vals, shape=self.shape[::-1]
        )

    def csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Row-major CSR (indptr, indices, values)."""
        return csr_from_coo(self.rows, self.cols, self.vals, self.shape[0])

    def degrees(self, axis: int = 0) -> np.ndarray:
        idx = self.rows if axis == 0 else self.cols
        n = self.shape[axis]
        return np.bincount(idx, minlength=n).astype(np.int64)

    def mean(self) -> float:
        return float(self.vals.mean()) if self.nnz else 0.0

    def centered(self) -> "SparseRatings":
        """Global-mean-centred copy (standard BPMF preprocessing)."""
        return SparseRatings(
            rows=self.rows,
            cols=self.cols,
            vals=(self.vals - self.mean()).astype(np.float32),
            shape=self.shape,
        )


def csr_from_coo(
    rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, n_rows: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    order = np.argsort(rows, kind="stable")
    rows_s, cols_s, vals_s = rows[order], cols[order], vals[order]
    indptr = np.zeros(n_rows + 1, dtype=np.int64)
    np.add.at(indptr, rows_s + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, cols_s.astype(np.int32), vals_s.astype(np.float32)
