"""Ensemble scorer over retained BPMF posterior samples.

The posterior-predictive rating of (i, j) under S retained Gibbs draws is

    p(r_ij | R) ~= 1/S sum_s N(r_ij ; u_i^s . v_j^s + mean, 1/alpha)

so the served score is the sample average of the per-draw dot products and
the predictive variance decomposes into epistemic (variance of the dot
product across draws) + aleatoric (1/alpha observation noise). The standard
error of the served *mean* shrinks as 1/S — more retained samples buy a
tighter score, which is the knob the ROADMAP's online-refresh follow-up
turns.

A key serving identity: the posterior-mean score is itself one matmul,

    1/S sum_s U_s V_s^T  =  U' V'^T,   U' = [U_1/S .. U_S/S],  V' = [V_1 .. V_S]

(concatenation along K). `scoring_matrices()` exposes exactly that (B, S*K)
/ (N, S*K) pair, which is what the Pallas top-N kernel consumes — ensemble
averaging costs nothing beyond a wider contraction axis.
"""
from __future__ import annotations

from pathlib import Path
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.samples import RetainedSample, SampleStore


class PosteriorEnsemble:
    """Stacked retained draws, device-resident, ready to score."""

    def __init__(self, samples: Sequence[RetainedSample]):
        if not samples:
            raise ValueError("ensemble needs at least one retained sample")
        shapes = {(s.u.shape, s.v.shape) for s in samples}
        if len(shapes) != 1:
            raise ValueError(f"inconsistent sample shapes: {shapes}")
        self.samples = tuple(samples)
        self.u = jnp.stack([jnp.asarray(s.u) for s in samples])  # (S, M, K)
        self.v = jnp.stack([jnp.asarray(s.v) for s in samples])  # (S, N, K)
        # per-draw user hypers, stacked device-resident: the cold-start
        # fold-in broadcasts one batch of rating statistics against all S
        # of these in a single (S*B) solve (serve/foldin.py)
        self.hyper_u_mu = jnp.stack(
            [jnp.asarray(s.hyper_u_mu) for s in samples]     # (S, K)
        )
        self.hyper_u_lam = jnp.stack(
            [jnp.asarray(s.hyper_u_lam) for s in samples]    # (S, K, K)
        )
        self.global_mean = float(samples[-1].global_mean)
        self.alpha = float(samples[-1].alpha)
        self.epoch = int(samples[-1].step)

    # ------------------------------------------------------------------
    @classmethod
    def load(cls, root: str | Path, *, max_samples: int | None = None
             ) -> "PosteriorEnsemble":
        """Load the retained draws under `root` (newest `max_samples`).

        Tolerates a co-running trainer pruning old draws mid-load (see
        SampleStore.load_all); only draws that survive the race are stacked.
        """
        store = SampleStore(root)
        return cls(store.load_all(max_samples))

    @classmethod
    def from_arrays(
        cls,
        u: jax.Array,
        v: jax.Array,
        *,
        hyper_u_mu: jax.Array,
        hyper_u_lam: jax.Array,
        hyper_v_mu: jax.Array,
        hyper_v_lam: jax.Array,
        global_mean: float,
        alpha: float,
        steps: Sequence[int],
    ) -> "PosteriorEnsemble":
        """In-memory construction from already-stacked (device) arrays, for
        embedders holding trainer state directly — no RetainedSample
        bookkeeping, no disk. (The channel publish path is different: it
        already has per-draw RetainedSamples and stacks them through the
        regular constructor — see RecommendFrontend._adopt_snapshot.)

        u: (S, M, K), v: (S, N, K); hypers are per-draw stacks
        ((S, K) means, (S, K, K) precisions); steps: the S Gibbs step
        numbers, ascending — the newest is the serving epoch.
        """
        u, v = jnp.asarray(u), jnp.asarray(v)
        s = u.shape[0]
        if len(steps) != s or v.shape[0] != s:
            raise ValueError(f"expected {s} steps/draws, got {len(steps)}/{v.shape[0]}")
        steps = [int(x) for x in steps]
        if steps != sorted(steps):
            raise ValueError(f"steps must be ascending (epoch = newest): {steps}")
        hyper_u_mu, hyper_u_lam = jnp.asarray(hyper_u_mu), jnp.asarray(hyper_u_lam)
        hyper_v_mu, hyper_v_lam = jnp.asarray(hyper_v_mu), jnp.asarray(hyper_v_lam)
        return cls(tuple(
            RetainedSample(
                step=steps[i],
                u=u[i], v=v[i],
                hyper_u_mu=hyper_u_mu[i], hyper_u_lam=hyper_u_lam[i],
                hyper_v_mu=hyper_v_mu[i], hyper_v_lam=hyper_v_lam[i],
                global_mean=float(global_mean),
                alpha=float(alpha),
            )
            for i in range(s)
        ))

    def shape_key(self) -> tuple[int, int, int, int]:
        """(S, M, N, K) — equal keys mean every serving executable compiled
        for this ensemble (top-N kernel, scoring jits) is reusable as-is."""
        return (self.n_samples, self.n_users, self.n_items, self.k)

    @property
    def n_samples(self) -> int:
        return self.u.shape[0]

    @property
    def n_users(self) -> int:
        return self.u.shape[1]

    @property
    def n_items(self) -> int:
        return self.v.shape[1]

    @property
    def k(self) -> int:
        return self.u.shape[2]

    # ------------------------------------------------------------------
    def score(
        self, users: jax.Array, items: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        """Posterior mean + predictive variance for (user, item) pairs.

        users, items: (B,) int32 -> (mean (B,), var (B,)). Variance is
        epistemic (across draws) + aleatoric (1/alpha); the epistemic part
        uses the unbiased estimator when S > 1.
        """
        per_draw = self._pair_scores(self.u, self.v, users, items)
        return self._moments(per_draw)

    def score_factors(
        self, u_draws: jax.Array, items: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        """Like score() but for explicit per-draw user factors (S, B, K) —
        the fold-in path, where the user has no row in U."""
        per_draw = (
            jnp.einsum("sbk,sbk->sb", u_draws, self.v[:, items])
            + self.global_mean
        )
        return self._moments(per_draw)

    def mean_stderr(
        self, users: jax.Array, items: jax.Array
    ) -> jax.Array:
        """Standard error of the served posterior-mean score (shrinks ~1/S)."""
        per_draw = self._pair_scores(self.u, self.v, users, items)
        s = per_draw.shape[0]
        var = jnp.var(per_draw, axis=0, ddof=1 if s > 1 else 0)
        return jnp.sqrt(var / s)

    def _pair_scores(self, u, v, users, items) -> jax.Array:
        return (
            jnp.einsum("smk,smk->sm", u[:, users], v[:, items])
            + self.global_mean
        )

    def _moments(self, per_draw: jax.Array) -> tuple[jax.Array, jax.Array]:
        s = per_draw.shape[0]
        mean = per_draw.mean(0)
        epistemic = jnp.var(per_draw, axis=0, ddof=1 if s > 1 else 0)
        return mean, epistemic + 1.0 / self.alpha

    # ------------------------------------------------------------------
    def scoring_matrices(self) -> tuple[jax.Array, jax.Array]:
        """(U' (M, S*K), V' (N, S*K)) with U' V'^T = posterior-mean scores
        minus the global mean — the flattened form the top-N kernel eats."""
        s, m, k = self.u.shape
        u_flat = (self.u / s).transpose(1, 0, 2).reshape(m, s * k)
        v_flat = self.v.transpose(1, 0, 2).reshape(self.n_items, s * k)
        return u_flat, v_flat

    def user_scoring_rows(self, u_draws: jax.Array) -> jax.Array:
        """Flatten explicit per-draw user factors (S, B, K) -> (B, S*K) rows
        compatible with scoring_matrices()' V' — used to score fold-in users
        through the same kernel as trained users."""
        s, b, k = u_draws.shape
        return (u_draws / s).transpose(1, 0, 2).reshape(b, s * k)
