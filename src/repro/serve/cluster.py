"""Multi-host top-N serving tier: scatter/gather over resident item shards,
with per-shard replication and health-routed failover.

The single-host recommender (serve/topn.py) stops scaling at one host's
HBM: V' for the full catalogue must fit beside the U table. This module is
the pod-scale tier ROADMAP names — the same decomposition "A
High-Performance Implementation of Bayesian Matrix Factorization with
Limited Communication" (Vander Aa et al., 2020) uses for BMF at scale:

* Each **ShardHost** owns a *resident* row-range of V' (its item shard)
  plus a *routed replica* of the U scoring table, so a warm-user request
  ships only user ids to every host — each host gathers the rows from its
  own replica and streams its shard through the `bpmf_topn` kernel.
  Cold-start rows (fold-in factors, computed once at the coordinator) are
  scattered to the hosts instead.

* The **ClusterCoordinator** gathers one candidate list per *shard* — each
  `(B, min(fetch, shard_rows))`, so the exchange is bounded by
  O(shards * fetch) values + indices regardless of catalogue size — and
  merges them with the same stable `_merge_topk` the kernel applies across
  item tiles: shards hold disjoint ascending index ranges and are
  concatenated in range order, so ties still resolve to the lowest global
  item index, bit-for-bit what one unsharded `lax.top_k` would pick.

* **Replication & failover** (`replicas=R`): every shard is owned by R
  hosts holding identical bindings, and requests are routed to the first
  healthy, epoch-current replica (serve/faults.py's `HostHealth` tracks
  heartbeats, adopt/serve error escalation, and explicit kills). A host
  that dies mid-request is routed around within the request; a shard whose
  owners are *all* dead is rebuilt from the committed ensemble on a
  surviving host's device (`reassignments` counts these) — served results
  stay bit-identical to a healthy tier at the committed epoch whenever at
  least one replica per shard is live, because every replica (original,
  surviving, or rebuilt) is a pure function of the same ensemble.

* Freshness rides the PublicationChannel's subscriber list (serve/publish):
  `attach()` fans each publish out to one subscriber loop per host. Each
  host *stages* its successor binding (a zero-retrace rebind: same shapes,
  same compiled executables), and the coordinator *commits* an epoch once a
  **quorum** — one healthy staged replica per shard — has staged it: a
  request can never score shard 0 against epoch E and shard 1 against E-1
  (no torn cross-shard ensembles), and a dead or hung host no longer wedges
  the barrier (it is simply absent from the quorum; with `replicas=1` its
  shard is reassigned and the replacement stages). Replicas that stage the
  committed epoch late flip in place — identical data, no second commit.
  A host that falls behind makes only *its* shard lean on the other
  replicas; epochs it skipped are never served.

* **Fault seams** (serve/faults.py): when a `FaultPlan` is injected, the
  coordinator fires named hook points — "adopt" (subscriber picked up a
  publish), "stage" (building the successor binding), "commit" (before the
  barrier), "gather" (collecting a host's candidates) — so chaos schedules
  (kill / hang / delay / drop) are reproducible from a seed instead of
  sleeps. tests/test_chaos.py is the suite built on them.

`TopNRecommender` is the single-host special case of this tier: it
subclasses the coordinator with all shards colocated in-process, so the
shard assignment, fetch quantization, exclusion filtering, and merge
contract exist exactly once.

Runnable without hardware: `launch/serve.py --hosts N [--replicas R]`
simulates N hosts via `XLA_FLAGS=--xla_force_host_platform_device_count`,
one simulated host per device with its own subscriber thread.
"""
from __future__ import annotations

import collections
import math
import threading
import time
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.serve.ensemble import PosteriorEnsemble
from repro.serve.faults import (
    DEAD,
    HEALTHY,
    Clock,
    FaultDrop,
    FaultPlan,
    HostHealth,
    HostKilled,
    assert_holds,
)
from repro.serve.publish import ChannelSnapshot, PublicationChannel


def shard_bounds(n_items: int, n_shards: int) -> np.ndarray:
    """Item-axis shard assignment shared by every tier layout: n_shards+1
    ascending bounds, balanced to within one row. The single-host
    recommender and the cluster use the same bounds, so their per-shard
    kernel shapes (and jit cache entries) coincide."""
    return np.linspace(0, n_items, n_shards + 1).astype(int)


def _merge_topk(vals: jax.Array, idx: jax.Array, topk: int
                ) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard candidates (B, C) keeping lax.top_k's stable order.

    Shards hold disjoint, ascending index ranges and are concatenated in
    range order, so position-stable top_k again resolves ties to the lowest
    global item index.
    """
    v, pos = jax.lax.top_k(vals, topk)
    return v, jnp.take_along_axis(idx, pos, axis=1)


class _Binding(NamedTuple):
    """One host's immutable serving state for one epoch. Requests capture a
    binding snapshot under the coordinator lock and score entirely against
    it — commits and reshards replace bindings, never mutate them."""

    ensemble: PosteriorEnsemble
    u_replica: jax.Array   # (M, S*K) routed replica of the U scoring table
    v_shard: jax.Array     # (hi-lo, S*K) resident item shard
    lo: int                # global index of the shard's first item
    hi: int


class ShardHost:
    """One serving host: device placement + the live/staged binding pair.

    `stage()` builds the successor binding off the serving path (the
    expensive part: slicing V' and placing both tables on the host's
    device); the coordinator performs the cheap barrier-side flip under
    its lock once a quorum of hosts has staged the same epoch.

    `shard` is the item shard this host owns; with `replicas=R` several
    hosts share one shard (identical bindings — any of them can serve it).

    routed=False is the colocated (single-host recommender) layout: hosts
    share one coordinator-side U table instead of each holding a routed
    device replica, and the coordinator gathers scoring rows once — the
    tier's replica memory cost is only paid where hosts are real.
    """

    def __init__(self, host_id: int, ensemble: PosteriorEnsemble,
                 lo: int, hi: int, *, device=None, interpret: bool | None = None,
                 routed: bool = True, flats=None, shard: int | None = None):
        self.host_id = host_id
        self.shard = host_id if shard is None else shard
        self.device = device
        self.interpret = interpret
        self.routed = routed
        self.live = self.build(ensemble, lo, hi, flats=flats)
        self.staged: _Binding | None = None

    def build(self, ensemble: PosteriorEnsemble, lo: int, hi: int,
              *, flats=None) -> _Binding:
        """Materialise a binding: resident V' rows [lo, hi) + the U table,
        device-placed when this host has a pinned device. `flats` shares
        one scoring_matrices() result across hosts (construction/reshard —
        colocated hosts then alias a single U array); staging computes its
        own, modelling per-host independence on a real pod."""
        u_flat, v_flat = flats if flats is not None else ensemble.scoring_matrices()
        chunk = v_flat[lo:hi]
        if self.device is not None:
            chunk = jax.device_put(chunk, self.device)
            if self.routed:
                u_flat = jax.device_put(u_flat, self.device)
        return _Binding(ensemble, u_flat, chunk, int(lo), int(hi))

    def stage(self, ensemble: PosteriorEnsemble) -> _Binding:
        """Build (but do not serve) the successor for a same-shape publish.
        Same bounds + same shapes -> every kernel invocation lands on the
        jit cache entries the live binding already compiled (zero retrace).
        """
        live = self.live  # snapshot: a concurrent reshard swaps the attr
        if ensemble.shape_key() != live.ensemble.shape_key():
            raise ValueError(
                f"shape changed: {ensemble.shape_key()} vs "
                f"{live.ensemble.shape_key()} — reshard, don't stage"
            )
        return self.build(ensemble, live.lo, live.hi)

    def candidates(self, binding: _Binding, fetch: int, *,
                   rows: jax.Array | None = None,
                   user_ids: np.ndarray | None = None
                   ) -> tuple[jax.Array, jax.Array]:
        """This host's (B, k_eff) candidate list against `binding`'s shard.

        Warm requests route user ids and gather from the local U replica;
        cold/fold-in requests scatter precomputed scoring rows instead.
        k_eff < fetch on a shard smaller than the fetch width (the ragged
        final shard) — the merge pads nothing, it just sees fewer columns.
        """
        if rows is None:
            rows = binding.u_replica[user_ids]
        k_eff = min(fetch, binding.hi - binding.lo)
        vals, idx = ops.topn_scores(rows, binding.v_shard, k_eff,
                                    interpret=self.interpret)
        return vals, idx + np.int32(binding.lo)


class ClusterCoordinator:
    """Scatter/gather top-N over ShardHosts, with a quorum epoch barrier,
    per-shard replication, and health-routed failover.

    The serving API matches TopNRecommender exactly (`recommend`,
    `recommend_rows`, `recommend_factors`, `rebind`) — the frontend and the
    launchers treat the two interchangeably; TopNRecommender *is* this
    class with every host colocated.

    `attach(channel)` subscribes one loop per host to a PublicationChannel:
    publishes fan out to all hosts, each stages its shard independently,
    and `epoch` advances once one healthy replica per shard staged it.

    `replicas=R` gives every item shard R owners (n_shards =
    ceil(n_hosts / R); host i owns shard i mod n_shards). `faults` injects
    a chaos schedule (serve/faults.py); `clock` is the injected time
    source shared with the health tracker.
    """

    # the tier routes user ids and each host gathers from its own U
    # replica; TopNRecommender overrides this to False — colocated shards
    # share one U table and the coordinator gathers rows once
    routed = True

    def __init__(
        self,
        ensemble: PosteriorEnsemble,
        *,
        n_hosts: int = 1,
        replicas: int = 1,
        devices=None,
        mesh=None,
        interpret: bool | None = None,
        channel: PublicationChannel | None = None,
        max_samples: int | None = None,
        faults: FaultPlan | None = None,
        clock: Clock | None = None,
        heartbeat_timeout: float = 5.0,
        max_host_errors: int = 3,
    ):
        if mesh is not None and devices is None:
            from repro.launch.mesh import serving_host_devices
            devices = serving_host_devices(mesh=mesh)
        if devices is not None:
            n_hosts = len(devices)
        self.interpret = interpret
        self.devices = devices
        self.max_samples = max_samples
        self.replicas = max(1, int(replicas))
        n_hosts = max(1, n_hosts)
        self._n_shards = max(1, min(math.ceil(n_hosts / self.replicas),
                                    ensemble.n_items))
        self._layout_hosts = n_hosts
        self.faults = faults
        if clock is None:
            clock = faults.clock if faults is not None else Clock()
        self.clock = clock
        self.health = HostHealth(clock=clock,
                                 heartbeat_timeout=heartbeat_timeout,
                                 max_errors=max_host_errors)
        bounds = shard_bounds(ensemble.n_items, self._n_shards)
        flats = ensemble.scoring_matrices()  # one U/V' build shared by all
        self.hosts = []
        self._owners: list[list[ShardHost]] = [[] for _ in range(self._n_shards)]
        for i in range(n_hosts):
            s = i % self._n_shards
            host = ShardHost(
                i, ensemble, bounds[s], bounds[s + 1],
                device=(devices[i % len(devices)] if devices is not None else None),
                interpret=interpret, routed=self.routed, flats=flats,
                shard=s,
            )
            self.hosts.append(host)
            self._owners[s].append(host)
            self.health.register(i)
        self._next_host_id = n_hosts
        # candidates from hosts pinned to distinct devices need an explicit
        # device->host gather before the merge; colocated shards merge on
        # device with no round trip
        self._multi_device = devices is not None and len(set(devices)) > 1
        self.ensemble = ensemble
        self._epoch = ensemble.epoch
        self._lock = threading.Lock()
        self._epoch_cond = threading.Condition(self._lock)
        self._build_lock = threading.Lock()
        self._pending: tuple[int, PosteriorEnsemble] | None = None  # (seq, ens)
        # barrier-path stats: committed epochs, coordinated reshards, shard
        # reassignments after host loss, gather-path failovers, and
        # publish -> all-shards-fresh latency (the cross-host freshness clock)
        self.commits = 0
        self.reshards = 0
        self.reassignments = 0
        self.gather_failovers = 0
        self.publish_to_fresh_s: collections.deque[float] = collections.deque(maxlen=4096)
        # adopt failures recorded instead of killing a host loop (the
        # frontend keeps the same deque one level up)
        self.adopt_errors: collections.deque[Exception] = collections.deque(maxlen=64)
        self.channel: PublicationChannel | None = None
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        if channel is not None:
            self.attach(channel)

    # -- layout ---------------------------------------------------------
    @property
    def n_hosts(self) -> int:
        with self._lock:
            return len(self.hosts)

    @property
    def n_shards(self) -> int:
        return self._n_shards

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def wait_epoch(self, epoch: int, timeout: float | None = None) -> bool:
        """Block until the committed epoch reaches `epoch`; True on success,
        False on timeout. Condition-based (woken by commits and reshards) —
        the synchronization seam tests use instead of sleep/poll loops."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._epoch < epoch:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                self._epoch_cond.wait(remaining)
            return True

    def _layout_kwargs(self) -> dict:
        return dict(n_hosts=self._layout_hosts, replicas=self.replicas,
                    devices=self.devices, interpret=self.interpret,
                    max_samples=self.max_samples)

    def rebind(self, ensemble: PosteriorEnsemble):
        """A new coordinator serving `ensemble` through this one's compiled
        executables: same shard bounds, same device placement, and — because
        every jit in the scoring path keys on shapes this layout pins — zero
        retraces of the top-N kernel (kernels.bpmf_topn.trace_count is flat
        across a rebind; tested). The publish hot path: a same-shape sample
        publication costs one V' re-shard + buffer swap, not a recompile.

        Self is left untouched and fully servable — callers swap the
        returned instance in atomically (RecommendFrontend holds requests'
        view stable by capturing the old instance under its lock).

        Raises ValueError when the ensemble's (S, M, N, K) changed; the
        caller falls back to a full rebuild (which will retrace).
        """
        with self._lock:
            current_key = self.ensemble.shape_key()
        if ensemble.shape_key() != current_key:
            raise ValueError(
                f"shape changed: {ensemble.shape_key()} vs "
                f"{current_key} — rebuild, don't rebind"
            )
        return type(self)(ensemble, **self._layout_kwargs())

    # -- fault seam -----------------------------------------------------
    def _fault(self, seam: str, host_id: int) -> None:
        """Hook point for the injected chaos schedule. kill marks the host
        dead and raises; hang blocks until released (heartbeats stop —
        the health tracker escalates); delay sleeps on the injected clock;
        drop raises FaultDrop for the caller to swallow."""
        if self.faults is None:
            return
        ev = self.faults.fire(seam, host_id)
        if ev is None:
            return
        if ev.action == "kill":
            self.health.kill(host_id)
            raise HostKilled(f"host {host_id} killed at seam {seam!r}")
        if ev.action == "hang":
            self.faults.hang(host_id)
        elif ev.action == "delay":
            self.clock.sleep(ev.delay_s)
        elif ev.action == "drop":
            raise FaultDrop(f"{seam!r} dropped for host {host_id}")

    # -- serving (scatter/gather with failover routing) ------------------
    def _snapshot(self) -> tuple[int, PosteriorEnsemble,
                                 list[tuple[ShardHost, _Binding]]]:
        """Atomic view for one request: epoch + one (host, binding) pick per
        shard, routed around unhealthy replicas. A commit or reshard that
        lands mid-request replaces bindings but never mutates these — the
        request finishes on one epoch."""
        with self._lock:
            picks = [self._select_shard_locked(s) for s in range(self._n_shards)]
            return self._epoch, self.ensemble, picks

    def _select_shard_locked(self, s: int, exclude: set[int] = frozenset()
                             ) -> tuple[ShardHost, _Binding]:
        """Pick the replica serving shard `s`: the first HEALTHY owner whose
        live binding is at the committed epoch; a SUSPECT owner (stale
        heartbeat) only as a fallback; a freshly rebuilt replica when no
        owner survives at the committed epoch. Caller holds self._lock."""
        assert_holds(self._lock)
        fallback = None
        for h in self._owners[s]:
            if h.host_id in exclude:
                continue
            state = self.health.state(h.host_id)
            if state == DEAD:
                continue
            if h.live.ensemble.epoch != self._epoch:
                continue  # stale replica: routed around until it catches up
            if state == HEALTHY:
                return h, h.live
            if fallback is None:
                fallback = (h, h.live)
        if fallback is not None:
            return fallback
        return self._reassign_locked(s)

    def _reassign_locked(self, s: int) -> tuple[ShardHost, _Binding]:
        """Failover path: every owner of shard `s` is dead (or stale past
        recovery) — rebuild the shard from the *committed* ensemble on a
        surviving host's device. The rebuilt binding is a pure function of
        the same ensemble every committed binding came from, so serving
        stays bit-identical and epoch monotonicity is untouched. When a
        channel is attached the replacement gets its own subscriber loop,
        so it stages future epochs like any other owner."""
        assert_holds(self._lock)
        bounds = shard_bounds(self.ensemble.n_items, self._n_shards)
        donor = next(
            (h for h in self.hosts
             if self.health.serveable(h.host_id) and h.device is not None),
            None,
        )
        host = ShardHost(
            self._next_host_id, self.ensemble, bounds[s], bounds[s + 1],
            device=(donor.device if donor is not None else None),
            interpret=self.interpret, routed=self.routed, shard=s,
        )
        self._next_host_id += 1
        self.hosts.append(host)
        self._owners[s].append(host)
        self.health.register(host.host_id)
        self.reassignments += 1
        if (self.channel is not None and self._threads
                and not self._stop.is_set()):
            t = threading.Thread(
                target=self._host_loop, args=(host,),
                name=f"shard-host-{host.host_id}", daemon=True,
            )
            self._threads.append(t)
            t.start()
        return host, host.live

    def _gather_merge(self, picks: list[tuple[ShardHost, _Binding]],
                      fetch: int, *, rows=None, user_ids=None
                      ) -> tuple[jax.Array, jax.Array]:
        vals, idx = [], []
        for s, (host, binding) in enumerate(picks):
            tried: set[int] = set()
            while True:
                try:
                    self._fault("gather", host.host_id)
                    v, i = host.candidates(binding, fetch, rows=rows,
                                           user_ids=user_ids)
                    break
                except HostKilled:
                    # the host died mid-request: fail over to another
                    # replica of the same shard (identical binding), or a
                    # rebuilt one — the request still completes
                    tried.add(host.host_id)
                except FaultDrop as e:
                    # the response was lost: escalate (repeated drops kill
                    # the host) and re-route this request
                    self.health.error(host.host_id, e)
                    tried.add(host.host_id)
                with self._lock:
                    self.gather_failovers += 1
                    host, binding = self._select_shard_locked(s, exclude=tried)
            vals.append(v)
            idx.append(i)
        if len(vals) == 1:
            return vals[0], idx[0]
        if self._multi_device:
            # the cross-host exchange: each host ships only its (B, k_eff)
            # candidate list to the coordinator — O(shards * fetch) values +
            # indices regardless of catalogue size. device_get is the
            # explicit gather (candidates live on per-host devices); the
            # merge itself runs at the coordinator.
            vals = np.concatenate([np.asarray(v) for v in vals], axis=1)
            idx = np.concatenate([np.asarray(i) for i in idx], axis=1)
            return _merge_topk(jnp.asarray(vals), jnp.asarray(idx), fetch)
        # colocated shards: merge on device, no host round trip
        return _merge_topk(jnp.concatenate(vals, 1), jnp.concatenate(idx, 1),
                           fetch)

    def _topk_rows(self, rows: jax.Array, topk: int
                   ) -> tuple[jax.Array, jax.Array]:
        """Kernel top-k of rows @ V'^T across all item shards."""
        _, ens, picks = self._snapshot()
        return self._gather_merge(picks, min(topk, ens.n_items), rows=rows)

    def _serve(self, topk: int, *, rows=None, user_ids=None,
               exclude: list[np.ndarray] | None = None,
               fetch_hint: int | None = None) -> tuple[np.ndarray, np.ndarray]:
        _, ens, picks = self._snapshot()
        if user_ids is not None and not self.routed:
            # colocated layout: one coordinator-side gather from the shared
            # U table instead of a per-host replica gather
            rows = picks[0][1].u_replica[np.asarray(user_ids, np.int32)]
            user_ids = None
        b = rows.shape[0] if rows is not None else len(user_ids)
        fetch = topk
        if exclude is not None:
            assert len(exclude) == b, (len(exclude), b)
            fetch = topk + max((len(e) for e in exclude), default=0)
        if fetch_hint is not None:
            # honored with or without exclusions: a hint pins the kernel
            # shape even for exclusion-free (e.g. cold-start) batches, whose
            # drifting topk would otherwise thrash the jit cache
            fetch = max(fetch, fetch_hint)
        # round up to a power of two unconditionally: every serving caller
        # (with exclusions, with a hint, or bare) folds onto O(log n_items)
        # kernel shapes instead of one compile per distinct topk
        fetch = 1 << (fetch - 1).bit_length()
        fetch = min(fetch, ens.n_items)
        vals, idx = self._gather_merge(picks, fetch, rows=rows,
                                       user_ids=user_ids)
        vals = np.asarray(vals) + ens.global_mean
        idx = np.asarray(idx)
        if exclude is None:
            return vals[:, :topk], idx[:, :topk]
        out_v = np.full((b, topk), -np.inf, np.float32)
        out_i = np.full((b, topk), -1, np.int32)
        for r in range(b):
            keep = ~np.isin(idx[r], exclude[r])
            kept_v, kept_i = vals[r][keep][:topk], idx[r][keep][:topk]
            out_v[r, : len(kept_v)] = kept_v
            out_i[r, : len(kept_i)] = kept_i
        return out_v, out_i

    def recommend_rows(
        self,
        rows: jax.Array,
        topk: int,
        *,
        exclude: list[np.ndarray] | None = None,
        fetch_hint: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-N for explicit scoring rows (B, S*K), scattered to every host.

        exclude: optional per-row arrays of item ids to drop (seen items).
        fetch_hint: a batch-independent upper bound on topk + exclusions
        (e.g. topk + SeenIndex.max_degree) — pins the candidate count so the
        serving hot path compiles exactly one kernel shape per topk.
        Returns host arrays (values (B, topk), indices (B, topk)); rows with
        fewer than topk candidates left are padded with (-inf, -1).
        """
        return self._serve(topk, rows=rows, exclude=exclude,
                           fetch_hint=fetch_hint)

    def recommend(
        self,
        user_ids: np.ndarray,
        topk: int,
        *,
        seen=None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-N for trained users: only the ids are routed — each host
        gathers the scoring rows from its own U replica. `seen` excludes
        each user's already-rated items; pass a prebuilt SeenIndex on the
        serving hot path (a raw SparseRatings is indexed from scratch on
        every call)."""
        from repro.serve.topn import SeenIndex  # lazy: topn subclasses us

        user_ids = np.asarray(user_ids, np.int32)
        exclude = None
        fetch_hint = None
        if seen is not None:
            if not isinstance(seen, SeenIndex):
                seen = SeenIndex(seen)
            exclude = [seen[int(u)] for u in user_ids]
            fetch_hint = topk + seen.max_degree
        return self._serve(topk, user_ids=user_ids, exclude=exclude,
                           fetch_hint=fetch_hint)

    def recommend_factors(
        self,
        u_draws: jax.Array,
        topk: int,
        *,
        exclude: list[np.ndarray] | None = None,
        fetch_hint: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-N for fold-in users given their per-draw factors (S, B, K).

        fetch_hint pins the candidate count across cold batches (the
        frontend passes topk + batch max degree, power-of-two quantized) so
        varying per-batch rated counts reuse one compiled kernel shape."""
        _, ens, _ = self._snapshot()
        rows = ens.user_scoring_rows(u_draws)
        return self._serve(topk, rows=rows, exclude=exclude,
                           fetch_hint=fetch_hint)

    # -- freshness: channel fan-out + quorum-staged barrier ---------------
    def attach(self, channel: PublicationChannel) -> None:
        """Fan the channel's publishes out to every host: one subscriber
        loop per host (the in-process stand-in for a per-process subscriber
        on a real pod), each staging its own shard as publishes land."""
        if self.channel is not None:
            raise RuntimeError("already attached to a channel")
        self.channel = channel
        with self._lock:
            threads = [
                threading.Thread(
                    target=self._host_loop, args=(host,),
                    name=f"shard-host-{host.host_id}", daemon=True,
                )
                for host in self.hosts
            ]
            self._threads = threads
        for t in threads:
            t.start()

    def close(self) -> None:
        """Stop the per-host subscriber loops (the channel stays usable).
        Hung hosts are released first so their threads can exit."""
        self._stop.set()
        if self.faults is not None:
            self.faults.release()
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=5.0)
        with self._lock:
            self._threads = []

    def _host_loop(self, host: ShardHost) -> None:
        last_staged = self.epoch
        while not self._stop.is_set():
            self.health.beat(host.host_id)
            snap = self.channel.wait(newer_than=last_staged, timeout=0.25)
            if snap is None:
                if self.channel.closed:
                    # drain: a final publish can land between a timed-out
                    # wait and the closed check (same discipline as the
                    # frontend's subscriber loop)
                    final = self.channel.snapshot()
                    if final is not None and final.epoch > last_staged:
                        self._adopt_in_loop(host, final)
                    return
                continue
            last_staged = max(last_staged, snap.epoch)
            if not self._adopt_in_loop(host, snap):
                return  # the host died; its replicas carry the shard

    def _adopt_in_loop(self, host: ShardHost, snap: ChannelSnapshot) -> bool:
        """Adoption with the loop's failure policy: a kill ends the loop
        (False); any other failure is recorded and escalated, and the loop
        lives on to try the next publish — a bad epoch must not freeze the
        host forever, and an unexpected exception must not silently wedge
        the quorum."""
        try:
            self._adopt(host, snap)
            return True
        except HostKilled:
            return False
        except Exception as e:  # noqa: BLE001 — recorded, host escalated
            self.adopt_errors.append(e)
            self.health.error(host.host_id, e)
            return True

    def _ensemble_for(self, snap: ChannelSnapshot) -> PosteriorEnsemble:
        """Stack the snapshot's draw window once per publish; host loops
        share the decoded ensemble, then do their own (per-device) staging
        work outside any lock."""
        with self._build_lock:
            if self._pending is not None and self._pending[0] == snap.seq:
                return self._pending[1]
            draws = snap.draws
            if self.max_samples is not None:
                draws = draws[-self.max_samples:]
            ensemble = PosteriorEnsemble(draws)
            self._pending = (snap.seq, ensemble)
            return ensemble

    def _adopt(self, host: ShardHost, snap: ChannelSnapshot) -> None:
        try:
            self._fault("adopt", host.host_id)
            ensemble = self._ensemble_for(snap)
            # optimistic shape precheck — deliberately lock-free: staging
            # revalidates (ValueError below) and _reshard re-checks epoch
            # and shape under the lock, so a stale read here only costs one
            # detour, never a torn commit
            if ensemble.shape_key() != self.ensemble.shape_key():  # repro-lint: disable=guarded-field (revalidated under lock)
                self._reshard(ensemble)
                return
            self._fault("stage", host.host_id)
            try:
                binding = host.stage(ensemble)  # heavy part: off the lock
            except ValueError:
                # raced a reshard: another host's thread changed the live
                # shapes between our shape check and staging. Re-run as a
                # reshard — _reshard re-checks epoch and shape under the
                # lock, so a reshard that already superseded this publish
                # is a no-op (and the host loop survives either way).
                self._reshard(ensemble)
                return
            # the commit seam fires *before* the lock: a hang here stalls
            # this host's commit, never the coordinator's critical section
            self._fault("commit", host.host_id)
        except FaultDrop:
            return  # the publish never reached this host; it catches up later
        with self._lock:
            if ensemble.epoch <= self._epoch:
                if (ensemble.epoch == self._epoch
                        and host.live.ensemble.epoch < self._epoch):
                    # late replica of the already-committed epoch: flip in
                    # place — byte-identical to every committed binding, so
                    # no second commit and no epoch movement
                    host.live = binding
                    host.staged = None
                return  # lost the race to a newer commit / reshard
            host.staged = binding
            self._commit_locked(snap.t_publish)

    def _commit_locked(self, t_publish: float | None) -> bool:
        """Flip staged hosts iff a quorum — one serveable replica per shard
        — has staged the same strictly-newer epoch (the no-torn-cross-shard
        barrier; dead hosts are excluded, so a lost host cannot wedge it).
        The highest fully-covered epoch wins; hosts staged on an older
        epoch have it discarded (it was never served), hosts staged on a
        newer one keep theirs for the next barrier. Caller holds self._lock.
        """
        assert_holds(self._lock)
        for s in range(self._n_shards):
            # a shard whose owners all died can never clear the barrier:
            # rebuild it on a surviving host now — with a channel attached
            # the replacement subscribes and stages the pending epoch
            if not any(self.health.serveable(h.host_id)
                       for h in self._owners[s]):
                self._reassign_locked(s)
        staged_epochs = sorted(
            {h.staged.ensemble.epoch for h in self.hosts
             if h.staged is not None and self.health.serveable(h.host_id)},
            reverse=True,
        )
        for epoch in staged_epochs:
            if epoch <= self._epoch:
                break
            covered = {
                h.shard for h in self.hosts
                if h.staged is not None and self.health.serveable(h.host_id)
                and h.staged.ensemble.epoch == epoch
            }
            if len(covered) != self._n_shards:
                continue  # some shard's replicas are all mid-flight: hold
            committed = next(
                h.staged.ensemble for h in self.hosts
                if h.staged is not None and h.staged.ensemble.epoch == epoch
            )
            for h in self.hosts:
                if h.staged is None:
                    continue
                if h.staged.ensemble.epoch == epoch:
                    h.live, h.staged = h.staged, None
                elif h.staged.ensemble.epoch < epoch:
                    h.staged = None  # superseded; that epoch is never served
            self._epoch = epoch
            self.ensemble = committed
            self.commits += 1
            if t_publish is not None:
                self.publish_to_fresh_s.append(time.perf_counter() - t_publish)
            self._epoch_cond.notify_all()
            return True
        return False

    def _reshard(self, ensemble: PosteriorEnsemble) -> None:
        """Coordinated shape-change adoption: new shard bounds, every host
        rebuilt in one critical section (on a real pod this is a resharding
        deployment, not a rolling rebind). First host thread to see the new
        shape does the work; the rest observe the advanced epoch and skip.
        In-flight requests hold the old bindings and finish untorn."""
        with self._lock:
            if ensemble.epoch <= self._epoch:
                return
            bounds = shard_bounds(ensemble.n_items, self._n_shards)
            # a reshard IS the stop-the-world path: every host must flip to
            # the new shard bounds in one critical section or a request
            # could gather torn cross-shard state. The device build happens
            # under the lock by design (rare: shape changes only).
            flats = ensemble.scoring_matrices()  # repro-lint: disable=sync-under-lock (intentional stop-the-world)
            for h in self.hosts:
                h.live = h.build(ensemble, bounds[h.shard],
                                 bounds[h.shard + 1], flats=flats)
                h.staged = None
            self._epoch = ensemble.epoch
            self.ensemble = ensemble
            self.reshards += 1
            self._epoch_cond.notify_all()

    # -- observability ---------------------------------------------------
    def freshness_percentiles(self) -> dict[str, float]:
        """p50/max publish -> all-shards-fresh latency (seconds)."""
        # snapshot under the lock: a commit appending to the deque while
        # np.asarray iterates it would raise "deque mutated during iteration"
        with self._lock:
            lat = list(self.publish_to_fresh_s)
        if not lat:
            return {"p50": float("nan"), "max": float("nan")}
        arr = np.asarray(lat)
        return {"p50": float(np.percentile(arr, 50)), "max": float(arr.max())}

    def stats(self) -> dict:
        """One observability snapshot: committed epoch, per-host health and
        binding state, and per-shard commit-quorum status (who owns it, who
        is serveable, who has staged what). The failure-mode dashboard the
        chaos suite and benchmarks read."""
        health = self.health.snapshot()
        with self._lock:
            hosts = {}
            for h in self.hosts:
                rec = dict(health.get(
                    h.host_id,
                    {"state": HEALTHY, "errors": 0, "last_beat_age_s": None},
                ))
                rec["shard"] = h.shard
                rec["live_epoch"] = h.live.ensemble.epoch
                rec["staged_epoch"] = (None if h.staged is None
                                       else h.staged.ensemble.epoch)
                hosts[h.host_id] = rec
            quorum = {}
            for s in range(self._n_shards):
                owners = self._owners[s]
                quorum[s] = {
                    "owners": [h.host_id for h in owners],
                    "serveable": [h.host_id for h in owners
                                  if health.get(h.host_id, {}).get("state")
                                  != DEAD],
                    "staged": {h.host_id: h.staged.ensemble.epoch
                               for h in owners if h.staged is not None},
                }
            return {
                "epoch": self._epoch,
                "replicas": self.replicas,
                "n_shards": self._n_shards,
                "n_hosts": len(self.hosts),
                "commits": self.commits,
                "reshards": self.reshards,
                "reassignments": self.reassignments,
                "gather_failovers": self.gather_failovers,
                "adopt_errors": len(self.adopt_errors),
                "hosts": hosts,
                "quorum": quorum,
            }
