"""BPMF posterior-predictive serving: the path from retained Gibbs samples
to live recommendations.

Training (core/gibbs.py) retains post-burn-in draws in a checkpoint
SampleStore; this package turns them into a service:

  ensemble.py   PosteriorEnsemble — stacked (U_s, V_s, hyper_s) draws,
                posterior-mean scores + predictive variance per (user, item)
  cluster.py    the multi-host serving tier — ShardHost (resident V' item
                shard + routed U replica) and ClusterCoordinator (bounded
                O(shards * topk) candidate gather/merge, channel fan-out,
                quorum epoch barrier, per-shard replication + failover)
  faults.py     deterministic chaos: FaultPlan (seeded kill/hang/delay/drop
                schedules at named seams), injectable clocks, and the
                HostHealth heartbeat/error tracker the tier routes around
  topn.py       TopNRecommender — batched top-N over the catalogue, backed
                by the Pallas streaming top-k kernel (kernels/bpmf_topn.py);
                the single-host special case of the cluster tier
  foldin.py     cold-start fold-in — batched (S*B) conditional posteriors
                for users unseen at train time, from their ratings alone;
                FoldInPlanCache keeps the solve shapes (and compiled
                executables) stable across request batches
  publish.py    PublicationChannel — push-based, double-buffered trainer ->
                server hand-off of retained draws; no disk poll in the loop
  frontend.py   RecommendFrontend — request micro-batching + an item-factor
                cache keyed by sample epoch, sharded over launch/mesh.py,
                refreshed by channel subscription (push) or store poll
"""
from repro.serve.cluster import ClusterCoordinator, ShardHost
from repro.serve.ensemble import PosteriorEnsemble
from repro.serve.faults import (
    Clock,
    FaultEvent,
    FaultPlan,
    HostHealth,
    StepClock,
    assert_holds,
    debug_locks_enabled,
)
from repro.serve.foldin import FoldInPlanCache, fold_in, fold_in_loop
from repro.serve.frontend import RecommendFrontend, RecommendResult
from repro.serve.publish import ChannelSnapshot, PublicationChannel
from repro.serve.topn import SeenIndex, TopNRecommender

__all__ = [
    "ChannelSnapshot",
    "Clock",
    "ClusterCoordinator",
    "FaultEvent",
    "FaultPlan",
    "FoldInPlanCache",
    "HostHealth",
    "ShardHost",
    "StepClock",
    "PosteriorEnsemble",
    "PublicationChannel",
    "fold_in",
    "fold_in_loop",
    "RecommendFrontend",
    "RecommendResult",
    "SeenIndex",
    "TopNRecommender",
    "assert_holds",
    "debug_locks_enabled",
]
