"""Cold-start fold-in: a one-shot conditional posterior for unseen users.

A user who arrives after training has no row in any retained U_s, but the
BPMF model still defines their conditional posterior given each draw's item
factors and user hyperparameters:

    Lambda_b^s = Lambda_u^s + alpha * sum_j v_j^s v_j^s^T   (j rated by b)
    rhs_b^s    = Lambda_u^s mu_u^s + alpha * sum_j r_bj v_j^s
    u_b^s      ~ N((Lambda_b^s)^-1 rhs_b^s, (Lambda_b^s)^-1)

— exactly the per-item update of the training sweep (posterior propagation
in the sense of Qin et al. 2017: the retained draws carry the training
posterior, and the new user's factor is inferred conditionally without
touching the chain). The implementation therefore *reuses* the training
machinery verbatim: ratings are bucketed with core.buckets.plan_buckets,
sufficient statistics come from core.gibbs.bucket_stats, and the draw (or
posterior mean, z = 0) from core.gibbs.sample_mvn_precision. One fold-in
per retained draw yields an (S, B, K) factor ensemble that the scorer and
recommender treat identically to trained users.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import plan_buckets
from repro.core.gibbs import bucket_stats, device_plan, sample_mvn_precision
from repro.data.sparse import SparseRatings, csr_from_coo
from repro.serve.ensemble import PosteriorEnsemble


def _ratings_stats(v: jax.Array, buckets, n_new: int,
                   use_kernel: bool) -> tuple[jax.Array, jax.Array]:
    """Accumulate (sum v v^T, sum r v) per new user via the bucket plan."""
    k = v.shape[-1]
    prec = jnp.zeros((n_new, k, k), v.dtype)
    rhs = jnp.zeros((n_new, k), v.dtype)
    for b in buckets:
        p, r = bucket_stats(v, b, use_kernel=use_kernel)
        prec = prec.at[b.seg_item_ids].add(p)
        rhs = rhs.at[b.seg_item_ids].add(r)
    return prec, rhs


def fold_in(
    key: jax.Array | None,
    ratings: SparseRatings,
    ensemble: PosteriorEnsemble,
    *,
    sample: bool = True,
    widths: tuple[int, ...] = (8, 32, 128, 512),
    use_kernel: bool = False,
) -> jax.Array:
    """Factor posteriors for a batch of new users from their ratings alone.

    ratings: (n_new, n_items) sparse — row b holds new user b's ratings on
    the *training* item index space, on the raw rating scale (the training
    global mean is subtracted here). Returns (S, n_new, K) per-draw factors:
    conditional draws when sample=True, conditional posterior means (z = 0,
    key may be None) when False. Feed them to
    PosteriorEnsemble.score_factors or TopNRecommender.recommend_factors.
    """
    n_new, n_items = ratings.shape
    if n_items != ensemble.n_items:
        raise ValueError(
            f"ratings cover {n_items} items, ensemble has {ensemble.n_items}"
        )
    # out-of-range item ids would otherwise be silently clamped by the gather
    ratings.validate()
    centered = (ratings.vals - ensemble.global_mean).astype(np.float32)
    indptr, idx, vals = csr_from_coo(ratings.rows, ratings.cols, centered, n_new)
    plan = plan_buckets(indptr, idx, vals, n_new, n_items, widths)
    buckets = device_plan(plan)
    alpha = ensemble.alpha

    out = []
    for s, smp in enumerate(ensemble.samples):
        v = ensemble.v[s]
        lam = jnp.asarray(smp.hyper_u_lam)
        mu = jnp.asarray(smp.hyper_u_mu)
        prec, rhs = _ratings_stats(v, buckets, n_new, use_kernel)
        prec = lam[None] + alpha * prec
        rhs = (lam @ mu)[None] + alpha * rhs
        if sample:
            key, sub = jax.random.split(key)
        else:
            sub = None  # posterior mean: the z = 0 limb of the same solve
        out.append(sample_mvn_precision(sub, prec, rhs, use_kernel=use_kernel))
    return jnp.stack(out)  # (S, n_new, K)
