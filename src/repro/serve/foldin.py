"""Cold-start fold-in: batched conditional posteriors for unseen users.

A user who arrives after training has no row in any retained U_s, but the
BPMF model still defines their conditional posterior given each draw's item
factors and user hyperparameters:

    Lambda_b^s = Lambda_u^s + alpha * sum_j v_j^s v_j^s^T   (j rated by b)
    rhs_b^s    = Lambda_u^s mu_u^s + alpha * sum_j r_bj v_j^s
    u_b^s      ~ N((Lambda_b^s)^-1 rhs_b^s, (Lambda_b^s)^-1)

— exactly the per-item update of the training sweep (posterior propagation
in the sense of Qin et al. 2017: the retained draws carry the training
posterior, and the new user's factor is inferred conditionally without
touching the chain).

The serving formulation is *batched over draws and users at once*: the
bucket plan (gather indices, ratings, mask) is draw-independent, so one
gather + contraction per bucket covers all S draws, the per-draw hypers are
broadcast from the ensemble's stacked (S, K, K) / (S, K) device arrays, and
the S*B conditional systems are factored and solved in one
`sample_mvn_precision` call over an (S, B, K, K) precision stack — one
compiled executable per plan shape instead of a Python loop of S separate
solves. `fold_in_loop` keeps the original per-draw loop as the reference
implementation (equivalence-tested; the fused path matches it bit-for-bit
through the statistics and to fp32 rounding through the batched triangular
solves).

`FoldInPlanCache` removes the other steady-state cost: recompiling. A
batch's bucket plan is still built per request (contents are new data),
but its *shapes* are keyed on a quantized rating-count profile — the
(width, rows, segments) shape of the plan with every count rounded up to
a power of two, plus the padded batch size — so repeated cold-start
batches with similar degree shapes map onto one set of padded array
shapes and therefore reuse every compiled executable (`trace_count()`
stays flat; tested). Padding is exact: mask-zero rows and zero-sum
segments contribute nothing.
"""
from __future__ import annotations

import functools
import threading
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import (
    DEFAULT_WIDTHS,
    balanced_widths,
    pad_bucket,
    plan_buckets,
)
from repro.core.gibbs import (
    DeviceBucket,
    bucket_stats,
    device_plan,
    sample_mvn_precision,
)
from repro.data.sparse import SparseRatings, csr_from_coo
from repro.serve.ensemble import PosteriorEnsemble

_trace_count = 0


def trace_count() -> int:
    """How many times the fused fold-in solve has been traced (compiled).

    Same discipline as kernels.bpmf_topn.trace_count: the counter bumps at
    trace time only, so a flat count across repeated cold-start batches
    proves the plan cache mapped them onto already-compiled executables.
    """
    return _trace_count


class FoldInPlanCache:
    """Quantized plan schemas for cold-start batches, keyed on rating counts.

    The expensive parts of serving a cold batch are shape-dependent: every
    distinct set of bucket array shapes costs a fresh trace + compile of the
    fused solve. Raw batches almost never repeat shapes exactly — degree
    profiles drift request to request — so the cache quantizes: a batch's
    rating-count profile (per-bucket rows and segments, and the batch size)
    is rounded up to powers of two, and batches that land on the same
    quantized schema share one set of padded shapes and therefore every
    compiled executable.

    An entry is the immutable quantized schema itself (per-batch array
    *contents* are new data and are rebuilt each request); what the hit path
    buys is shape stability — `trace_count()` flat across same-profile
    batches — plus the hit/miss accounting serving dashboards want. Entries
    are LRU-bounded. Thread-safe: the frontend may flush from several
    threads.

    The cache is ensemble-shape-agnostic except for the item-axis width
    (item ids must index the same catalogue), so same-shape publishes keep
    every entry; `RecommendFrontend` clears it only when the ensemble's
    shapes actually change.
    """

    def __init__(
        self,
        widths: tuple[int, ...] = DEFAULT_WIDTHS,
        *,
        max_entries: int = 64,
        quantum: int = 8,
    ):
        self.widths = tuple(sorted(widths))
        self.quantum = int(quantum)
        self.max_entries = int(max_entries)
        self.hits = 0
        self.misses = 0
        self._entries: OrderedDict[tuple, None] = OrderedDict()
        self._lock = threading.Lock()

    @classmethod
    def balanced(
        cls,
        degrees: np.ndarray,
        *,
        max_buckets: int = 8,
        lane: int = 1,
        max_width: int = 512,
        max_entries: int = 64,
        quantum: int = 8,
    ) -> "FoldInPlanCache":
        """A cache whose width ladder is fit ONCE to a reference degree
        profile (typically the training users') by the balanced planner,
        then frozen. Per-request plans bin into these fixed — possibly
        non-pow2 — widths, so quantized-profile keys stay trace-flat
        exactly as with the pow2 ladder, while the padding tracks the
        workload's real degree shape. The ladder must not be refit per
        batch: that would make the width axis of the schema key
        data-dependent and retrace on every profile drift.
        """
        widths = balanced_widths(
            np.asarray(degrees), max_buckets=max_buckets,
            lane=lane, max_width=max_width,
        )
        return cls(widths, max_entries=max_entries, quantum=quantum)

    @staticmethod
    def _quantize(n: int, quantum: int) -> int:
        """Smallest power of two >= n, floored at `quantum` (tile-friendly)."""
        return max(quantum, 1 << (max(int(n), 1) - 1).bit_length())

    def schema(
        self,
        profile: tuple[tuple[int, int, int], ...],
        n_new: int,
        n_items: int,
    ) -> tuple[int, tuple[tuple[int, int, int], ...]]:
        """Quantized (padded_batch, ((width, rows, segments), ...)) for a
        batch whose exact plan shape is `profile` — the (width, rows,
        segments) triples of the plan's buckets, in bucket order, so the
        quantized targets stay aligned with the plan by construction.
        Records hit/miss."""
        q = self.quantum
        padded_batch = self._quantize(n_new, q)
        buckets = tuple(
            (w, self._quantize(rows, q), self._quantize(segs, q))
            for w, rows, segs in profile
        )
        key = (n_items, padded_batch, buckets)
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
            else:
                self._entries[key] = None
                self.misses += 1
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
        return padded_batch, buckets

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "entries": len(self._entries),
            }


@functools.partial(
    jax.jit, static_argnames=("plan_key", "n_new", "engine")
)
def _fused_fold_in(
    v: jax.Array,           # (S, N, K) stacked item factors
    lam: jax.Array,         # (S, K, K) stacked user hyper precisions
    mu: jax.Array,          # (S, K)    stacked user hyper means
    alpha: float,
    arrays: tuple,          # per bucket: (indices, values, mask, seg_ids, seg_item_ids)
    z: jax.Array | None,    # (S, n_new, K) pre-drawn noise, or None for the mean
    *,
    plan_key: tuple,        # per bucket: (width, n_segments, identity) — static
    n_new: int,
    engine: str,
) -> jax.Array:
    """One batched (S*B) assembly + Cholesky solve for the whole fold-in."""
    global _trace_count
    _trace_count += 1  # executes at trace time only: one bump per jit miss
    s, _, k = v.shape
    prec = jnp.zeros((s, n_new, k, k), v.dtype)
    rhs = jnp.zeros((s, n_new, k), v.dtype)
    for (width, n_segments, identity), (idx, vals, mask, seg_ids, seg_item_ids) in zip(
        plan_key, arrays
    ):
        b = DeviceBucket(
            width=width, indices=idx, values=vals, mask=mask,
            seg_ids=seg_ids, n_segments=n_segments, seg_item_ids=seg_item_ids,
            identity_segments=identity,
        )
        # stacked-draw bucket stats: the fused engine rides the same
        # gather-syrk kernel as the training sweep (leading S axis)
        p, r = bucket_stats(v, b, engine=engine)  # (S, segs, ...)
        prec = prec.at[:, seg_item_ids].add(p)
        rhs = rhs.at[:, seg_item_ids].add(r)
    prec = lam[:, None] + alpha * prec
    rhs = jnp.einsum("skl,sl->sk", lam, mu)[:, None] + alpha * rhs
    solver = "kernel" if engine == "kernel" else "subst"
    return sample_mvn_precision(None, prec, rhs, z=z, solver=solver)


def _check_fold_in_args(
    key: jax.Array | None, ratings: SparseRatings,
    ensemble: PosteriorEnsemble, sample: bool,
) -> None:
    if sample and key is None:
        raise ValueError(
            "fold_in(sample=True) draws conditional samples and needs a PRNG "
            "key; pass a key, or sample=False for the deterministic "
            "posterior mean"
        )
    n_items = ratings.shape[1]
    if n_items != ensemble.n_items:
        raise ValueError(
            f"ratings cover {n_items} items, ensemble has {ensemble.n_items}"
        )
    # out-of-range item ids would otherwise be silently clamped by the gather
    ratings.validate()


def _presample_noise(
    key: jax.Array, s: int, n_new: int, k: int
) -> jax.Array:
    """(S, n_new, K) noise via the per-draw key-split sequence of the
    original loop — fused and looped sampling consume identical bits."""
    zs = []
    for _ in range(s):
        key, sub = jax.random.split(key)
        zs.append(jax.random.normal(sub, (n_new, k), jnp.float32))
    return jnp.stack(zs)


def fold_in(
    key: jax.Array | None,
    ratings: SparseRatings,
    ensemble: PosteriorEnsemble,
    *,
    sample: bool = True,
    widths: tuple[int, ...] = DEFAULT_WIDTHS,
    use_kernel: bool = False,
    engine: str | None = None,
    plan_cache: FoldInPlanCache | None = None,
) -> jax.Array:
    """Factor posteriors for a batch of new users from their ratings alone.

    ratings: (n_new, n_items) sparse — row b holds new user b's ratings on
    the *training* item index space, on the raw rating scale (the training
    global mean is subtracted here). Returns (S, n_new, K) per-draw factors:
    conditional draws when sample=True (a PRNG key is required), conditional
    posterior means (z = 0, key may be None) when False. Feed them to
    PosteriorEnsemble.score_factors or TopNRecommender.recommend_factors.

    The whole batch is solved fused: rating statistics are computed once per
    bucket for all S draws, broadcast against the ensemble's stacked user
    hypers, and the S*n_new conditional systems share one batched Cholesky
    solve. A user with zero ratings gets their hyper-prior posterior
    N(mu_u^s, (Lambda_u^s)^-1) — the zero-statistics limb of the same solve.

    plan_cache: a FoldInPlanCache quantizes the plan shapes so repeated
    batches with similar rating-count profiles reuse compiled executables
    (the serving hot path; `widths` is taken from the cache). Without one,
    the plan is built at exact shapes (bit-parity with `fold_in_loop`).

    engine: sweep engine for the bucket statistics and solve
    (core.gibbs.ENGINES) — "fused" routes the stacked-draw statistics
    through the same gather-syrk kernel as the training sweep.
    """
    from repro.core.gibbs import resolve_engine

    engine = resolve_engine(engine, use_kernel)
    _check_fold_in_args(key, ratings, ensemble, sample)
    n_new = ratings.shape[0]
    s, k = ensemble.n_samples, ensemble.k

    z = _presample_noise(key, s, n_new, k) if sample else None

    if ratings.nnz == 0:
        # zero-rating batch: nothing to plan — the prior-only solve below.
        # Still quantize the batch axis when a cache is attached, or every
        # distinct empty-batch size would trace a fresh executable.
        arrays: tuple = ()
        plan_key: tuple = ()
        padded_batch = (
            plan_cache._quantize(n_new, plan_cache.quantum)
            if plan_cache is not None else n_new
        )
    else:
        centered = (ratings.vals - ensemble.global_mean).astype(np.float32)
        indptr, idx, vals = csr_from_coo(
            ratings.rows, ratings.cols, centered, n_new
        )
        if plan_cache is not None:
            widths = plan_cache.widths
        plan = plan_buckets(
            indptr, idx, vals, n_new, ensemble.n_items, widths
        )
        buckets = plan.buckets
        if plan_cache is not None:
            padded_batch, targets = plan_cache.schema(
                tuple((b.width, b.rows, b.n_segments) for b in buckets),
                n_new, ensemble.n_items,
            )
            buckets = tuple(
                pad_bucket(b, rows, segs)
                for b, (_, rows, segs) in zip(buckets, targets)
            )
        else:
            padded_batch = n_new
        db = device_plan(buckets)
        # under a plan cache the static key must be a function of the
        # quantized SCHEMA alone: identity_segments is computed from the
        # padded seg_ids contents, which can differ between two batches
        # that share a schema (e.g. padding by one row makes seg_ids
        # exactly arange) — letting it through would retrace on a cache
        # hit and break the trace-flat contract
        plan_key = tuple(
            (b.width, b.n_segments,
             False if plan_cache is not None else b.identity_segments)
            for b in db
        )
        arrays = tuple(
            (b.indices, b.values, b.mask, b.seg_ids, b.seg_item_ids)
            for b in db
        )

    if z is not None and padded_batch != n_new:
        z = jnp.concatenate(
            [z, jnp.zeros((s, padded_batch - n_new, k), z.dtype)], axis=1
        )

    out = _fused_fold_in(
        ensemble.v, ensemble.hyper_u_lam, ensemble.hyper_u_mu,
        ensemble.alpha, arrays, z,
        plan_key=plan_key, n_new=padded_batch, engine=engine,
    )
    return out[:, :n_new]  # drop batch padding (padded rows solve the prior)


def _ratings_stats(v: jax.Array, buckets, n_new: int,
                   use_kernel: bool) -> tuple[jax.Array, jax.Array]:
    """Accumulate (sum v v^T, sum r v) per new user via the bucket plan."""
    k = v.shape[-1]
    prec = jnp.zeros((n_new, k, k), v.dtype)
    rhs = jnp.zeros((n_new, k), v.dtype)
    for b in buckets:
        p, r = bucket_stats(v, b, use_kernel=use_kernel)
        prec = prec.at[b.seg_item_ids].add(p)
        rhs = rhs.at[b.seg_item_ids].add(r)
    return prec, rhs


def fold_in_loop(
    key: jax.Array | None,
    ratings: SparseRatings,
    ensemble: PosteriorEnsemble,
    *,
    sample: bool = True,
    widths: tuple[int, ...] = DEFAULT_WIDTHS,
    use_kernel: bool = False,
) -> jax.Array:
    """The original per-retained-draw fold-in: S separate solves in a Python
    loop. Kept as the reference implementation the fused `fold_in` is
    equivalence-tested against, and as the baseline
    `benchmarks/foldin_latency.py` measures the fusion speedup from. Not the
    serving path.
    """
    _check_fold_in_args(key, ratings, ensemble, sample)
    n_new = ratings.shape[0]
    centered = (ratings.vals - ensemble.global_mean).astype(np.float32)
    indptr, idx, vals = csr_from_coo(ratings.rows, ratings.cols, centered, n_new)
    plan = plan_buckets(indptr, idx, vals, n_new, ensemble.n_items, widths)
    buckets = device_plan(plan)
    alpha = ensemble.alpha

    out = []
    for s, smp in enumerate(ensemble.samples):
        v = ensemble.v[s]
        lam = jnp.asarray(smp.hyper_u_lam)
        mu = jnp.asarray(smp.hyper_u_mu)
        prec, rhs = _ratings_stats(v, buckets, n_new, use_kernel)
        prec = lam[None] + alpha * prec
        rhs = (lam @ mu)[None] + alpha * rhs
        if sample:
            key, sub = jax.random.split(key)
        else:
            sub = None  # posterior mean: the z = 0 limb of the same solve
        out.append(sample_mvn_precision(sub, prec, rhs, use_kernel=use_kernel))
    return jnp.stack(out)  # (S, n_new, K)
