"""Request-batching serving front end for BPMF recommendations.

Serving traffic arrives as single-user requests; the kernel wants batches.
The frontend queues requests (thread-safe), then `flush()` drains the queue
in micro-batches of up to `max_batch`, one kernel invocation per batch —
the same amortisation the LM serving path gets from batched decode steps.
Cold-start requests (raw ratings instead of a user id) ride the same queue:
each flush folds them in against the current ensemble and scores them
through the same top-N kernel as trained users.

The item-factor cache is keyed by *sample epoch* — the newest retained step
in the SampleStore. `refresh()` compares epochs and only then rebuilds the
ensemble + re-shards V' across the mesh devices; between training publishes
(or when no trainer is running) serving never touches the checkpoint
directory again. The previous epoch's recommender is kept until the swap
completes, so refresh is safe to call from a poller thread while requests
are in flight.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np
import jax

from repro.checkpoint.samples import SampleStore
from repro.data.sparse import SparseRatings
from repro.serve.ensemble import PosteriorEnsemble
from repro.serve.foldin import fold_in
from repro.serve.topn import SeenIndex, TopNRecommender


@dataclass(frozen=True)
class RecommendResult:
    ticket: int
    items: np.ndarray    # (topk,) int32, -1 padded
    scores: np.ndarray   # (topk,) f32 posterior-mean scores
    epoch: int           # sample epoch that served the request
    latency_s: float     # enqueue -> result


@dataclass
class _Pending:
    ticket: int
    topk: int
    t_enqueue: float
    user_id: int | None = None
    item_ids: np.ndarray | None = None   # cold-start payload
    ratings: np.ndarray | None = None


class RecommendFrontend:
    def __init__(
        self,
        sample_root: str | Path,
        *,
        seen: SparseRatings | None = None,
        max_batch: int = 32,
        max_samples: int | None = None,
        devices=None,
        mesh=None,
        interpret: bool | None = None,
    ):
        """seen: training ratings used to exclude already-rated items.
        devices / mesh: where to shard the item factors — a mesh contributes
        its "data"-axis devices (launch/mesh.py), default all local devices.
        """
        self.store = SampleStore(sample_root)
        self.seen = SeenIndex(seen) if seen is not None else None
        self.max_batch = max_batch
        self.max_samples = max_samples
        if mesh is not None and devices is None:
            devices = list(mesh.devices.flatten())
        self.devices = devices if devices is not None else jax.devices()
        self.interpret = interpret
        self._lock = threading.Lock()
        self._queue: list[_Pending] = []
        self._ticket = 0
        self._epoch: int | None = None
        self._recommender: TopNRecommender | None = None
        # bounded: a long-lived server must not grow one float per request
        self.latencies_s: collections.deque[float] = collections.deque(maxlen=65536)
        self.refresh()

    # ------------------------------------------------------------------
    @property
    def epoch(self) -> int:
        assert self._epoch is not None
        return self._epoch

    @property
    def ensemble(self) -> PosteriorEnsemble:
        return self._recommender.ensemble

    def refresh(self) -> bool:
        """Adopt the newest sample epoch; True if the cache was rebuilt."""
        newest = self.store.epoch()
        if newest is None:
            raise FileNotFoundError(f"no retained samples in {self.store.store.root}")
        if newest == self._epoch:
            return False
        try:
            ensemble = PosteriorEnsemble.load(
                self.store.store.root, max_samples=self.max_samples
            )
        except (FileNotFoundError, ValueError):
            # lost the race against the trainer's prune wholesale — keep
            # serving the cached epoch and let the next poll retry
            if self._recommender is not None:
                return False
            raise
        recommender = TopNRecommender(
            ensemble, devices=self.devices, interpret=self.interpret
        )
        with self._lock:
            self._epoch = ensemble.epoch
            self._recommender = recommender
        return True

    # ------------------------------------------------------------------
    def submit(self, user_id: int, topk: int = 10) -> int:
        """Queue a trained-user request; returns a ticket matched by flush()."""
        n_users = self.ensemble.n_users
        if not 0 <= user_id < n_users:
            # reject at enqueue (like submit_ratings): an out-of-range id
            # would otherwise clamp to another user's recommendations, or
            # crash the whole micro-batch in the seen-item lookup
            raise ValueError(f"user id must be in [0, {n_users}), got {user_id}")
        with self._lock:
            self._ticket += 1
            self._queue.append(_Pending(
                ticket=self._ticket, topk=topk, t_enqueue=time.perf_counter(),
                user_id=int(user_id),
            ))
            return self._ticket

    def submit_ratings(
        self, item_ids: np.ndarray, ratings: np.ndarray, topk: int = 10
    ) -> int:
        """Queue a cold-start request: the user's ratings, not a user id."""
        item_ids = np.asarray(item_ids, np.int32)
        ratings = np.asarray(ratings, np.float32)
        assert item_ids.shape == ratings.shape
        n_items = self.ensemble.n_items
        if item_ids.size and not (0 <= item_ids.min() and item_ids.max() < n_items):
            # reject here, not at flush: one bad request must not poison the
            # whole micro-batch it would be folded in with
            raise ValueError(
                f"item ids must be in [0, {n_items}), got "
                f"[{item_ids.min()}, {item_ids.max()}]"
            )
        with self._lock:
            self._ticket += 1
            self._queue.append(_Pending(
                ticket=self._ticket, topk=topk, t_enqueue=time.perf_counter(),
                item_ids=item_ids, ratings=ratings,
            ))
            return self._ticket

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------------
    def flush(self) -> list[RecommendResult]:
        """Drain the queue in micro-batches; returns results ticket-matched."""
        with self._lock:
            batch_all, self._queue = self._queue, []
            rec = self._recommender
            epoch = self._epoch
        results: list[RecommendResult] = []
        for lo in range(0, len(batch_all), self.max_batch):
            results.extend(self._run_batch(batch_all[lo: lo + self.max_batch],
                                           rec, epoch))
        self.latencies_s.extend(r.latency_s for r in results)
        return results

    def _run_batch(self, batch: list[_Pending], rec: TopNRecommender,
                   epoch: int) -> list[RecommendResult]:
        if not batch:
            return []
        topk = max(p.topk for p in batch)
        warm = [p for p in batch if p.user_id is not None]
        cold = [p for p in batch if p.user_id is None]
        out: dict[int, tuple[np.ndarray, np.ndarray]] = {}

        if warm:
            ids = np.asarray([p.user_id for p in warm], np.int32)
            vals, idx = rec.recommend(ids, topk, seen=self.seen)
            for r, p in enumerate(warm):
                out[p.ticket] = (vals[r], idx[r])

        if cold:
            rows = np.concatenate([
                np.full(len(p.item_ids), r, np.int32) for r, p in enumerate(cold)
            ])
            cols = np.concatenate([p.item_ids for p in cold])
            vals_r = np.concatenate([p.ratings for p in cold])
            ratings = SparseRatings(
                rows=rows, cols=cols, vals=vals_r,
                shape=(len(cold), rec.ensemble.n_items),
            )
            # deterministic fold-in (conditional posterior means): serving
            # the same ratings twice must return the same recommendations
            u_draws = fold_in(None, ratings, rec.ensemble, sample=False)
            vals, idx = rec.recommend_factors(
                u_draws, topk, exclude=[p.item_ids for p in cold]
            )
            for r, p in enumerate(cold):
                out[p.ticket] = (vals[r], idx[r])

        t_done = time.perf_counter()
        return [
            RecommendResult(
                ticket=p.ticket,
                items=out[p.ticket][1][: p.topk],
                scores=out[p.ticket][0][: p.topk],
                epoch=epoch,
                latency_s=t_done - p.t_enqueue,
            )
            for p in batch
        ]

    # ------------------------------------------------------------------
    def latency_percentiles(self) -> dict[str, float]:
        """p50/p99 over every request served so far (seconds)."""
        if not self.latencies_s:
            return {"p50": float("nan"), "p99": float("nan")}
        lat = np.asarray(self.latencies_s)
        return {"p50": float(np.percentile(lat, 50)),
                "p99": float(np.percentile(lat, 99))}
