"""Request-batching serving front end for BPMF recommendations.

Serving traffic arrives as single-user requests; the kernel wants batches.
The frontend queues requests (thread-safe), then `flush()` drains the queue
in micro-batches of up to `max_batch`, one kernel invocation per batch —
the same amortisation the LM serving path gets from batched decode steps.
Cold-start requests (raw ratings instead of a user id) ride the same queue:
each flush folds them in against the current ensemble and scores them
through the same top-N kernel as trained users.

The item-factor cache is keyed by *sample epoch* — the newest retained
Gibbs step — and is refreshed on one of two paths:

* Push (preferred, trainer co-running): the frontend subscribes to a
  `serve.publish.PublicationChannel`; each retained draw the trainer
  publishes wakes the subscriber thread, which stacks the window into a
  PosteriorEnsemble *in memory* and swaps it in without touching disk.
  When the ensemble shapes (S, N, K) are unchanged — the steady state —
  the swap rebinds the existing recommender's shard layout and reuses
  every compiled top-N executable: a publish costs a buffer swap, not a
  recompile.
* Poll (fallback, no trainer attached): `refresh()` compares the
  SampleStore's newest step against the cached epoch and only on change
  reloads the ensemble from disk and re-shards V' across the mesh devices.

Both paths swap atomically and double-buffered: the previous epoch's
recommender is kept intact until the successor is fully built, and
`flush()` captures (recommender, epoch) under the lock, so in-flight
requests always score against one consistent ensemble — never a torn mix
of old and new factors — whichever thread published.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np
import jax

from repro.checkpoint.samples import SampleStore
from repro.data.sparse import SparseRatings
from repro.serve.cluster import ClusterCoordinator
from repro.serve.ensemble import PosteriorEnsemble
from repro.serve.foldin import FoldInPlanCache, fold_in
from repro.serve.publish import ChannelSnapshot, PublicationChannel
from repro.serve.topn import SeenIndex, TopNRecommender


@dataclass(frozen=True)
class RecommendResult:
    ticket: int
    items: np.ndarray    # (topk,) int32, -1 padded
    scores: np.ndarray   # (topk,) f32 posterior-mean scores
    epoch: int           # sample epoch that served the request
    latency_s: float     # enqueue -> result


@dataclass
class _Pending:
    ticket: int
    topk: int
    t_enqueue: float
    user_id: int | None = None
    item_ids: np.ndarray | None = None   # cold-start payload
    ratings: np.ndarray | None = None


class RecommendFrontend:
    def __init__(
        self,
        sample_root: str | Path | None = None,
        *,
        channel: PublicationChannel | None = None,
        subscribe: bool = True,
        wait_first_publish_s: float = 60.0,
        seen: SparseRatings | None = None,
        max_batch: int = 32,
        max_samples: int | None = None,
        devices=None,
        mesh=None,
        n_hosts: int | None = None,
        replicas: int = 1,
        interpret: bool | None = None,
    ):
        """seen: training ratings used to exclude already-rated items.
        devices / mesh: where to shard the item factors — a mesh contributes
        its "data"-axis devices (launch/mesh.py), default all local devices.
        n_hosts: serve through the multi-host tier (serve/cluster.py) with
        this many shard hosts — one per device when enough exist — instead
        of the colocated single-host recommender.
        replicas: per-shard replication factor for the tier (n_hosts only):
        each item shard gets `replicas` owners and the coordinator routes
        around dead or stale ones (serve/cluster.py failure semantics).

        channel: a PublicationChannel a co-running trainer publishes into;
        with subscribe=True (default) a daemon thread adopts each publish as
        it lands, otherwise call refresh() to adopt on your own schedule.
        At least one of sample_root / channel is required; with only a
        channel the constructor blocks up to `wait_first_publish_s` for the
        trainer's first retained draw.
        """
        if sample_root is None and channel is None:
            raise ValueError("need a sample_root, a channel, or both")
        self.store = SampleStore(sample_root) if sample_root is not None else None
        self.channel = channel
        self.seen = SeenIndex(seen) if seen is not None else None
        self.max_batch = max_batch
        self.max_samples = max_samples
        if mesh is not None and devices is None:
            devices = list(mesh.devices.flatten())
        self.devices = devices if devices is not None else jax.devices()
        self.n_hosts = n_hosts
        self.replicas = replicas
        self.interpret = interpret
        self._lock = threading.Lock()
        # notified (under _lock) by every _swap — the condition wait_epoch()
        # blocks on, so tests and drain loops need no sleep/poll
        self._swap_cond = threading.Condition(self._lock)
        self._adopt_lock = threading.Lock()  # one ensemble build at a time
        # cold-start plan cache: batches with similar rating-count profiles
        # share padded plan shapes, so the fused fold-in solve never
        # recompiles on the steady-state cold path (serve/foldin.py)
        self.foldin_cache = FoldInPlanCache()
        self._queue: list[_Pending] = []
        self._ticket = 0
        self._epoch: int | None = None
        self._recommender: TopNRecommender | None = None
        # bounded: a long-lived server must not grow one float per request
        self.latencies_s: collections.deque[float] = collections.deque(maxlen=65536)
        # publish-path stats: swap count and publish -> swap-visible latency
        self.swaps = 0
        self.rebinds = 0  # swaps that reused the compiled executables
        self.publish_to_swap_s: collections.deque[float] = collections.deque(maxlen=4096)
        # publishes the subscriber rejected (e.g. an ensemble smaller than
        # the seen-item index) — kept so a rejection is observable without
        # killing the subscriber thread
        self.adopt_errors: collections.deque[Exception] = collections.deque(maxlen=64)
        self._subscriber: threading.Thread | None = None
        self._stop = threading.Event()

        # initial ensemble: disk when the store has retained draws (restart /
        # no-trainer case); otherwise block for the trainer's first publish —
        # a co-train first boot hands the server an still-empty sample dir
        if self.store is not None and self.store.epoch() is not None:
            self.refresh()
        elif channel is not None:
            snap = channel.wait(timeout=wait_first_publish_s)
            if snap is None:
                if channel.closed:
                    # not a timeout: the trainer ended (or died) before
                    # publishing anything — report that, don't mask it
                    raise RuntimeError(
                        "publication channel closed before the first publish "
                        "(trainer failed or finished during burn-in?)"
                    )
                raise TimeoutError(
                    f"no sample published within {wait_first_publish_s}s "
                    "and no retained samples to fall back to"
                )
            self._adopt_snapshot(snap)
        else:
            raise FileNotFoundError(
                f"no retained samples in {self.store.store.root}"
            )
        if channel is not None and subscribe:
            self._subscriber = threading.Thread(
                target=self._subscriber_loop, name="publish-subscriber", daemon=True
            )
            self._subscriber.start()

    # ------------------------------------------------------------------
    @property
    def epoch(self) -> int:
        with self._lock:
            assert self._epoch is not None
            return self._epoch

    @property
    def ensemble(self) -> PosteriorEnsemble:
        with self._lock:
            rec = self._recommender
        return rec.ensemble

    def refresh(self) -> bool:
        """Adopt the newest published or retained epoch; True on a swap.

        Checks the attached PublicationChannel first (in-memory adopt, no
        disk); falls back to polling the SampleStore directory — the only
        path when no trainer is co-running. The served-epoch reads here are
        prechecks on one locked snapshot; _swap() re-checks monotonicity
        under its lock.
        """
        with self._lock:
            served = self._epoch
            have_recommender = self._recommender is not None
        if self.channel is not None:
            snap = self.channel.snapshot()
            if snap is not None and (served is None or snap.epoch > served):
                return self._adopt_snapshot(snap)
        if self.store is None:
            return False
        newest = self.store.epoch()
        if newest is None:
            raise FileNotFoundError(f"no retained samples in {self.store.store.root}")
        if served is not None and newest <= served:
            return False
        try:
            ensemble = PosteriorEnsemble.load(
                self.store.store.root, max_samples=self.max_samples
            )
        except (FileNotFoundError, ValueError):
            # lost the race against the trainer's prune wholesale — keep
            # serving the cached epoch and let the next poll retry
            if have_recommender:
                return False
            raise
        return self._swap(ensemble, t_publish=None)

    # ------------------------------------------------------------------
    # publish-path adoption: in-memory ensemble build + atomic swap
    # ------------------------------------------------------------------
    def _adopt_snapshot(self, snap: ChannelSnapshot) -> bool:
        """Build an ensemble from a channel snapshot and swap it in. The
        epoch precheck is only an optimisation — _swap() re-checks under
        its lock, which is what preserves monotonicity under races."""
        with self._lock:
            served = self._epoch
        if served is not None and snap.epoch <= served:
            return False
        draws = snap.draws
        if self.max_samples is not None:
            draws = draws[-self.max_samples:]
        ensemble = PosteriorEnsemble(draws)
        return self._swap(ensemble, t_publish=snap.t_publish)

    def _swap(self, ensemble: PosteriorEnsemble, *, t_publish: float | None) -> bool:
        """Atomically publish a fully-built successor recommender.

        Double-buffered: the old recommender keeps serving until the new one
        exists; rebind() reuses its compiled executables when shapes are
        unchanged, else a full build (which retraces on first use).

        Every adoption path (channel snapshot, disk reload) funnels through
        here, and the monotonicity check runs under _adopt_lock — so a slow
        disk refresh() racing the subscriber thread can never regress the
        served epoch, and only one successor is built at a time.
        """
        with self._adopt_lock:
            if self._epoch is not None and ensemble.epoch <= self._epoch:
                return False  # lost the race to a newer adopt
            old = self._recommender
            rebound = False
            if old is not None:
                try:
                    recommender = old.rebind(ensemble)
                    rebound = True
                except ValueError:
                    # shape change: fold-in plan schemas key on the item
                    # axis, so drop them with the executables they fed.
                    # Same-shape rebinds keep every cache entry — a publish
                    # must not cost the cold path its compiled solves.
                    self.foldin_cache.clear()
                    recommender = self._build_recommender(ensemble)
            else:
                recommender = self._build_recommender(ensemble)
            with self._lock:
                self._epoch = ensemble.epoch
                self._recommender = recommender
                self.swaps += 1
                self.rebinds += int(rebound)
                if t_publish is not None:
                    self.publish_to_swap_s.append(time.perf_counter() - t_publish)
                self._swap_cond.notify_all()
        return True

    def wait_epoch(self, epoch: int, timeout: float | None = None) -> bool:
        """Block until the served epoch reaches `epoch`; True on success,
        False on timeout. Condition-based (woken by every swap) — the
        synchronization seam threaded tests use instead of sleep/poll."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._epoch is None or self._epoch < epoch:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                self._swap_cond.wait(remaining)
            return True

    def _build_recommender(self, ensemble: PosteriorEnsemble):
        """Fresh recommender for `ensemble` (boot, or a shape-changing
        swap). Resyncs the seen-item index first: an exclusion index built
        against the boot-time ratings silently under-excludes once the
        user/item axes grow, so a mismatched shape rebuilds it padded to
        the ensemble's axes (new users/items get empty exclusion rows) and
        an ensemble *smaller* than the ratings is rejected outright."""
        if self.seen is not None:
            want = (ensemble.n_users, ensemble.n_items)
            if self.seen.shape != want:
                self.seen = self.seen.resized(want)  # ValueError on shrink
        if self.n_hosts is not None:
            devices = None
            if self.devices is not None and len(self.devices) >= self.n_hosts:
                devices = list(self.devices)[: self.n_hosts]
            return ClusterCoordinator(
                ensemble, n_hosts=self.n_hosts, replicas=self.replicas,
                devices=devices, interpret=self.interpret,
            )
        return TopNRecommender(
            ensemble, devices=self.devices, interpret=self.interpret
        )

    def _subscriber_loop(self) -> None:
        """Daemon: sleep on the channel, adopt each newer snapshot on
        arrival — the push path; serving threads never wait on a rebuild.

        A publish whose adoption is *rejected* (ValueError — e.g. an
        ensemble shrunk below the seen-item index) is recorded in
        `adopt_errors` and skipped: the loop keeps serving the current
        epoch and stays alive for future publishes, rather than dying and
        silently freezing the served epoch forever.
        """
        rejected: int | None = None  # newest rejected epoch; skip until newer

        def adopt(snap) -> None:
            nonlocal rejected
            try:
                self._adopt_snapshot(snap)
            except ValueError as e:
                with self._lock:
                    # recorded under the lock + notified so tests and
                    # operators can condition-wait on a rejection instead
                    # of polling the deque
                    self.adopt_errors.append(e)
                    self._swap_cond.notify_all()
                rejected = snap.epoch

        while not self._stop.is_set():
            with self._lock:
                # locked read: _swap writes _epoch under this lock, and an
                # unlocked read here could see a torn/stale value while a
                # swap is mid-publish (the hammer test in tests/test_publish
                # drives this race)
                epoch = self._epoch
            floor = epoch if rejected is None else max(epoch, rejected)
            snap = self.channel.wait(newer_than=floor, timeout=0.25)
            if snap is None:
                if self.channel.closed:
                    # a final publish can land between our timed-out wait()
                    # and the closed check — drain it before exiting, or the
                    # last epoch would never be adopted (co-train drain loops
                    # block on fe.epoch catching up to channel.epoch)
                    final = self.channel.snapshot()
                    if final is not None and final.epoch > floor:
                        adopt(final)
                    return
                continue  # timeout heartbeat: re-check _stop
            adopt(snap)

    def close(self) -> None:
        """Stop the subscriber thread (the channel itself stays usable)."""
        self._stop.set()
        if self._subscriber is not None:
            self._subscriber.join(timeout=5.0)
            self._subscriber = None

    # ------------------------------------------------------------------
    def submit(self, user_id: int, topk: int = 10) -> int:
        """Queue a trained-user request; returns a ticket matched by flush()."""
        with self._lock:
            # snapshot the ensemble under the lock (the discipline flush()
            # uses): an unlocked read could race a concurrent publish swap
            # and validate against a torn view
            n_users = self._recommender.ensemble.n_users
            if not 0 <= user_id < n_users:
                # reject at enqueue (like submit_ratings): an out-of-range id
                # would otherwise clamp to another user's recommendations, or
                # crash the whole micro-batch in the seen-item lookup
                raise ValueError(
                    f"user id must be in [0, {n_users}), got {user_id}"
                )
            self._ticket += 1
            self._queue.append(_Pending(
                ticket=self._ticket, topk=topk, t_enqueue=time.perf_counter(),
                user_id=int(user_id),
            ))
            return self._ticket

    def submit_ratings(
        self, item_ids: np.ndarray, ratings: np.ndarray, topk: int = 10
    ) -> int:
        """Queue a cold-start request: the user's ratings, not a user id."""
        item_ids = np.asarray(item_ids, np.int32)
        ratings = np.asarray(ratings, np.float32)
        assert item_ids.shape == ratings.shape
        with self._lock:
            # same snapshot-under-lock discipline as submit(): the item-axis
            # bound must come from the recommender a concurrent publish
            # cannot be half-way through swapping
            n_items = self._recommender.ensemble.n_items
            if item_ids.size and not (
                0 <= item_ids.min() and item_ids.max() < n_items
            ):
                # reject here, not at flush: one bad request must not poison
                # the whole micro-batch it would be folded in with
                raise ValueError(
                    f"item ids must be in [0, {n_items}), got "
                    f"[{item_ids.min()}, {item_ids.max()}]"
                )
            self._ticket += 1
            self._queue.append(_Pending(
                ticket=self._ticket, topk=topk, t_enqueue=time.perf_counter(),
                item_ids=item_ids, ratings=ratings,
            ))
            return self._ticket

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------------
    def flush(self) -> list[RecommendResult]:
        """Drain the queue in micro-batches; returns results ticket-matched."""
        with self._lock:
            batch_all, self._queue = self._queue, []
            rec = self._recommender
            epoch = self._epoch
        results: list[RecommendResult] = []
        for lo in range(0, len(batch_all), self.max_batch):
            results.extend(self._run_batch(batch_all[lo: lo + self.max_batch],
                                           rec, epoch))
        self.latencies_s.extend(r.latency_s for r in results)
        return results

    def _run_batch(self, batch: list[_Pending], rec: TopNRecommender,
                   epoch: int) -> list[RecommendResult]:
        if not batch:
            return []
        topk = max(p.topk for p in batch)
        warm = [p for p in batch if p.user_id is not None]
        cold = [p for p in batch if p.user_id is None]
        out: dict[int, tuple[np.ndarray, np.ndarray]] = {}

        if warm:
            ids = np.asarray([p.user_id for p in warm], np.int32)
            vals, idx = rec.recommend(ids, topk, seen=self.seen)
            for r, p in enumerate(warm):
                out[p.ticket] = (vals[r], idx[r])

        if cold:
            rows = np.concatenate([
                np.full(len(p.item_ids), r, np.int32) for r, p in enumerate(cold)
            ])
            cols = np.concatenate([p.item_ids for p in cold])
            vals_r = np.concatenate([p.ratings for p in cold])
            ratings = SparseRatings(
                rows=rows, cols=cols, vals=vals_r,
                shape=(len(cold), rec.ensemble.n_items),
            )
            # deterministic fold-in (conditional posterior means): serving
            # the same ratings twice must return the same recommendations.
            # The plan cache quantizes the batch's rating-count profile so
            # the fused (S*B) solve recompiles only on new shape families.
            u_draws = fold_in(None, ratings, rec.ensemble, sample=False,
                              plan_cache=self.foldin_cache)  # repro-lint: disable=guarded-field (never rebound; cache is internally locked)
            # explicit candidate-count pin (topk + batch max degree,
            # power-of-two quantized) — the same fetch the exclusion lists
            # imply, but stated independently of them, so the kernel shape
            # stays pinned even for requests with nothing to exclude
            hint = topk + max(len(p.item_ids) for p in cold)
            hint = 1 << (hint - 1).bit_length()
            vals, idx = rec.recommend_factors(
                u_draws, topk, exclude=[p.item_ids for p in cold],
                fetch_hint=hint,
            )
            for r, p in enumerate(cold):
                out[p.ticket] = (vals[r], idx[r])

        t_done = time.perf_counter()
        return [
            RecommendResult(
                ticket=p.ticket,
                items=out[p.ticket][1][: p.topk],
                scores=out[p.ticket][0][: p.topk],
                epoch=epoch,
                latency_s=t_done - p.t_enqueue,
            )
            for p in batch
        ]

    # ------------------------------------------------------------------
    def latency_percentiles(self) -> dict[str, float]:
        """p50/p99 over every request served so far (seconds)."""
        if not self.latencies_s:
            return {"p50": float("nan"), "p99": float("nan")}
        lat = np.asarray(self.latencies_s)
        return {"p50": float(np.percentile(lat, 50)),
                "p99": float(np.percentile(lat, 99))}
