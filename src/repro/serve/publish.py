"""Push-based sample publication: trainer -> live server, no disk poll.

The paper's headline claim is that *asynchronous* communication of factor
updates lets computation and communication overlap (Sec 4; the shared-memory
companion arXiv:1705.04159 overlaps sampling with publication the same way).
PR 1's serving stack still coupled trainer and server through a pull-based
poll of the checkpoint directory. This module is the push half of that
seam: a `PublicationChannel` the trainer writes each retained post-burn-in
draw into (`GibbsSampler.run(..., publish=channel)` — alongside, not
instead of, the durable SampleStore write) and a live `RecommendFrontend`
subscribes to, swapping its ensemble in memory without ever touching disk.

Double buffering: the writer never blocks on readers and readers never see
a half-written ensemble. `publish()` builds the next window *off* the lock
(copy-on-write over an immutable tuple of draws), then flips the snapshot
reference under it; `snapshot()` just grabs the current reference. A reader
holding last epoch's snapshot keeps serving it until its own swap completes
— the same discipline `RecommendFrontend.flush()` applies one level up by
capturing (recommender, epoch) under its lock.

Ordering: draws are windowed by Gibbs step and the channel epoch is the
*newest* step ever accepted, so the epoch is monotone even when publishes
arrive out of order (a straggler draw lands in the window but cannot move
the epoch backwards; a duplicate step is dropped). Subscribers that adopt
only strictly-newer epochs therefore never regress.

The channel is also the seam the multi-host serving tier plugs into
(serve/cluster.py): `ClusterCoordinator.attach` subscribes one loop *per
shard host*, fanning every publish out across the serving mesh, and each
host stages its own V' shard rebind. Nothing below this interface changed
when that tier landed — single-host frontends and the pod-scale
coordinator consume the exact same snapshots.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, NamedTuple

from repro.checkpoint.samples import RetainedSample, as_retained_sample


class ChannelSnapshot(NamedTuple):
    """One immutable published state: what a subscriber adopts atomically."""

    epoch: int                          # newest step in the window (monotone)
    seq: int                            # bumps once per accepted publish
    draws: tuple[RetainedSample, ...]   # window, oldest first, step-sorted
    t_publish: float                    # perf_counter when epoch was published


class PublicationChannel:
    """In-memory keep-last-`window` channel of retained Gibbs draws.

    Thread-safe; one trainer (writer) and any number of subscribers
    (readers). Closed channels wake all waiters — `wait()` returning None
    with `closed` set is the end-of-stream signal a serving loop drains on.
    """

    def __init__(self, *, window: int = 16):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = window
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._snapshot: ChannelSnapshot | None = None
        self._times: dict[int, float] = {}   # step -> publish wall time
        self._closed = False
        self._callbacks: list[Callable[[ChannelSnapshot], None]] = []

    # -- writer side ---------------------------------------------------
    def publish(self, step: int, sample: dict) -> bool:
        """Offer one retained draw; returns False if it was dropped as stale
        (duplicate step, or older than everything a full window retains).
        `sample` carries exactly the SampleStore key schema (SAMPLE_KEYS).
        """
        draw = as_retained_sample(step, sample)
        t_now = time.perf_counter()
        with self._lock:
            if self._closed:
                raise RuntimeError("publish() on a closed channel")
            old = self._snapshot
            draws = old.draws if old is not None else ()
            if any(d.step == step for d in draws):
                return False
            merged = sorted(draws + (draw,), key=lambda d: d.step)
            merged = merged[-self.window:]
            if not any(d is draw for d in merged):
                return False  # straggler older than a full window
            epoch = max(step, old.epoch if old is not None else step)
            self._times[step] = t_now
            for stale in set(self._times) - {d.step for d in merged}:
                del self._times[stale]
            snap = ChannelSnapshot(
                epoch=epoch,
                seq=(old.seq + 1) if old is not None else 1,
                draws=tuple(merged),
                t_publish=self._times[epoch],
            )
            self._snapshot = snap
            callbacks = list(self._callbacks)
            self._cond.notify_all()
        for cb in callbacks:  # outside the lock: a slow subscriber must not
            cb(snap)          # stall the trainer's next publish
        return True

    def close(self) -> None:
        """End of stream (trainer finished); wakes every waiter."""
        with self._lock:
            self._closed = True
            self._cond.notify_all()

    # -- reader side ---------------------------------------------------
    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    @property
    def epoch(self) -> int | None:
        with self._lock:
            return self._snapshot.epoch if self._snapshot else None

    @property
    def seq(self) -> int:
        """Number of accepted publishes so far (0 before the first)."""
        with self._lock:
            return self._snapshot.seq if self._snapshot else 0

    def snapshot(self) -> ChannelSnapshot | None:
        """The current published state, or None before the first publish.
        The returned tuple is immutable — adopt it without further locking.
        """
        with self._lock:
            return self._snapshot

    def publish_time(self, step: int) -> float | None:
        """perf_counter timestamp of `step`'s publish, while it is windowed
        — the freshness clock benchmarks/publish_latency.py reads."""
        with self._lock:
            return self._times.get(step)

    def wait(
        self, *, newer_than: int | None = None, timeout: float | None = None
    ) -> ChannelSnapshot | None:
        """Block until a snapshot with epoch > `newer_than` exists (any
        snapshot when None). Returns it, or None on timeout / closed-and-
        nothing-newer — check `closed` to tell the two apart."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._lock:
            while True:
                snap = self._snapshot
                if snap is not None and (newer_than is None or snap.epoch > newer_than):
                    return snap
                if self._closed:
                    return None
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        return None
                self._cond.wait(remaining)

    def subscribe(self, callback: Callable[[ChannelSnapshot], None]
                  ) -> Callable[[], None]:
        """Register a push callback, invoked (outside the channel lock, in
        the publisher's thread) with each new snapshot. Keep callbacks
        cheap — flag-and-return; heavy adoption belongs on the subscriber's
        own thread (see RecommendFrontend's subscriber loop). Returns an
        unsubscribe function."""
        with self._lock:
            self._callbacks.append(callback)

        def unsubscribe() -> None:
            with self._lock:
                if callback in self._callbacks:
                    self._callbacks.remove(callback)

        return unsubscribe
