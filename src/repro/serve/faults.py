"""Deterministic fault injection for the multi-host serving tier.

The paper's asynchronous-communication design is motivated by exactly the
failures a synchronous barrier cannot ride out: hosts that die, hang, or
fall behind mid-exchange. This module is the harness that *manufactures*
those failures reproducibly, so serve/cluster.py's replication and quorum
machinery can be driven through every interleaving in tests instead of
hoping a race shows up under load:

* **FaultPlan** — an explicit schedule of fault events, each pinned to a
  named *seam* (a hook point the coordinator calls at a specific moment:
  ``"adopt"`` as a host's subscriber picks up a publish, ``"stage"`` as it
  builds the successor binding, ``"commit"`` just before the epoch
  barrier, ``"gather"`` as the coordinator collects a host's candidates).
  Events fire on the N-th traversal of their seam, counted per host — a
  chaos schedule is a pure function of the plan, never of thread timing or
  sleeps. `FaultPlan.random(seed, ...)` derives a schedule from a PRNG
  seed, so a failing randomized run is replayed bit-for-bit from its seed.

* **Clock / StepClock** — the injected time source. Delay faults and the
  health tracker's heartbeat arithmetic go through `clock.sleep` /
  `clock.time`; tests swap in a `StepClock` whose sleeps advance *virtual*
  time instantly, so "host silent for 10s" is one `advance(10)` call and
  bounded-time guarantees are asserted without wall-clock waits.

* **HostHealth** — per-host liveness state (healthy / suspect / dead)
  driven by heartbeats from the subscriber loops, error escalation from
  adopt/serve failures, and explicit kills. The coordinator consults it to
  route requests around bad replicas and to exclude dead hosts from the
  commit quorum. `wait_state` is condition-based (no poll loops) so tests
  synchronize on transitions.

Fault actions:

  kill   the host dies at the seam: marked dead, its loop thread exits,
         and (at the gather seam) the in-flight request routes around it.
  hang   the host blocks at the seam until `FaultPlan.release()` — it
         stops heartbeating but holds its binding, modelling a stalled
         process rather than a dead one.
  delay  the host sleeps `delay_s` on the injected clock at the seam — a
         slow host, not a failed one.
  drop   the operation at the seam is silently lost (the publish never
         reached the host, the candidate response never arrived); the
         host itself survives and catches up later.

* **assert_holds** — the runtime half of the ``*_locked`` naming
  convention repro-lint checks statically (docs/concurrency.md): under
  ``REPRO_DEBUG_LOCKS=1`` (the chaos CI job) every ``*_locked`` method
  verifies on entry that its caller actually acquired the lock; in
  production the check compiles down to one env-var-cached boolean test.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field

import numpy as np

SEAMS = ("adopt", "stage", "commit", "gather")
ACTIONS = ("kill", "hang", "delay", "drop")

HEALTHY, SUSPECT, DEAD = "healthy", "suspect", "dead"

DEBUG_LOCKS_ENV = "REPRO_DEBUG_LOCKS"


def debug_locks_enabled() -> bool:
    """True when ``REPRO_DEBUG_LOCKS`` is set to a non-empty, non-"0"
    value (the chaos CI job sets it; production leaves it unset)."""
    return os.environ.get(DEBUG_LOCKS_ENV, "") not in ("", "0")


def assert_holds(lock) -> None:
    """Debug-mode check that the calling thread holds `lock`.

    The runtime complement of the static ``*_locked`` convention: repro-lint
    proves call *sites* hold the lock lexically, this proves it dynamically
    on method *entry* under ``REPRO_DEBUG_LOCKS=1``. No-op otherwise.

    RLock/Condition expose ownership (``_is_owned``), so the check is
    exact there. A plain ``threading.Lock`` has no owner concept — the
    fallback is a non-blocking acquire probe: if it succeeds, *nobody*
    held the lock (the convention was violated by the caller); a lock held
    by a different thread is indistinguishable from held-by-us and passes.
    That asymmetry is fine for the bug class this catches: a ``*_locked``
    method reached with no lock at all.
    """
    if not debug_locks_enabled():
        return
    owned = getattr(lock, "_is_owned", None)
    if owned is not None:
        if not owned():
            raise AssertionError(
                "*_locked method entered without its lock held "
                f"(REPRO_DEBUG_LOCKS caught a convention violation on {lock!r})"
            )
        return
    if lock.acquire(blocking=False):
        lock.release()
        raise AssertionError(
            "*_locked method entered while its lock was unheld "
            f"(REPRO_DEBUG_LOCKS caught a convention violation on {lock!r})"
        )


class HostKilled(RuntimeError):
    """Raised at a seam whose fault action is ``kill``: the host is gone.

    The host's subscriber loop exits on it; the serving path catches it
    and fails over to another replica of the same shard."""


class FaultDrop(RuntimeError):
    """Raised at a seam whose fault action is ``drop``: the operation was
    lost in flight. The caller skips the operation; the host lives on."""


# ---------------------------------------------------------------------------
# injected time
# ---------------------------------------------------------------------------
class Clock:
    """Wall-clock time source — the production default."""

    def time(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class StepClock(Clock):
    """Virtual time: `sleep` advances instantly, `advance` moves time by
    hand. Delay faults and heartbeat timeouts become deterministic — a
    chaos test asserting "the tier declares a silent host suspect after
    10s" runs in microseconds of wall time."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def time(self) -> float:
        with self._lock:
            return self._t

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def advance(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError(f"cannot move time backwards ({seconds})")
        with self._lock:
            self._t += float(seconds)


# ---------------------------------------------------------------------------
# the fault schedule
# ---------------------------------------------------------------------------
@dataclass
class FaultEvent:
    """One scheduled fault: fire `action` on the `at`-th traversal of
    `seam` by `host` (any host when None — counted per seam, so "the 3rd
    publish adoption anywhere hangs" is expressible)."""

    seam: str
    action: str = "kill"
    host: int | None = None
    at: int = 1
    delay_s: float = 0.0
    fired: bool = field(default=False, compare=False)

    def __post_init__(self):
        if self.seam not in SEAMS:
            raise ValueError(f"unknown seam {self.seam!r}, want one of {SEAMS}")
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown action {self.action!r}, want one of {ACTIONS}")
        if self.at < 1:
            raise ValueError(f"at must be >= 1, got {self.at}")


class FaultPlan:
    """A reproducible chaos schedule threaded through the coordinator.

    The coordinator calls `fire(seam, host)` at every hook point; the plan
    counts traversals per (seam, host) — and per seam for host-agnostic
    events — and returns the event scheduled for that exact traversal, or
    None. Each event fires at most once; `fired_log` records the order
    for post-mortem replay. Thread-safe.
    """

    def __init__(self, events: tuple[FaultEvent, ...] | list[FaultEvent] = (),
                 *, clock: Clock | None = None, hang_timeout: float | None = 30.0):
        self.events = list(events)
        self.clock = clock if clock is not None else Clock()
        self.hang_timeout = hang_timeout
        self.fired_log: list[tuple[str, int, FaultEvent]] = []
        self._hits: dict[tuple[str, int | None], int] = {}
        self._lock = threading.Lock()
        self._release = threading.Event()
        self._hanging: set[int] = set()

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        n_hosts: int,
        n_events: int | None = None,
        seams: tuple[str, ...] = SEAMS,
        actions: tuple[str, ...] = ("kill", "drop", "delay"),
        max_at: int = 3,
        max_delay_s: float = 0.5,
        clock: Clock | None = None,
    ) -> "FaultPlan":
        """A schedule derived purely from `seed`: same seed, same faults,
        same trigger points — a failing chaos run replays exactly. Hangs
        are excluded by default (they need a `release()` choreographer);
        pass actions=ACTIONS to include them."""
        rng = np.random.default_rng(seed)
        if n_events is None:
            n_events = int(rng.integers(1, 2 * n_hosts + 1))
        events = [
            FaultEvent(
                seam=str(rng.choice(seams)),
                action=str(rng.choice(actions)),
                host=(int(rng.integers(0, n_hosts))
                      if rng.random() < 0.8 else None),
                at=int(rng.integers(1, max_at + 1)),
                delay_s=float(np.round(rng.uniform(0.0, max_delay_s), 3)),
            )
            for _ in range(n_events)
        ]
        return cls(events, clock=clock)

    # -- firing --------------------------------------------------------
    def fire(self, seam: str, host: int) -> FaultEvent | None:
        """Record one traversal of (seam, host); return the event scheduled
        for it, if any. At most one event fires per traversal."""
        with self._lock:
            for key in ((seam, int(host)), (seam, None)):
                self._hits[key] = self._hits.get(key, 0) + 1
            for ev in self.events:
                if ev.fired or ev.seam != seam:
                    continue
                if ev.host is not None and ev.host != host:
                    continue
                if self._hits[(seam, ev.host)] == ev.at:
                    ev.fired = True
                    self.fired_log.append((seam, int(host), ev))
                    return ev
            return None

    def hits(self, seam: str, host: int | None = None) -> int:
        with self._lock:
            return self._hits.get((seam, host), 0)

    @property
    def pending(self) -> list[FaultEvent]:
        with self._lock:
            return [ev for ev in self.events if not ev.fired]

    # -- hang choreography ---------------------------------------------
    def hang(self, host: int) -> None:
        """Block the calling (host) thread until `release()`. Bounded by
        `hang_timeout` as a safety net against a test that forgets."""
        with self._lock:
            self._hanging.add(int(host))
        try:
            self._release.wait(self.hang_timeout)
        finally:
            with self._lock:
                self._hanging.discard(int(host))

    @property
    def hanging(self) -> set[int]:
        with self._lock:
            return set(self._hanging)

    def release(self) -> None:
        """Unblock every hung host (the recover half of hang-then-recover)."""
        self._release.set()


# ---------------------------------------------------------------------------
# host liveness
# ---------------------------------------------------------------------------
class HostHealth:
    """Heartbeat + error-escalation liveness tracking for shard hosts.

    States: HEALTHY -> SUSPECT (missed heartbeats, or recent adopt/serve
    errors) -> DEAD (explicit kill, or `max_errors` accumulated errors).
    SUSPECT recovers to HEALTHY on the next heartbeat; DEAD is terminal —
    its shard is served by a replica or rebuilt on a surviving host.

    `serveable()` is what request routing consults: dead hosts never, and
    silent hosts (no heartbeat within `heartbeat_timeout` on the injected
    clock) only as a last resort. Hosts that have never beaten (no
    subscriber loop attached — the synchronous/unit-test layout) are
    serveable by construction.
    """

    def __init__(self, *, clock: Clock | None = None,
                 heartbeat_timeout: float = 5.0, max_errors: int = 3):
        self.clock = clock if clock is not None else Clock()
        self.heartbeat_timeout = heartbeat_timeout
        self.max_errors = max_errors
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._state: dict[int, str] = {}
        self._beat: dict[int, float | None] = {}
        self._errors: dict[int, list[Exception]] = {}

    def register(self, host_id: int) -> None:
        with self._lock:
            self._state.setdefault(int(host_id), HEALTHY)
            self._beat.setdefault(int(host_id), None)
            self._errors.setdefault(int(host_id), [])

    # -- signals -------------------------------------------------------
    def beat(self, host_id: int) -> None:
        """A liveness signal from the host's loop; revives SUSPECT."""
        with self._lock:
            self._beat[int(host_id)] = self.clock.time()
            if self._state.get(int(host_id)) == SUSPECT:
                self._state[int(host_id)] = HEALTHY
                self._cond.notify_all()

    def error(self, host_id: int, exc: Exception) -> None:
        """Escalate an adopt/serve failure: SUSPECT now, DEAD at
        `max_errors` accumulated errors."""
        with self._lock:
            errs = self._errors.setdefault(int(host_id), [])
            errs.append(exc)
            if self._state.get(int(host_id)) != DEAD:
                self._state[int(host_id)] = (
                    DEAD if len(errs) >= self.max_errors else SUSPECT
                )
                self._cond.notify_all()

    def kill(self, host_id: int) -> None:
        with self._lock:
            self._state[int(host_id)] = DEAD
            self._cond.notify_all()

    # -- queries -------------------------------------------------------
    def state(self, host_id: int) -> str:
        """Current state, heartbeat staleness folded in: a HEALTHY host
        whose last beat is older than the timeout reads as SUSPECT."""
        with self._lock:
            return self._state_locked(int(host_id))

    def _state_locked(self, host_id: int) -> str:
        assert_holds(self._lock)
        st = self._state.get(host_id, HEALTHY)
        if st == DEAD:
            return DEAD
        last = self._beat.get(host_id)
        if last is not None and (
            self.clock.time() - last > self.heartbeat_timeout
        ):
            return SUSPECT
        return st

    def serveable(self, host_id: int) -> bool:
        return self.state(host_id) != DEAD

    def preferred(self, host_id: int) -> bool:
        """Healthy AND heartbeat-fresh — routing picks these first and
        falls back to SUSPECT replicas only when no preferred one exists."""
        return self.state(host_id) == HEALTHY

    def errors(self, host_id: int) -> list[Exception]:
        with self._lock:
            return list(self._errors.get(int(host_id), ()))

    def snapshot(self) -> dict[int, dict]:
        """Per-host observability record for ClusterCoordinator.stats()."""
        with self._lock:
            now = self.clock.time()
            out = {}
            for hid in self._state:
                last = self._beat.get(hid)
                out[hid] = {
                    "state": self._state_locked(hid),
                    "errors": len(self._errors.get(hid, ())),
                    "last_beat_age_s": (None if last is None else now - last),
                }
            return out

    def wait_state(self, host_id: int, state: str, timeout: float | None = None
                   ) -> bool:
        """Condition-based wait until `host_id` reads as `state` (no poll
        loop; woken by beat/error/kill transitions)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._state_locked(int(host_id)) != state:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                self._cond.wait(remaining)
            return True
