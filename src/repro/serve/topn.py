"""Batched top-N recommendation over the full item catalogue.

Wraps the Pallas streaming top-k kernel (kernels/bpmf_topn.py) around the
ensemble's flattened scoring matrices. Two serving concerns live here:

* Seen-item exclusion. Users should not be recommended items they already
  rated. Rated sets are tiny next to the catalogue, so the kernel fetches
  topk + max(batch rated counts) candidates and the host drops the seen ones
  — cheaper than materialising a (B, N) mask the kernel would have to read.

* Item sharding. V' is split row-wise into `n_shards` chunks (one per mesh
  device when a mesh is given, mirroring launch/mesh.py's "data" axis). Each
  shard streams its chunk through the kernel independently; the per-shard
  candidate lists (values + global indices) are merged with one more stable
  top-k, the same merge the kernel itself applies across item tiles. On a
  real slice each shard's kernel runs on its own device against its resident
  chunk — scoring scales with devices while the merge stays O(shards * topk).

* Executable reuse across publishes. A co-running trainer replaces the
  ensemble many times over a server's life, almost always at unchanged
  (S, N, K). `rebind()` builds the successor recommender on the *same*
  shard layout, so every kernel invocation lands on the jit cache entries
  the predecessor already compiled — publishing costs a buffer swap, never
  a retrace (`shape_key` is the identity that makes this safe).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.sparse import SparseRatings, csr_from_coo
from repro.kernels import ops
from repro.serve.ensemble import PosteriorEnsemble


class SeenIndex:
    """One-time CSR index over the training matrix: O(degree) lookup of a
    user's rated items, vs the O(nnz) boolean scan a COO filter would cost
    on every request batch."""

    def __init__(self, ratings: SparseRatings):
        self.indptr, self.cols, _ = csr_from_coo(
            ratings.rows, ratings.cols, ratings.vals, ratings.shape[0]
        )
        self.max_degree = int(np.diff(self.indptr).max(initial=0))

    def __getitem__(self, user: int) -> np.ndarray:
        return self.cols[self.indptr[user]: self.indptr[user + 1]]


def _merge_topk(vals: jax.Array, idx: jax.Array, topk: int
                ) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard candidates (B, C) keeping lax.top_k's stable order.

    Shards hold disjoint, ascending index ranges and are concatenated in
    range order, so position-stable top_k again resolves ties to the lowest
    global item index.
    """
    v, pos = jax.lax.top_k(vals, topk)
    return v, jnp.take_along_axis(idx, pos, axis=1)


class TopNRecommender:
    def __init__(
        self,
        ensemble: PosteriorEnsemble,
        *,
        n_shards: int = 1,
        devices=None,
        interpret: bool | None = None,
    ):
        self.ensemble = ensemble
        self.interpret = interpret
        self.devices = devices
        u_flat, v_flat = ensemble.scoring_matrices()
        self.u_flat = u_flat  # (M, S*K) trained-user scoring rows
        if devices is not None:
            n_shards = len(devices)
        self.n_shards = max(1, min(n_shards, v_flat.shape[0]))
        bounds = np.linspace(0, v_flat.shape[0], self.n_shards + 1).astype(int)
        self.shard_bounds = bounds
        self.shard_offsets = bounds[:-1]
        self.v_shards = self._shard(v_flat)

    def _shard(self, v_flat: jax.Array) -> list[jax.Array]:
        """Split V' row-wise on the precomputed bounds, one chunk per device."""
        shards = []
        for i in range(self.n_shards):
            chunk = v_flat[self.shard_bounds[i]: self.shard_bounds[i + 1]]
            if self.devices is not None:
                chunk = jax.device_put(chunk, self.devices[i % len(self.devices)])
            shards.append(chunk)
        return shards

    # ------------------------------------------------------------------
    def rebind(self, ensemble: PosteriorEnsemble) -> "TopNRecommender":
        """A new recommender serving `ensemble` through this one's compiled
        executables: same shard bounds, same device placement, and — because
        every jit in the scoring path keys on shapes this layout pins — zero
        retraces of the top-N kernel (kernels.bpmf_topn.trace_count is flat
        across a rebind; tested). The publish hot path: a same-shape sample
        publication costs one V' re-shard + buffer swap, not a recompile.

        Self is left untouched and fully servable — callers swap the
        returned instance in atomically (RecommendFrontend holds requests'
        view stable by capturing the old instance under its lock).

        Raises ValueError when the ensemble's (S, M, N, K) changed; the
        caller falls back to a full rebuild (which will retrace).
        """
        if ensemble.shape_key() != self.ensemble.shape_key():
            raise ValueError(
                f"shape changed: {ensemble.shape_key()} vs "
                f"{self.ensemble.shape_key()} — rebuild, don't rebind"
            )
        # same config + same shapes -> identical shard bounds and device
        # placement, so every kernel shape lands on the jit cache entries
        # this instance already compiled
        return self.__class__(
            ensemble, n_shards=self.n_shards, devices=self.devices,
            interpret=self.interpret,
        )

    # ------------------------------------------------------------------
    def _topk_rows(self, rows: jax.Array, topk: int
                   ) -> tuple[jax.Array, jax.Array]:
        """Kernel top-k of rows @ V'^T across all item shards."""
        topk = min(topk, self.ensemble.n_items)
        vals, idx = [], []
        for off, chunk in zip(self.shard_offsets, self.v_shards):
            k_eff = min(topk, chunk.shape[0])
            v, i = ops.topn_scores(rows, chunk, k_eff, interpret=self.interpret)
            vals.append(v)
            idx.append(i + np.int32(off))
        if len(vals) == 1:
            return vals[0], idx[0]
        return _merge_topk(jnp.concatenate(vals, 1), jnp.concatenate(idx, 1), topk)

    def recommend_rows(
        self,
        rows: jax.Array,
        topk: int,
        *,
        exclude: list[np.ndarray] | None = None,
        fetch_hint: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-N for explicit scoring rows (B, S*K).

        exclude: optional per-row arrays of item ids to drop (seen items).
        fetch_hint: a batch-independent upper bound on topk + exclusions
        (e.g. topk + SeenIndex.max_degree) — pins the candidate count so the
        serving hot path compiles exactly one kernel shape per topk.
        Returns host arrays (values (B, topk), indices (B, topk)); rows with
        fewer than topk candidates left are padded with (-inf, -1).
        """
        b = rows.shape[0]
        fetch = topk
        if exclude is not None:
            assert len(exclude) == b, (len(exclude), b)
            fetch = topk + max((len(e) for e in exclude), default=0)
        if fetch_hint is not None:
            # honored with or without exclusions: a hint pins the kernel
            # shape even for exclusion-free (e.g. cold-start) batches, whose
            # drifting topk would otherwise thrash the jit cache
            fetch = max(fetch, fetch_hint)
        if exclude is not None or fetch_hint is not None:
            # round up to a power of two: candidate count changes per batch,
            # quantizing it keeps the jit cache to O(log n_items) entries
            fetch = 1 << (fetch - 1).bit_length()
            fetch = min(fetch, self.ensemble.n_items)
        vals, idx = self._topk_rows(rows, fetch)
        vals = np.asarray(vals) + self.ensemble.global_mean
        idx = np.asarray(idx)
        if exclude is None:
            return vals[:, :topk], idx[:, :topk]
        out_v = np.full((b, topk), -np.inf, np.float32)
        out_i = np.full((b, topk), -1, np.int32)
        for r in range(b):
            keep = ~np.isin(idx[r], exclude[r])
            kept_v, kept_i = vals[r][keep][:topk], idx[r][keep][:topk]
            out_v[r, : len(kept_v)] = kept_v
            out_i[r, : len(kept_i)] = kept_i
        return out_v, out_i

    # ------------------------------------------------------------------
    def recommend(
        self,
        user_ids: np.ndarray,
        topk: int,
        *,
        seen: SparseRatings | SeenIndex | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-N for trained users. `seen` excludes each user's already-rated
        items; pass a prebuilt SeenIndex on the serving hot path (a raw
        SparseRatings is indexed from scratch on every call)."""
        user_ids = np.asarray(user_ids, np.int32)
        rows = self.u_flat[user_ids]
        exclude = None
        fetch_hint = None
        if seen is not None:
            if isinstance(seen, SparseRatings):
                seen = SeenIndex(seen)
            exclude = [seen[int(u)] for u in user_ids]
            fetch_hint = topk + seen.max_degree
        return self.recommend_rows(rows, topk, exclude=exclude,
                                   fetch_hint=fetch_hint)

    def recommend_factors(
        self,
        u_draws: jax.Array,
        topk: int,
        *,
        exclude: list[np.ndarray] | None = None,
        fetch_hint: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-N for fold-in users given their per-draw factors (S, B, K).

        fetch_hint pins the candidate count across cold batches (the
        frontend passes topk + batch max degree, power-of-two quantized) so
        varying per-batch rated counts reuse one compiled kernel shape."""
        rows = self.ensemble.user_scoring_rows(u_draws)
        return self.recommend_rows(rows, topk, exclude=exclude,
                                   fetch_hint=fetch_hint)
