"""Batched top-N recommendation over the full item catalogue — the
single-host special case of the multi-host tier (serve/cluster.py).

All the serving mechanics live in the tier: shard assignment
(`cluster.shard_bounds`), per-shard kernel scoring through
`kernels/bpmf_topn.py`, stable candidate merging (`cluster._merge_topk` —
re-exported here), power-of-two fetch quantization, and host-side
seen-item exclusion. `TopNRecommender` is a `ClusterCoordinator` whose
"hosts" are all colocated in this process (one per local device when a
device list / mesh is given, mirroring launch/mesh.py's "data" axis) — so
there is exactly one implementation of the merge contract, and the
single-host and pod-scale paths are bit-identical by construction.

Serving concerns kept from the original module:

* Seen-item exclusion. Users should not be recommended items they already
  rated. Rated sets are tiny next to the catalogue, so the kernel fetches
  topk + max(batch rated counts) candidates and the host drops the seen ones
  — cheaper than materialising a (B, N) mask the kernel would have to read.

* Executable reuse across publishes. A co-running trainer replaces the
  ensemble many times over a server's life, almost always at unchanged
  (S, N, K). `rebind()` builds the successor recommender on the *same*
  shard layout, so every kernel invocation lands on the jit cache entries
  the predecessor already compiled — publishing costs a buffer swap, never
  a retrace (`shape_key` is the identity that makes this safe).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.data.sparse import SparseRatings, csr_from_coo
from repro.serve.cluster import ClusterCoordinator, _merge_topk, shard_bounds
from repro.serve.ensemble import PosteriorEnsemble

__all__ = ["SeenIndex", "TopNRecommender", "_merge_topk", "shard_bounds"]


class SeenIndex:
    """One-time CSR index over the training matrix: O(degree) lookup of a
    user's rated items, vs the O(nnz) boolean scan a COO filter would cost
    on every request batch.

    `shape` is the (n_users, n_items) the index is valid for. It may be
    built *larger* than the ratings matrix (users/items the boot-time
    ratings never saw get empty exclusion rows) — the frontend uses
    `resized()` to follow an ensemble whose axes grew across a publish.
    Building it smaller than the ratings is rejected: an index that silently
    dropped known ratings would under-exclude.
    """

    def __init__(self, ratings: SparseRatings, *,
                 shape: tuple[int, int] | None = None):
        self.ratings = ratings
        self.shape = tuple(ratings.shape) if shape is None else tuple(shape)
        if self.shape[0] < ratings.shape[0] or self.shape[1] < ratings.shape[1]:
            raise ValueError(
                f"seen-index shape {self.shape} cannot shrink below the "
                f"ratings matrix {tuple(ratings.shape)} — it would silently "
                "under-exclude"
            )
        self.indptr, self.cols, _ = csr_from_coo(
            ratings.rows, ratings.cols, ratings.vals, self.shape[0]
        )
        self.max_degree = int(np.diff(self.indptr).max(initial=0))

    def resized(self, shape: tuple[int, int]) -> "SeenIndex":
        """The same ratings re-indexed for a grown (n_users, n_items) —
        raises ValueError when `shape` is smaller than the ratings."""
        return SeenIndex(self.ratings, shape=shape)

    def __getitem__(self, user: int) -> np.ndarray:
        return self.cols[self.indptr[user]: self.indptr[user + 1]]


class TopNRecommender(ClusterCoordinator):
    """Single-host top-N: every item shard colocated in this process.

    The serving API (`recommend`, `recommend_rows`, `recommend_factors`,
    `rebind`) is the coordinator's; this class only maps the historical
    `n_shards=` spelling onto the tier's host axis and keeps the flat-array
    accessors callers grew around the original implementation.
    """

    # colocated shards share one U table and the coordinator gathers
    # scoring rows once — no per-device replicas of a table that can be
    # millions of rows (the tier pays that only for real hosts)
    routed = False

    def __init__(
        self,
        ensemble: PosteriorEnsemble,
        *,
        n_shards: int = 1,
        devices=None,
        interpret: bool | None = None,
    ):
        super().__init__(ensemble, n_hosts=n_shards, devices=devices,
                         interpret=interpret)

    def _layout_kwargs(self) -> dict:
        # rebind() builds `type(self)(ensemble, **layout)` — the subclass
        # spells the host axis n_shards
        return dict(n_shards=self.n_hosts, devices=self.devices,
                    interpret=self.interpret)

    # -- flat-array accessors (compat with pre-tier callers) -------------
    @property
    def u_flat(self) -> jax.Array:
        """(M, S*K) trained-user scoring rows (host 0's U replica — all
        replicas are identical by construction)."""
        return self.hosts[0].live.u_replica

    @property
    def v_shards(self) -> list[jax.Array]:
        return [h.live.v_shard for h in self.hosts]

    @property
    def shard_bounds(self) -> np.ndarray:
        return np.asarray([self.hosts[0].live.lo]
                          + [h.live.hi for h in self.hosts])

    @property
    def shard_offsets(self) -> np.ndarray:
        return np.asarray([h.live.lo for h in self.hosts])
