"""int8 gradient compression with error feedback for cross-pod all-reduce.

The pod axis rides the slowest links; quantizing the gradient to int8 with a
per-tensor scale before the cross-pod reduce cuts those bytes 4x. The
quantization residual is carried in an error-feedback buffer so the scheme is
unbiased over time (Seide et al. / Karimireddy et al. style).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    error: Any  # residual pytree, same structure as grads


def compress_init(params: Any) -> CompressState:
    return CompressState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def int8_compress(g: jax.Array, error: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q int8, scale f32 scalar, new_error)."""
    gf = g.astype(jnp.float32) + error
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Any, state: CompressState, axis_name: str):
    """psum a gradient pytree over `axis_name` in int8 (+error feedback).

    For use inside shard_map. Integer payloads only sum correctly under a
    SHARED scale, so the (scalar) per-tensor scales are pmax-agreed first;
    each device then quantizes with the shared scale, the int payload is
    all-reduced in int32, and the residual wrt the shared-scale dequant is
    carried as error feedback.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        local = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        scale = jax.lax.pmax(local, axis_name)   # shared scale (scalar)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return qsum.astype(jnp.float32) * scale, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_grads = tdef.unflatten([o[0] for o in outs])
    new_state = CompressState(error=tdef.unflatten([o[1] for o in outs]))
    return new_grads, new_state
