"""AdamW with global-norm clipping, built from scratch (no optax).

Moments are stored in a configurable dtype: f32 by default, bf16 for
HBM-constrained trillion-parameter configs (kimi-k2), where the quantization
error is dominated by gradient noise at these batch sizes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
    lr: jax.Array | float | None = None,
) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = jnp.zeros(())
    step = state.step + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mf.astype(cfg.moment_dtype), vf.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, step=step), {"grad_norm": gnorm}
