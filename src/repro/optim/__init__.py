from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule
from repro.optim.compress import int8_compress, int8_decompress

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "cosine_schedule",
    "int8_compress",
    "int8_decompress",
]
