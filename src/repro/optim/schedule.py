"""Learning-rate / step-size schedules."""
from __future__ import annotations

import jax.numpy as jnp


def sgld_step_schedule(
    step, *, peak: float, decay: float = 0.33, t0: float = 200.0,
    floor: float = 0.0,
):
    """Polynomial SGLD step-size decay: eps_t = peak * (t0 / (t0 + t))^decay.

    The Welling & Teh (2011) a(b+t)^-gamma family, reparameterized so
    `peak` IS eps_0 (no coupled a/b algebra when tuning). `decay` < 1
    keeps the step sum divergent (the chain keeps exploring) while the
    discretization bias shrinks; `floor` optionally pins a terminal step
    size for infinite-horizon serving runs where a fully decayed chain
    would stop mixing.
    """
    step = jnp.asarray(step, jnp.float32)
    eps = peak * (t0 / (t0 + step)) ** decay
    return jnp.maximum(eps, floor)


def cosine_schedule(
    step, *, peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    frac = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup_steps, warm, cos)
