"""Unified model API: init / loss / prefill / decode per architecture family,
plus input specs and sharding rules for the production mesh.

Every architecture exposes the same four entry points so the launcher,
dry-run, and benchmarks are arch-agnostic:

    init(key)                       -> params
    loss_fn(params, batch)          -> (loss, metrics)           [train shapes]
    prefill_fn(params, batch)       -> {"logits", **cache}       [prefill shapes]
    decode_fn(params, cache, batch) -> (new_cache, logits)       [decode shapes]

`[audio]`/`[vlm]` modality frontends are STUBS per the grading spec:
`input_specs()` provides precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import ModelConfig
from repro.models import transformer as tfm
from repro.models import ssm as xl
from repro.models import zamba as zb


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def supported_shapes(cfg: ModelConfig) -> list[ShapeSpec]:
    """long_500k only for sub-quadratic (ssm/hybrid) families."""
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            continue  # full-attention archs: quadratic prefill — skip per spec
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Decoder-family model (dense / moe / vlm)
# ---------------------------------------------------------------------------
def _positions_for(cfg: ModelConfig, batch: int, seq: int, offset=0):
    pos = offset + jnp.arange(seq, dtype=jnp.int32)
    return jnp.broadcast_to(pos, (batch, seq))


class DecoderModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return tfm.init_decoder(key, self.cfg)

    def _embeds_and_positions(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(cfg.dtype)
            tok_emb = params["embed"].astype(cfg.dtype)[tokens]
            embeds = jnp.concatenate([patches, tok_emb], axis=1)
            positions = batch["positions"]  # (B, 3, S)
            return embeds, positions, None
        s = tokens.shape[1]
        return None, _positions_for(cfg, b, s), tokens

    def loss_fn(self, params, batch):
        cfg = self.cfg
        embeds, positions, tokens = self._embeds_and_positions(params, batch)
        logits, _, aux = tfm.decoder_forward(
            params, cfg, tokens, positions=positions, embeds=embeds
        )
        loss, metrics = tfm.cross_entropy(logits, batch["labels"])
        loss = loss + 0.01 * aux
        metrics["aux"] = aux
        return loss, metrics

    def init_cache(self, batch: int, max_len: int):
        return tfm.init_decode_cache(self.cfg, batch, max_len)

    def prefill_fn(self, params, batch, *, headroom: int = 64):
        cfg = self.cfg
        embeds, positions, tokens = self._embeds_and_positions(params, batch)
        b = batch["tokens"].shape[0]
        s = positions.shape[-1]
        # headroom: decode steps append past the prompt; a cache sized
        # exactly S would clamp the first decode write onto slot S-1.
        caches = self.init_cache(b, s + headroom)
        logits, caches, _ = tfm.decoder_forward(
            params, cfg, tokens, positions=positions, embeds=embeds, caches=caches
        )
        return {"logits": logits[:, -1], "cache": caches}

    def decode_fn(self, params, cache, batch):
        cfg = self.cfg
        tokens = batch["tokens"]  # (B, 1)
        b = tokens.shape[0]
        if cfg.family == "vlm":
            positions = batch["positions"]  # (B, 3, 1)
            embeds = params["embed"].astype(cfg.dtype)[tokens]
            logits, cache, _ = tfm.decoder_forward(
                params, cfg, None, positions=positions, embeds=embeds, caches=cache
            )
        else:
            pos = _positions_for(cfg, b, 1, offset=cache["pos"][0])
            logits, cache, _ = tfm.decoder_forward(
                params, cfg, tokens, positions=pos, caches=cache
            )
        return cache, logits[:, -1]


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper)
# ---------------------------------------------------------------------------
class EncDecModel:
    def __init__(self, cfg: ModelConfig, max_dec_len: int = 32_768):
        self.cfg = cfg
        self.max_dec_len = max_dec_len

    def init(self, key):
        return tfm.init_encdec(key, self.cfg, max_dec_len=self.max_dec_len)

    def loss_fn(self, params, batch):
        cfg = self.cfg
        enc = tfm.encoder_forward(params, cfg, batch["frames"])
        logits, _ = tfm.encdec_forward(params, cfg, batch["tokens"], enc)
        loss, metrics = tfm.cross_entropy(logits, batch["labels"])
        return loss, metrics

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
        xshape = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd)
        return {
            "k": jnp.zeros(shape, cfg.dtype),
            "v": jnp.zeros(shape, cfg.dtype),
            "pos": jnp.zeros((cfg.n_layers,), jnp.int32),
            "ck": jnp.zeros(xshape, cfg.dtype),
            "cv": jnp.zeros(xshape, cfg.dtype),
        }

    def prefill_fn(self, params, batch, *, headroom: int = 64):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        enc = tfm.encoder_forward(params, cfg, batch["frames"])
        ck, cv = tfm.init_cross_cache(params, cfg, enc)
        caches = self.init_cache(b, s + headroom)
        caches["ck"], caches["cv"] = ck, cv
        logits, caches = tfm.encdec_forward(
            params, cfg, tokens, enc, pos_offset=0, caches=caches
        )
        return {"logits": logits[:, -1], "cache": caches}

    def decode_fn(self, params, cache, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        logits, cache = tfm.encdec_forward(
            params, cfg, tokens, None, pos_offset=cache["pos"][0], caches=cache
        )
        return cache, logits[:, -1]


# ---------------------------------------------------------------------------
# xLSTM (ssm)
# ---------------------------------------------------------------------------
class XLSTMModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return xl.init_xlstm(key, self.cfg)

    def loss_fn(self, params, batch):
        logits, _ = xl.xlstm_forward(params, self.cfg, batch["tokens"])
        return tfm.cross_entropy(logits, batch["labels"])

    def init_cache(self, batch: int, max_len: int):
        return xl.xlstm_init_states(self.cfg, batch)

    def prefill_fn(self, params, batch):
        states = self.init_cache(batch["tokens"].shape[0], 0)
        logits, states = xl.xlstm_forward(params, self.cfg, batch["tokens"], states)
        return {"logits": logits[:, -1], "cache": states}

    def decode_fn(self, params, cache, batch):
        logits, cache = xl.xlstm_forward(params, self.cfg, batch["tokens"], cache)
        return cache, logits[:, -1]


# ---------------------------------------------------------------------------
# Zamba2 (hybrid)
# ---------------------------------------------------------------------------
class ZambaModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key):
        return zb.init_zamba(key, self.cfg)

    def loss_fn(self, params, batch):
        tokens = batch["tokens"]
        positions = _positions_for(self.cfg, *tokens.shape)
        logits, _ = zb.zamba_forward(params, self.cfg, tokens, positions=positions)
        return tfm.cross_entropy(logits, batch["labels"])

    def init_cache(self, batch: int, max_len: int):
        return zb.zamba_init_states(self.cfg, batch, max_len)

    def prefill_fn(self, params, batch, *, headroom: int = 64):
        tokens = batch["tokens"]
        b, s = tokens.shape
        states = self.init_cache(b, s + headroom)
        positions = _positions_for(self.cfg, b, s)
        logits, states = zb.zamba_forward(
            params, self.cfg, tokens, positions=positions, states=states
        )
        return {"logits": logits[:, -1], "cache": states}

    def decode_fn(self, params, cache, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        positions = _positions_for(self.cfg, b, 1, offset=cache["attn_pos"][0])
        logits, cache = zb.zamba_forward(
            params, self.cfg, tokens, positions=positions, states=cache
        )
        return cache, logits[:, -1]


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderModel(cfg)
    if cfg.family == "audio":
        return EncDecModel(cfg)
    if cfg.family == "ssm":
        return XLSTMModel(cfg)
    if cfg.family == "hybrid":
        return ZambaModel(cfg)
    raise ValueError(f"unknown family {cfg.family}")


# ---------------------------------------------------------------------------
# Exact parameter counting (family-aware, from init shapes — no allocation)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def count_params(cfg: ModelConfig) -> tuple[float, float]:
    """(total, active) parameter counts from the actual init shapes.

    Active: MoE expert tensors scaled by top-k / n_experts (pad experts are
    never routed to, so they count toward neither).
    """
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = 0.0
    active = 0.0
    e_pad = cfg.n_experts_pad or cfg.n_experts
    for path, sd in jax.tree_util.tree_leaves_with_path(shapes):
        n = float(np.prod(sd.shape))
        ps = "/".join(str(getattr(p, "key", p)) for p in path)
        total += n
        if cfg.is_moe and "moe" in ps and e_pad and e_pad in sd.shape:
            active += n * (cfg.n_experts_active / e_pad)
        else:
            active += n
    return total, active


def model_flops_per_step(cfg: ModelConfig, shape: "ShapeSpec") -> float:
    """6 * N_active * tokens (train) or 2 * N_active * tokens (fwd-only)."""
    _, active = count_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens


# ---------------------------------------------------------------------------
# Parameter sharding rules (FSDP + expert parallelism)
# ---------------------------------------------------------------------------
STACKED1 = ("layers", "enc_layers", "dec_layers", "slstm")
STACKED2 = ("mlstm", "mamba")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_pspecs(cfg: ModelConfig, params_shapes: Any, mesh) -> Any:
    """PartitionSpec pytree for the parameters.

    Rules (DESIGN.md §4):
      - stacked layer axes are never sharded;
      - MoE expert weights shard experts -> 'model' (EP);
      - every tensor's largest remaining dim shards over 'data'
        (plus 'pod' when cfg.fsdp_pod — the trillion-param posture);
      - vectors (norms, biases, gates) replicate.
    """
    dsize = mesh.shape.get("data", 1)
    psize = mesh.shape.get("pod", 1)
    msize = mesh.shape.get("model", 1)
    fsdp_axes = ("pod", "data") if (cfg.fsdp_pod and "pod" in mesh.axis_names) else ("data",)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes if a in mesh.axis_names]))

    def spec(path, sd):
        shape = sd.shape
        ps = _path_str(path)
        names: list = [None] * len(shape)
        stacked = 0
        if any(k in ps for k in STACKED2) and "shared" not in ps:
            stacked = 2
        elif any(k in ps for k in STACKED1):
            stacked = 1
        body = list(range(stacked, len(shape)))
        if len(body) < 2:
            return P()  # vectors / scalars replicate
        # Expert axis -> model.
        if "moe" in ps and len(body) == 3 and msize > 1:
            e_idx = body[0]
            if shape[e_idx] % msize == 0:
                names[e_idx] = "model"
                body = body[1:]
        # FSDP: largest remaining dim divisible by the fsdp extent.
        for i in sorted(body, key=lambda i: -shape[i]):
            if names[i] is None and shape[i] % fsdp_size == 0 and fsdp_size > 1:
                names[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
            if names[i] is None and len(fsdp_axes) > 1 and shape[i] % dsize == 0 and dsize > 1:
                names[i] = "data"
                break
        return P(*names)

    return jax.tree_util.tree_map_with_path(spec, params_shapes)


def train_state_pspecs(cfg: ModelConfig, state_shapes: Any, mesh) -> Any:
    """Shard AdamW moments exactly like their parameters; step replicates."""
    from repro.optim.adamw import AdamWState

    pspec = param_pspecs(cfg, state_shapes.params, mesh)
    return type(state_shapes)(
        params=pspec,
        opt=AdamWState(
            m=param_pspecs(cfg, state_shapes.opt.m, mesh),
            v=param_pspecs(cfg, state_shapes.opt.v, mesh),
            step=P(),
        ),
        step=P(),
    )


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocated) and PartitionSpecs
# ---------------------------------------------------------------------------
def batch_axes_for(mesh, batch: int) -> tuple:
    """Largest prefix of (pod, data) whose product divides the batch."""
    use = []
    div = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and mesh.shape[a] > 1 and batch % (div * mesh.shape[a]) == 0:
            use.append(a)
            div *= mesh.shape[a]
    return tuple(use)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input."""
    b, s = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.family == "vlm":
            npch = cfg.n_patches
            batch = {
                "tokens": sds((b, s - npch), i32),
                "labels": sds((b, s), i32),
                "patch_embeds": sds((b, npch, cfg.d_model), f32),
                "positions": sds((b, 3, s), i32),
            }
        if cfg.family == "audio":
            batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), f32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s), i32)}
        if cfg.family == "vlm":
            npch = cfg.n_patches
            batch = {
                "tokens": sds((b, s - npch), i32),
                "patch_embeds": sds((b, npch, cfg.d_model), f32),
                "positions": sds((b, 3, s), i32),
            }
        if cfg.family == "audio":
            batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), f32)
        return batch
    # decode: one new token against a seq_len cache
    batch = {"tokens": sds((b, 1), i32)}
    if cfg.family == "vlm":
        batch["positions"] = sds((b, 3, 1), i32)
    return batch


def input_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict[str, P]:
    baxes = batch_axes_for(mesh, shape.global_batch)
    bspec = baxes if baxes else None
    msize = mesh.shape.get("model", 1)

    def seq_spec(n):
        # jit input shardings must divide exactly (constraints inside pad).
        return "model" if (shape.kind != "decode" and n % msize == 0) else None

    out: dict[str, P] = {}
    for name, sd in input_specs(cfg, shape).items():
        if name in ("tokens", "labels"):
            if sd.shape[-1] == 1 or shape.kind == "decode":
                out[name] = P(bspec, None)
            else:
                out[name] = P(bspec, seq_spec(sd.shape[-1]))
        elif name == "patch_embeds":
            out[name] = P(bspec, None, None)
        elif name == "positions":
            out[name] = P(bspec, None, seq_spec(sd.shape[-1]) if sd.shape[-1] > 1 else None)
        elif name == "frames":
            out[name] = P(bspec, seq_spec(sd.shape[1]), None)
    return out


def cache_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Any:
    """PartitionSpecs for the decode cache pytree.

    KV caches shard sequence over 'model' (plus 'data' when the batch can't
    use it — the long-context single-sequence case), batch over (pod, data).
    Recurrent states shard heads over 'model' when divisible.
    """
    baxes = batch_axes_for(mesh, shape.global_batch)
    bspec = baxes if baxes else None
    seq_axes = ("model",) if baxes else tuple(
        a for a in ("data", "model") if a in mesh.axis_names
    )
    seq = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    model = build_model(cfg)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )

    def divisible(n, axes) -> bool:
        if axes is None:
            return False
        ax = (axes,) if isinstance(axes, str) else axes
        need = 1
        for a in ax:
            need *= mesh.shape.get(a, 1)
        return n % need == 0

    def spec_for(path, sd):
        names = [None] * len(sd.shape)
        keyname = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if keyname in ("k", "v", "ck", "cv", "attn_k", "attn_v"):
            # (L_or_G, B, S, Hk, hd)
            names[1] = bspec
            names[2] = seq if divisible(sd.shape[2], seq) else None
        elif keyname == "pos" or keyname == "attn_pos":
            pass
        else:
            # recurrent states: (..., B, H, ...) — shard heads over model
            msize = mesh.shape.get("model", 1)
            for i, d in enumerate(sd.shape):
                if i >= 1 and d % msize == 0 and d >= msize and msize > 1:
                    # pick the first large divisible non-leading axis as heads
                    names[i] = "model"
                    break
        return P(*names)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)
