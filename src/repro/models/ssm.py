"""xLSTM blocks: chunk-parallel mLSTM (matrix memory) and sequential sLSTM.

The mLSTM forward uses the stabilized chunkwise-parallel formulation: within a
chunk the update is an attention-like batched matmul (MXU-friendly); across
chunks a small recurrent state (C: hd x hd matrix memory, n: hd normalizer,
m: scalar stabilizer) is scanned. A step-by-step sequential form doubles as
the decode path and as the correctness oracle for the chunked form.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (
    BATCH_AXES,
    SEQ_AXIS,
    ModelConfig,
    Params,
    constrain,
    dense_init,
    rms_norm,
)


class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, dk, dv) matrix memory
    n: jax.Array   # (B, H, dk) normalizer
    m: jax.Array   # (B, H) stabilizer


def mlstm_init_state(batch: int, heads: int, dk: int, dv: int, dtype=jnp.float32):
    return MLSTMState(
        c=jnp.zeros((batch, heads, dk, dv), dtype),
        n=jnp.zeros((batch, heads, dk), dtype),
        m=jnp.full((batch, heads), -1e30, dtype),
    )


def mlstm_sequential(q, k, v, i_raw, f_raw, state: MLSTMState):
    """Oracle/decode path: scan the exact recurrence over time.

    q,k,v: (B, S, H, d); i_raw,f_raw: (B, S, H). Returns (h, state).
    """
    b, s, h, d = q.shape
    scale = 1.0 / math.sqrt(d)

    def step(st: MLSTMState, xs):
        qt, kt, vt, it, ft = xs
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + st.m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(logf + st.m - m_new)
        c = f_p[..., None, None] * st.c + i_p[..., None, None] * (
            kt[..., :, None] * scale * vt[..., None, :]
        )
        n = f_p[..., None] * st.n + i_p[..., None] * kt * scale
        num = jnp.einsum("bhkv,bhk->bhv", c, qt)
        den = jnp.einsum("bhk,bhk->bh", n, qt)
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        ht = num / denom[..., None]
        return MLSTMState(c, n, m_new), ht

    xs = tuple(
        jnp.moveaxis(a, 1, 0) for a in (q.astype(jnp.float32), k.astype(jnp.float32),
                                        v.astype(jnp.float32), i_raw, f_raw)
    )
    state, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), state


def mlstm_chunked(q, k, v, i_raw, f_raw, state: MLSTMState, *, chunk: int = 64):
    """Chunkwise-parallel stabilized mLSTM. Same signature as sequential."""
    b, s, h, d = q.shape
    scale = 1.0 / math.sqrt(d)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = map(zf, (q, k, v))
        # gate-neutral padding: f -> +inf (log-sigmoid 0, no decay),
        # i -> -inf (no input); otherwise the carried state would be
        # spuriously decayed by the pad steps.
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)), constant_values=1e9)
    # (nc, B, L, H, ...)
    resh = lambda a: jnp.moveaxis(
        a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0
    )
    qc, kc, vc = map(resh, (q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)))
    ic, fc = map(resh, (i_raw, f_raw))

    neg_inf = -1e30

    def chunk_step(st: MLSTMState, xs):
        qb, kb, vb, ib, fb = xs                     # (B, L, H, ...) / (B, L, H)
        logf = jax.nn.log_sigmoid(fb)               # (B, L, H)
        fcum = jnp.cumsum(logf, axis=1)             # inclusive
        # intra-chunk exponent D[t, s] = F_t - F_s + logi_s, s <= t
        dmat = (
            fcum[:, :, None, :] - fcum[:, None, :, :] + ib[:, None, :, :]
        )                                           # (B, T, S, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, neg_inf)
        inter_b = fcum + st.m[:, None, :]           # (B, T, H)
        m_new = jnp.maximum(inter_b, dmat.max(axis=2))   # (B, T, H)

        w = jnp.exp(dmat - m_new[:, :, None, :])    # (B, T, S, H)
        scores = jnp.einsum("bthd,bshd->btsh", qb, kb) * scale * w
        inter_scale = jnp.exp(inter_b - m_new)      # (B, T, H)
        numer = jnp.einsum("btsh,bshd->bthd", scores, vb) + inter_scale[
            ..., None
        ] * jnp.einsum("bthk,bhkv->bthv", qb, st.c)
        denom = scores.sum(axis=2) + inter_scale * jnp.einsum(
            "bthk,bhk->bth", qb, st.n
        )
        hb = numer / jnp.maximum(jnp.abs(denom), jnp.exp(-m_new))[..., None]

        # carry update to the end of the chunk
        f_all = fcum[:, -1, :]                      # (B, H) total log decay
        dec_exp = f_all[:, None, :] - fcum + ib     # (B, S, H)
        m_next = jnp.maximum(f_all + st.m, dec_exp.max(axis=1))
        kv = jnp.einsum(
            "bshk,bshv->bshkv", kb * scale, vb
        )
        wgt = jnp.exp(dec_exp - m_next[:, None, :])
        c_new = jnp.exp(f_all + st.m - m_next)[..., None, None] * st.c + jnp.einsum(
            "bsh,bshkv->bhkv", wgt, kv
        )
        n_new = jnp.exp(f_all + st.m - m_next)[..., None] * st.n + jnp.einsum(
            "bsh,bshk->bhk", wgt, kb * scale
        )
        return MLSTMState(c_new, n_new, m_next), hb

    state, hs = jax.lax.scan(chunk_step, state, (qc, kc, vc, ic, fc))
    out = jnp.moveaxis(hs, 0, 1).reshape(b, nc * chunk, h, d)
    return out[:, :s], state


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------
def init_mlstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 8)
    return {
        "ln": {"scale": jnp.zeros((d,), cfg.param_dtype)},
        "w_up": dense_init(ks[0], (d, 2 * d), cfg.param_dtype),
        "conv": dense_init(ks[1], (cfg.ssm_conv, d), cfg.param_dtype, scale=0.3),
        "wq": dense_init(ks[2], (d, d), cfg.param_dtype),
        "wk": dense_init(ks[3], (d, d), cfg.param_dtype),
        "wv": dense_init(ks[4], (d, d), cfg.param_dtype),
        "w_if": dense_init(ks[5], (d, 2 * h), jnp.float32, scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]).astype(jnp.float32),
        "gn": jnp.zeros((d,), cfg.param_dtype),
        "w_down": dense_init(ks[6], (d, d), cfg.param_dtype),
    }


def causal_conv1d(x: jax.Array, w: jax.Array, cache: jax.Array | None = None):
    """Depthwise causal conv. x: (B, S, C), w: (W, C). Returns (y, new_cache).

    cache: (B, W-1, C) trailing context for decode.
    """
    width = w.shape[0]
    if cache is not None:
        xin = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
        new_cache = xin[:, -(width - 1):] if width > 1 else cache
    else:
        xin = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
        new_cache = None
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        seg = jax.lax.dynamic_slice_in_dim(xin, i, x.shape[1], axis=1)
        out = out + seg.astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype), new_cache


def mlstm_block(p: Params, x: jax.Array, cfg: ModelConfig, state: MLSTMState | None,
                conv_cache: jax.Array | None = None, *, chunk: int = 64):
    """x: (B, S, D). Returns (out, new_state, new_conv_cache)."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xin = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    up = xin @ p["w_up"].astype(cfg.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = causal_conv1d(xm, p["conv"], conv_cache)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"].astype(cfg.dtype)).reshape(b, s, h, hd)
    k = (xc @ p["wk"].astype(cfg.dtype)).reshape(b, s, h, hd)
    v = (xm @ p["wv"].astype(cfg.dtype)).reshape(b, s, h, hd)
    gates = xm.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_raw, f_raw = jnp.split(gates.reshape(b, s, 2, h), 2, axis=2)
    i_raw, f_raw = i_raw[:, :, 0], f_raw[:, :, 0]

    if state is None:
        state = mlstm_init_state(b, h, hd, hd)
    if s == 1:
        ht, new_state = mlstm_sequential(q, k, v, i_raw, f_raw, state)
    else:
        ht, new_state = mlstm_chunked(q, k, v, i_raw, f_raw, state, chunk=chunk)
    ht = ht.reshape(b, s, d).astype(cfg.dtype)
    ht = rms_norm(ht, p["gn"], cfg.norm_eps)        # group-norm stand-in
    out = (ht * jax.nn.silu(z)) @ p["w_down"].astype(cfg.dtype)
    return out, new_state, new_conv


# ---------------------------------------------------------------------------
# sLSTM block (sequential scalar memory with exponential gating)
# ---------------------------------------------------------------------------
class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, hd)
    n: jax.Array  # (B, H, hd)
    m: jax.Array  # (B, H, hd)
    h: jax.Array  # (B, H, hd)


def slstm_init_state(batch: int, heads: int, hd: int, dtype=jnp.float32):
    z = jnp.zeros((batch, heads, hd), dtype)
    return SLSTMState(c=z, n=z, m=jnp.full_like(z, -1e30), h=z)


def init_slstm_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 4)
    return {
        "ln": {"scale": jnp.zeros((d,), cfg.param_dtype)},
        "w_gates": dense_init(ks[0], (d, 4 * d), jnp.float32, scale=0.02),
        "r_gates": dense_init(ks[1], (h, hd, 4 * hd), jnp.float32, scale=0.02),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "gn": jnp.zeros((d,), cfg.param_dtype),
        "w_up": dense_init(ks[2], (d, 2 * d), cfg.param_dtype),
        "w_down": dense_init(ks[3], (d, d), cfg.param_dtype),
    }


def slstm_block(p: Params, x: jax.Array, cfg: ModelConfig, state: SLSTMState | None):
    """Sequential sLSTM over the time axis + gated FFN. x: (B, S, D)."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xin = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    wx = xin.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]  # (B, S, 4D)
    if cfg.slstm_reshard:
        # the stacked gate residuals dominate the scan's HBM traffic; hold
        # them in bf16 (the recurrence itself stays f32)
        wx = wx.astype(jnp.bfloat16)
    wx = wx.reshape(b, s, 4, h, hd)
    if cfg.slstm_reshard and s > 1:
        # The scan below iterates the time axis; if S stays sharded over
        # 'model', every step dynamic-slices a distributed array (one
        # collective per timestep). Batch-shard only for the recurrence.
        wx = constrain(wx, P(BATCH_AXES, None, None, None, None))
    if state is None:
        state = slstm_init_state(b, h, hd)

    def step(st: SLSTMState, wxt):
        wxt = wxt.astype(jnp.float32)
        rec = jnp.einsum("bhk,hkg->bhg", st.h, p["r_gates"]).reshape(b, h, 4, hd)
        zi = wxt[:, 0] + rec[:, :, 0]
        zf = wxt[:, 1] + rec[:, :, 1]
        zz = wxt[:, 2] + rec[:, :, 2]
        zo = wxt[:, 3] + rec[:, :, 3]
        logf = jax.nn.log_sigmoid(zf)
        m_new = jnp.maximum(logf + st.m, zi)
        i_p = jnp.exp(zi - m_new)
        f_p = jnp.exp(logf + st.m - m_new)
        c = f_p * st.c + i_p * jnp.tanh(zz)
        n = f_p * st.n + i_p
        hh = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1e-6)
        return SLSTMState(c, n, m_new, hh), hh

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    ht = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(cfg.dtype)
    if cfg.slstm_reshard and s > 1:
        ht = constrain(ht, P(BATCH_AXES, SEQ_AXIS, None))
    ht = rms_norm(ht, p["gn"], cfg.norm_eps)
    up = ht @ p["w_up"].astype(cfg.dtype)
    g, u = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(g) * u) @ p["w_down"].astype(cfg.dtype)
    return out, state


# ---------------------------------------------------------------------------
# xLSTM model: groups of (slstm_every - 1) mLSTM blocks + 1 sLSTM block
# ---------------------------------------------------------------------------
def init_xlstm(key, cfg: ModelConfig) -> Params:
    n_groups = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    km, ks_, ke = jax.random.split(key, 3)
    m_keys = jax.random.split(km, n_groups * n_m).reshape(n_groups, n_m, 2)
    s_keys = jax.random.split(ks_, n_groups)
    mlstm = jax.vmap(jax.vmap(lambda k: init_mlstm_block(k, cfg)))(m_keys)
    slstm = jax.vmap(lambda k: init_slstm_block(k, cfg))(s_keys)
    return {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model), cfg.param_dtype, scale=0.02),
        "mlstm": mlstm,    # (G, n_m, ...)
        "slstm": slstm,    # (G, ...)
        "ln_final": {"scale": jnp.zeros((cfg.d_model,), cfg.param_dtype)},
    }


def xlstm_forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  states: dict | None = None, *, chunk: int = 64):
    """Returns (logits, new_states). states carries mLSTM/sLSTM/conv caches."""
    b, s = tokens.shape
    h = params["embed"].astype(cfg.dtype)[tokens]
    h = constrain(h, P(BATCH_AXES, SEQ_AXIS if s > 1 else None, None))
    n_groups = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    heads, hd = cfg.n_heads, cfg.d_model // cfg.n_heads

    decode = states is not None

    def group_body(carry, xs):
        h = carry
        if decode:
            gp_m, gp_s, ms, ss, cc = xs
        else:
            gp_m, gp_s = xs
            ms = ss = cc = None

        def m_body(carry2, xs2):
            h2 = carry2
            if decode:
                lp, st, cv = xs2
                st = MLSTMState(*st)
            else:
                lp = xs2
                st, cv = None, None
            out, new_st, new_cv = mlstm_block(lp, h2, cfg, st, cv, chunk=chunk)
            h2 = h2 + out
            ys = (tuple(new_st), new_cv) if decode else ()
            return h2, ys

        if cfg.remat and not decode:
            m_body = jax.checkpoint(m_body)
        if decode:
            h, m_out = jax.lax.scan(m_body, h, (gp_m, ms, cc))
        else:
            h, m_out = jax.lax.scan(m_body, h, gp_m)

        st_s = SLSTMState(*ss) if decode else None
        out, new_ss = slstm_block(gp_s, h, cfg, st_s)
        h = h + out
        ys = (m_out[0], m_out[1], tuple(new_ss)) if decode else ()
        return h, ys

    if decode:
        xs = (
            params["mlstm"], params["slstm"],
            states["mlstm"], states["slstm"], states["conv"],
        )
    else:
        xs = (params["mlstm"], params["slstm"])
    h, group_out = jax.lax.scan(group_body, h, xs)

    h = rms_norm(h, params["ln_final"]["scale"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv", h, params["embed"].astype(cfg.dtype),
        preferred_element_type=jnp.float32,
    )
    new_states = None
    if decode:
        new_states = {
            "mlstm": group_out[0], "conv": group_out[1], "slstm": group_out[2]
        }
    return logits, new_states


def xlstm_init_states(cfg: ModelConfig, batch: int):
    n_groups = cfg.n_layers // cfg.slstm_every
    n_m = cfg.slstm_every - 1
    heads, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    m0 = mlstm_init_state(batch, heads, hd, hd)
    s0 = slstm_init_state(batch, heads, hd)
    tile = lambda a: jnp.broadcast_to(a, (n_groups, n_m) + a.shape).copy()
    tile1 = lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy()
    return {
        "mlstm": tuple(tile(a) for a in m0),
        "slstm": tuple(tile1(a) for a in s0),
        "conv": jnp.zeros((n_groups, n_m, batch, cfg.ssm_conv - 1, cfg.d_model), cfg.dtype),
    }
