"""Decoder-only and encoder-decoder transformer stacks.

Layers are *stacked* (leading n_layers axis) and iterated with jax.lax.scan so
the HLO stays compact for 61-plus-layer models; per-layer heterogeneity
(gemma2's alternating local/global windows) rides along as scanned scalar
metadata. Remat wraps the scan body.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (
    BATCH_AXES,
    SEQ_AXIS,
    ModelConfig,
    Params,
    attention_block,
    constrain,
    dense_init,
    init_attention,
    init_mlp,
    init_moe,
    layer_norm,
    mlp_block,
    moe_block,
    rms_norm,
    softcap,
)


# ---------------------------------------------------------------------------
# Norm helpers (rms or layer)
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, norm_type: str | None = None) -> Params:
    nt = norm_type or getattr(cfg, "norm_type", "rms")
    d = cfg.d_model
    if nt == "layer":
        return {"scale": jnp.ones((d,), cfg.param_dtype), "bias": jnp.zeros((d,), cfg.param_dtype)}
    return {"scale": jnp.zeros((d,), cfg.param_dtype)}


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder layer
# ---------------------------------------------------------------------------
def init_decoder_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {
        "ln_attn": init_norm(cfg),
        "attn": init_attention(ks[0], cfg),
        "ln_mlp": init_norm(cfg),
    }
    if cfg.is_moe:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[2], cfg)
    if cfg.post_norms:
        p["ln_attn_post"] = init_norm(cfg)
        p["ln_mlp_post"] = init_norm(cfg)
    return p


def decoder_layer(
    p: Params,
    h: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    window,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None, jax.Array]:
    """Pre-norm (optionally sandwich-norm) transformer block."""
    a_in = apply_norm(p["ln_attn"], h, cfg)
    a, new_cache = attention_block(
        p["attn"], a_in, cfg, positions=positions, causal=True,
        window=window, cache=cache,
    )
    if cfg.post_norms:
        a = apply_norm(p["ln_attn_post"], a, cfg)
    h = h + a

    m_in = apply_norm(p["ln_mlp"], h, cfg)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        m, aux = moe_block(p["moe"], m_in, cfg)
    else:
        m = mlp_block(p["mlp"], m_in, cfg)
    if cfg.post_norms:
        m = apply_norm(p["ln_mlp_post"], m, cfg)
    h = h + m
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Decoder model
# ---------------------------------------------------------------------------
def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) per-layer sliding windows: 0 = full attention."""
    if cfg.local_global_period and cfg.sliding_window:
        idx = jnp.arange(cfg.n_layers)
        # gemma2 pattern: local, global, local, global, ...
        return jnp.where(
            idx % cfg.local_global_period == cfg.local_global_period - 1,
            0,
            cfg.sliding_window,
        ).astype(jnp.int32)
    return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)


def init_decoder(key: jax.Array, cfg: ModelConfig) -> Params:
    k_embed, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_decoder_layer(k, cfg))(layer_keys)
    p: Params = {
        "embed": dense_init(k_embed, (cfg.vocab_size, cfg.d_model), cfg.param_dtype, scale=0.02),
        "layers": layers,
        "ln_final": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k_out, (cfg.d_model, cfg.vocab_size), cfg.param_dtype)
    return p


def _scan_layers(params, h, cfg, positions, windows, caches=None):
    """Scan the stacked decoder layers, optionally threading decode caches."""

    def body(carry, xs):
        h = carry
        if caches is not None:
            lp, w, ck, cv, pos = xs
            cache = {"k": ck, "v": cv, "pos": pos}
        else:
            lp, w = xs
            cache = None
        h, new_cache, aux = decoder_layer(
            lp, h, cfg, positions=positions, window=w, cache=cache
        )
        h = constrain(h, P(BATCH_AXES, SEQ_AXIS, None))
        out = (new_cache["k"], new_cache["v"]) if cache is not None else ()
        return h, (out, aux)

    if cfg.remat:
        # nothing_saveable = full recompute: per-layer live set is just the
        # scan carry (B,S,D); FSDP weight gathers are re-issued in backward
        # (reshard-after-forward), trading ICI bytes for HBM. "dots" keeps
        # matmul outputs (incl. the attention score chain) — more HBM held,
        # far less recompute traffic (§Perf).
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_saveable
        elif cfg.remat_policy == "attn_probs":
            # save just the (bf16) probability tensor: backward reuses it
            # instead of recomputing the whole S^2 softmax chain, at
            # ~S^2*H*2 bytes per layer of HBM held
            policy = jax.checkpoint_policies.save_only_these_names("attn_probs")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        body = jax.checkpoint(body, policy=policy)

    if caches is not None:
        xs = (params["layers"], windows, caches["k"], caches["v"], caches["pos"])
    else:
        xs = (params["layers"], windows)
    h, (cache_out, aux) = jax.lax.scan(body, h, xs)
    new_caches = None
    if caches is not None:
        new_caches = {
            "k": cache_out[0],
            "v": cache_out[1],
            "pos": caches["pos"] + positions.shape[-1],
        }
    return h, new_caches, aux.sum()


def decoder_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array | None,
    *,
    positions: jax.Array,
    embeds: jax.Array | None = None,      # precomputed embeddings (vlm stub)
    caches: dict | None = None,           # stacked decode caches
) -> tuple[jax.Array, dict | None, jax.Array]:
    """Returns (logits, new_caches, moe_aux_loss)."""
    if embeds is None:
        h = params["embed"].astype(cfg.dtype)[tokens]
    else:
        h = embeds.astype(cfg.dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    h = constrain(h, P(BATCH_AXES, SEQ_AXIS, None))

    windows = layer_windows(cfg)
    h, new_caches, aux = _scan_layers(params, h, cfg, positions, windows, caches)
    h = apply_norm(params["ln_final"], h, cfg)

    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", h, params["embed"].astype(cfg.dtype),
            preferred_element_type=jnp.float32,
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", h, params["unembed"].astype(cfg.dtype),
            preferred_element_type=jnp.float32,
        )
    logits = softcap(logits, cfg.logit_softcap)
    logits = constrain(logits, P(BATCH_AXES, SEQ_AXIS, None))
    return logits, new_caches, aux


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int | None = None):
    l = n_layers or cfg.n_layers
    shape = (l, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "pos": jnp.zeros((l,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Cross-entropy loss
# ---------------------------------------------------------------------------
def cross_entropy(
    logits: jax.Array, labels: jax.Array, *, z_loss: float = 0.0
) -> tuple[jax.Array, dict]:
    """Mean next-token CE over valid (label >= 0) positions."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    valid = (labels >= 0).astype(jnp.float32)
    nll = (lse - gold) * valid
    n = jnp.maximum(valid.sum(), 1.0)
    loss = nll.sum() / n
    if z_loss:
        loss = loss + z_loss * ((lse * valid) ** 2).sum() / n
    metrics = {"ce": loss, "tokens": n}
    return loss, metrics


# ---------------------------------------------------------------------------
# Encoder-decoder (whisper backbone; conv frontend is a stub per spec)
# ---------------------------------------------------------------------------
def init_encoder_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": init_norm(cfg, "layer"),
        "attn": init_attention(ks[0], cfg),
        "ln_mlp": init_norm(cfg, "layer"),
        "mlp": init_mlp(ks[1], cfg),
    }


def init_crossdec_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln_self": init_norm(cfg, "layer"),
        "self_attn": init_attention(ks[0], cfg),
        "ln_cross": init_norm(cfg, "layer"),
        "cross_attn": init_attention(ks[1], cfg),
        "ln_mlp": init_norm(cfg, "layer"),
        "mlp": init_mlp(ks[2], cfg),
    }


def init_encdec(key: jax.Array, cfg: ModelConfig, max_dec_len: int = 0) -> Params:
    ks = jax.random.split(key, 6)
    enc_layers = jax.vmap(lambda k: init_encoder_layer(k, cfg))(
        jax.random.split(ks[0], cfg.encoder_layers)
    )
    dec_layers = jax.vmap(lambda k: init_crossdec_layer(k, cfg))(
        jax.random.split(ks[1], cfg.n_layers)
    )
    max_dec = max_dec_len or 4096
    return {
        "enc_pos": dense_init(ks[2], (cfg.encoder_seq, cfg.d_model), cfg.param_dtype, scale=0.02),
        "enc_layers": enc_layers,
        "enc_ln_final": init_norm(cfg, "layer"),
        "embed": dense_init(ks[3], (cfg.vocab_size, cfg.d_model), cfg.param_dtype, scale=0.02),
        "dec_pos": dense_init(ks[4], (max_dec, cfg.d_model), cfg.param_dtype, scale=0.02),
        "dec_layers": dec_layers,
        "dec_ln_final": init_norm(cfg, "layer"),
    }


def encoder_forward(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) precomputed frame embeddings (conv stub)."""
    h = frames.astype(cfg.dtype) + params["enc_pos"].astype(cfg.dtype)[None]
    h = constrain(h, P(BATCH_AXES, SEQ_AXIS, None))
    positions = jnp.broadcast_to(
        jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2]
    )

    def body(h, lp):
        a_in = apply_norm(lp["ln_attn"], h, cfg)
        a, _ = attention_block(
            lp["attn"], a_in, cfg, positions=positions, causal=False, use_rope=False
        )
        h = h + a
        m = mlp_block(lp["mlp"], apply_norm(lp["ln_mlp"], h, cfg), cfg)
        h = h + m
        return constrain(h, P(BATCH_AXES, SEQ_AXIS, None)), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return apply_norm(params["enc_ln_final"], h, cfg)


def encdec_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    enc_out: jax.Array,
    *,
    pos_offset: jax.Array | int = 0,
    caches: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    """Decoder with self + cross attention. caches: {"k","v","pos","ck","cv"}."""
    b, s = tokens.shape
    positions = pos_offset + jnp.arange(s, dtype=jnp.int32)
    h = params["embed"].astype(cfg.dtype)[tokens]
    pos_emb = jax.lax.dynamic_slice_in_dim(
        params["dec_pos"].astype(cfg.dtype), jnp.asarray(pos_offset, jnp.int32), s, 0
    )
    h = h + pos_emb[None]
    h = constrain(h, P(BATCH_AXES, SEQ_AXIS, None))
    pos_b = jnp.broadcast_to(positions, (b, s))

    def body(h, xs):
        if caches is not None:
            lp, ck, cv, cpos, xk, xv = xs
            self_cache = {"k": ck, "v": cv, "pos": cpos}
            cross_cache = {"k": xk, "v": xv}
        else:
            lp = xs
            self_cache = None
            cross_cache = None
        a_in = apply_norm(lp["ln_self"], h, cfg)
        a, new_self = attention_block(
            lp["self_attn"], a_in, cfg, positions=pos_b, causal=True,
            cache=self_cache, use_rope=False,
        )
        h = h + a
        c_in = apply_norm(lp["ln_cross"], h, cfg)
        if cross_cache is not None:
            c, _ = attention_block(
                lp["cross_attn"], c_in, cfg, positions=pos_b, causal=False,
                cache=cross_cache, use_rope=False,
            )
        else:
            c, _ = attention_block(
                lp["cross_attn"], c_in, cfg, positions=pos_b, causal=False,
                kv_src=enc_out, use_rope=False,
            )
        h = h + c
        m = mlp_block(lp["mlp"], apply_norm(lp["ln_mlp"], h, cfg), cfg)
        h = h + m
        h = constrain(h, P(BATCH_AXES, SEQ_AXIS, None))
        out = (new_self["k"], new_self["v"]) if self_cache is not None else ()
        return h, out

    if cfg.remat:
        body = jax.checkpoint(body)
    if caches is not None:
        xs = (
            params["dec_layers"], caches["k"], caches["v"], caches["pos"],
            caches["ck"], caches["cv"],
        )
    else:
        xs = params["dec_layers"]
    h, cache_out = jax.lax.scan(body, h, xs)
    h = apply_norm(params["dec_ln_final"], h, cfg)
    logits = jnp.einsum(
        "bsd,vd->bsv", h, params["embed"].astype(cfg.dtype),
        preferred_element_type=jnp.float32,
    )
    new_caches = None
    if caches is not None:
        new_caches = dict(
            k=cache_out[0], v=cache_out[1], pos=caches["pos"] + s,
            ck=caches["ck"], cv=caches["cv"],
        )
    return constrain(logits, P(BATCH_AXES, SEQ_AXIS, None)), new_caches


def init_cross_cache(params: Params, cfg: ModelConfig, enc_out: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Precompute per-layer cross-attention K/V from encoder output."""

    def per_layer(lp):
        b, se, _ = enc_out.shape
        k = (enc_out @ lp["cross_attn"]["wk"].astype(cfg.dtype)).reshape(
            b, se, cfg.n_kv_heads, cfg.hd
        )
        v = (enc_out @ lp["cross_attn"]["wv"].astype(cfg.dtype)).reshape(
            b, se, cfg.n_kv_heads, cfg.hd
        )
        if "bk" in lp["cross_attn"]:
            k = k + lp["cross_attn"]["bk"].astype(cfg.dtype).reshape(cfg.n_kv_heads, cfg.hd)
            v = v + lp["cross_attn"]["bv"].astype(cfg.dtype).reshape(cfg.n_kv_heads, cfg.hd)
        return k, v

    return jax.lax.map(per_layer, params["dec_layers"])
