from repro.models.layers import ModelConfig
from repro.models.api import (
    LM_SHAPES,
    ShapeSpec,
    build_model,
    input_specs,
    input_pspecs,
    cache_pspecs,
    param_pspecs,
    shape_by_name,
    supported_shapes,
)

__all__ = [
    "ModelConfig",
    "LM_SHAPES",
    "ShapeSpec",
    "build_model",
    "input_specs",
    "input_pspecs",
    "cache_pspecs",
    "param_pspecs",
    "shape_by_name",
    "supported_shapes",
]
