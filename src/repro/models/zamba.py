"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention/MLP block
applied every `attn_every` layers (weight-tied across applications).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (
    BATCH_AXES,
    SEQ_AXIS,
    ModelConfig,
    Params,
    constrain,
    dense_init,
    init_attention,
    init_mlp,
    attention_block,
    mlp_block,
    rms_norm,
)
from repro.models.mamba import (
    Mamba2State,
    init_mamba2_block,
    mamba2_block,
    mamba2_init_state,
)
from repro.models.transformer import apply_norm, init_norm


def zamba_groups(cfg: ModelConfig) -> tuple[int, int]:
    n_per = cfg.attn_every
    n_groups = cfg.n_layers // n_per
    return n_groups, n_per


def init_zamba(key, cfg: ModelConfig) -> Params:
    n_groups, n_per = zamba_groups(cfg)
    km, ka, ke, km2 = jax.random.split(key, 4)
    m_keys = jax.random.split(km, n_groups * n_per).reshape(n_groups, n_per, 2)
    mamba = jax.vmap(jax.vmap(lambda k: init_mamba2_block(k, cfg)))(m_keys)
    shared = {
        "ln_attn": init_norm(cfg),
        "attn": init_attention(ka, cfg),
        "ln_mlp": init_norm(cfg),
        "mlp": init_mlp(km2, cfg),
    }
    return {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model), cfg.param_dtype, scale=0.02),
        "mamba": mamba,
        "shared": shared,
        "ln_final": {"scale": jnp.zeros((cfg.d_model,), cfg.param_dtype)},
    }


def zamba_forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    positions: jax.Array,
    states: dict | None = None,   # {"mamba": stacked Mamba2State, "attn": caches}
    chunk: int | None = None,
):
    """Returns (logits, new_states)."""
    b, s = tokens.shape
    h = params["embed"].astype(cfg.dtype)[tokens]
    h = constrain(h, P(BATCH_AXES, SEQ_AXIS if s > 1 else None, None))
    decode = states is not None
    shared = params["shared"]

    def group_body(carry, xs):
        h = carry
        if decode:
            gp, mst, mconv, ck, cv, cpos = xs
        else:
            gp = xs
            mst = mconv = None

        def m_body(carry2, xs2):
            h2 = carry2
            if decode:
                lp, hst, cst = xs2
                st = Mamba2State(h=hst, conv=cst)
            else:
                lp = xs2
                st = None
            out, new_st = mamba2_block(lp, h2, cfg, st, chunk=chunk)
            h2 = h2 + out
            ys = (new_st.h, new_st.conv) if decode else ()
            return h2, ys

        if cfg.remat and not decode:
            m_body = jax.checkpoint(m_body)
        if decode:
            h, m_out = jax.lax.scan(m_body, h, (gp, mst, mconv))
        else:
            h, m_out = jax.lax.scan(m_body, h, gp)

        # Shared (weight-tied) attention + MLP block.
        cache = {"k": ck, "v": cv, "pos": cpos} if decode else None
        a_in = apply_norm(shared["ln_attn"], h, cfg)
        a, new_cache = attention_block(
            shared["attn"], a_in, cfg, positions=positions, causal=True,
            cache=cache,
        )
        h = h + a
        m = mlp_block(shared["mlp"], apply_norm(shared["ln_mlp"], h, cfg), cfg)
        h = h + m
        h = constrain(h, P(BATCH_AXES, SEQ_AXIS if s > 1 else None, None))
        if decode:
            ys = (m_out[0], m_out[1], new_cache["k"], new_cache["v"])
        else:
            ys = ()
        return h, ys

    if decode:
        xs = (
            params["mamba"],
            states["mamba_h"], states["mamba_conv"],
            states["attn_k"], states["attn_v"], states["attn_pos"],
        )
    else:
        xs = params["mamba"]
    h, group_out = jax.lax.scan(group_body, h, xs)

    h = rms_norm(h, params["ln_final"]["scale"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsd,vd->bsv", h, params["embed"].astype(cfg.dtype),
        preferred_element_type=jnp.float32,
    )
    new_states = None
    if decode:
        new_states = {
            "mamba_h": group_out[0],
            "mamba_conv": group_out[1],
            "attn_k": group_out[2],
            "attn_v": group_out[3],
            "attn_pos": states["attn_pos"] + s,
        }
    return logits, new_states


def zamba_init_states(cfg: ModelConfig, batch: int, max_len: int):
    n_groups, n_per = zamba_groups(cfg)
    m0 = mamba2_init_state(cfg, batch)
    tile = lambda a: jnp.broadcast_to(a, (n_groups, n_per) + a.shape).copy()
    return {
        "mamba_h": tile(m0.h),
        "mamba_conv": tile(m0.conv),
        "attn_k": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "attn_v": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "attn_pos": jnp.zeros((n_groups,), jnp.int32),
    }
