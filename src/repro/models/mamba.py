"""Mamba2 (SSD) blocks: chunked-parallel training form + recurrent decode step.

The SSD computation splits the sequence into chunks; within a chunk the
contribution is an attention-like batched matmul weighted by cumulative
decays, across chunks a (B, H, state, headdim) recurrent tensor is scanned.
The recurrent single-step path serves decode and the correctness oracle.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (
    BATCH_AXES,
    SEQ_AXIS,
    ModelConfig,
    Params,
    constrain,
    dense_init,
    rms_norm,
)
from repro.models.ssm import causal_conv1d


class Mamba2State(NamedTuple):
    h: jax.Array          # (B, H, N, P) SSM state
    conv: jax.Array       # (B, W-1, C) conv cache


def mamba2_dims(cfg: ModelConfig) -> tuple[int, int, int, int, int]:
    """(d_inner, n_heads, headdim, n_groups, d_state)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    headdim = cfg.ssm_headdim
    n_heads = d_inner // headdim
    n_groups = max(1, getattr(cfg, "ssm_groups", 1))
    return d_inner, n_heads, headdim, n_groups, cfg.ssm_state


def init_mamba2_block(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, nh, hp, ng, ns = mamba2_dims(cfg)
    conv_ch = d_inner + 2 * ng * ns
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * ng * ns + nh
    return {
        "ln": {"scale": jnp.zeros((d,), cfg.param_dtype)},
        "in_proj": dense_init(ks[0], (d, d_in_proj), cfg.param_dtype),
        "conv": dense_init(ks[1], (cfg.ssm_conv, conv_ch), cfg.param_dtype, scale=0.3),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.full((nh,), -4.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gn": jnp.zeros((d_inner,), cfg.param_dtype),
        "out_proj": dense_init(ks[2], (d_inner, d), cfg.param_dtype),
    }


def _split_proj(z, cfg: ModelConfig):
    d_inner, nh, hp, ng, ns = mamba2_dims(cfg)
    zi, xi, bi, ci, dti = jnp.split(
        z, [d_inner, 2 * d_inner, 2 * d_inner + ng * ns, 2 * d_inner + 2 * ng * ns],
        axis=-1,
    )
    return zi, xi, bi, ci, dti


def ssd_chunked(x, dt, a, b_in, c_in, state, *, chunk: int = 128,
                fold_decay: bool = False):
    """Chunked SSD scan.

    x: (B, S, H, P), dt: (B, S, H) (post-softplus), a: (H,) negative decay
    rates, b_in/c_in: (B, S, G, N), state: (B, H, N, P).
    Returns (y (B,S,H,P), new_state).

    fold_decay (perf variant): folds exp(+-cumsum(a dt)) into the C/B
    factors so the (B, T, S, H) decay tensor is never materialized — the
    intra-chunk score matrix becomes a single einsum + causal mask. The
    cumulative exponent is re-zeroed per chunk, bounding exp(-acum) by the
    chunk's own decay range.
    """
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    rep = h // g
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, b_in, c_in = map(zf, (x, dt, b_in, c_in))

    resh = lambda t: jnp.moveaxis(t.reshape(bsz, nc, chunk, *t.shape[2:]), 1, 0)
    xc, dtc, bc, cc = map(resh, (x.astype(jnp.float32), dt, b_in.astype(jnp.float32), c_in.astype(jnp.float32)))

    bh = jnp.repeat(bc, rep, axis=3)  # (nc, B, L, H, N) — per-head B
    ch = jnp.repeat(cc, rep, axis=3)

    def chunk_step(hst, xs):
        xb, dtb, bb, cb = xs                       # (B, L, H, ...)
        adt = a[None, None, :] * dtb               # (B, L, H) <= 0
        acum = jnp.cumsum(adt, axis=1)             # inclusive
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        if fold_decay:
            # scores[t,s] = (C_t e^{acum_t}) . (B_s e^{-acum_s} dt_s)
            cf = cb * jnp.exp(acum)[..., None]
            bf = bb * (jnp.exp(-acum) * dtb)[..., None]
            w = jnp.einsum("bthn,bshn->btsh", cf, bf)
            w = jnp.where(tri[None, :, :, None], w, 0.0)
            y_intra = jnp.einsum("btsh,bshp->bthp", w, xb)
            # state update reuses bf: exp(acum_T - acum_s) dt_s B_s = e^{acum_T} bf_s
            upd = jnp.einsum("bshn,bshp->bhnp", bf, xb)
            eT = jnp.exp(acum[:, -1])               # (B, H)
            h_new = eT[:, :, None, None] * (hst + upd)
            y_inter = jnp.einsum("bthn,bhnp->bthp", cf, hst)
        else:
            # intra-chunk: scores[t,s] = (C_t . B_s) exp(acum_t - acum_s) dt_s
            seg = acum[:, :, None, :] - acum[:, None, :, :]   # (B, T, S, H)
            decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
            cb_dot = jnp.einsum("bthn,bshn->btsh", cb, bb)
            w = cb_dot * decay * dtb[:, None, :, :]
            y_intra = jnp.einsum("btsh,bshp->bthp", w, xb)
            y_inter = jnp.exp(acum)[..., None] * jnp.einsum(
                "bthn,bhnp->bthp", cb, hst
            )
            tail = jnp.exp(acum[:, -1:, :] - acum)  # (B, S, H)
            upd = jnp.einsum("bsh,bshn,bshp->bhnp", tail * dtb, bb, xb)
            h_new = jnp.exp(acum[:, -1])[:, :, None, None] * hst + upd
        return h_new, y_intra + y_inter

    state, ys = jax.lax.scan(chunk_step, state.astype(jnp.float32), (xc, dtc, bh, ch))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * chunk, h, p)
    return y[:, :s], state


def ssd_step(x, dt, a, b_in, c_in, state):
    """One recurrent step. x: (B, H, P), dt: (B, H), b/c: (B, G, N)."""
    h = x.shape[1]
    g = b_in.shape[1]
    rep = h // g
    bh = jnp.repeat(b_in, rep, axis=1)   # (B, H, N)
    ch = jnp.repeat(c_in, rep, axis=1)
    decay = jnp.exp(a[None, :] * dt)     # (B, H)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, bh, x.astype(jnp.float32))
    h_new = decay[..., None, None] * state + upd
    y = jnp.einsum("bhn,bhnp->bhp", ch, h_new)
    return y, h_new


def mamba2_block(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: Mamba2State | None, *, chunk: int | None = None):
    """x: (B, S, D). Returns (out, new_state)."""
    chunk = chunk or cfg.ssm_chunk
    bsz, s, d = x.shape
    d_inner, nh, hp, ng, ns = mamba2_dims(cfg)
    xin = rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    z, xi, bi, ci, dti = _split_proj(xin @ p["in_proj"].astype(cfg.dtype), cfg)

    conv_in = jnp.concatenate([xi, bi, ci], axis=-1)
    conv_cache = state.conv if state is not None else None
    conv_out, new_conv = causal_conv1d(conv_in, p["conv"], conv_cache)
    conv_out = jax.nn.silu(conv_out)
    xi, bi, ci = jnp.split(conv_out, [d_inner, d_inner + ng * ns], axis=-1)

    dt = jax.nn.softplus(dti.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xi.reshape(bsz, s, nh, hp)
    bh = bi.reshape(bsz, s, ng, ns)
    chh = ci.reshape(bsz, s, ng, ns)

    h0 = (
        state.h if state is not None
        else jnp.zeros((bsz, nh, ns, hp), jnp.float32)
    )
    if s == 1 and state is not None:
        y, h_new = ssd_step(xh[:, 0], dt[:, 0], a, bh[:, 0], chh[:, 0], h0)
        y = y[:, None]
    else:
        y, h_new = ssd_chunked(
            xh, dt, a, bh, chh, h0, chunk=chunk, fold_decay=cfg.ssd_fold_decay
        )
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(cfg.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gn"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(cfg.dtype)
    new_state = Mamba2State(h=h_new, conv=new_conv) if state is not None else None
    return out, new_state


def mamba2_init_state(cfg: ModelConfig, batch: int) -> Mamba2State:
    d_inner, nh, hp, ng, ns = mamba2_dims(cfg)
    conv_ch = d_inner + 2 * ng * ns
    return Mamba2State(
        h=jnp.zeros((batch, nh, ns, hp), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype),
    )
