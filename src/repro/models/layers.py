"""Building blocks for the LM architectures.

Sharding philosophy (see DESIGN.md §4): activations are *token-sharded* —
batch over (pod, data), sequence over model — so every architecture balances
perfectly regardless of head counts. Parameters are FSDP-sharded; attention
all-gathers the (small, GQA) KV heads over the model axis; MoE uses an
explicit shard_map dispatch. Collectives that XLA can overlap with compute
are preferred everywhere (the paper's async-communication discipline).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]

# Concrete mesh made visible to layers that open shard_map regions (the EP
# MoE dispatch). jit in/out shardings carry only the abstract mesh, whose
# axes are Auto — shard_map needs the real one.
_ACTIVE_MESH: list = []


@contextlib.contextmanager
def active_mesh(mesh):
    _ACTIVE_MESH.append(mesh)
    try:
        yield
    finally:
        _ACTIVE_MESH.pop()


def get_active_mesh():
    return _ACTIVE_MESH[-1] if _ACTIVE_MESH else None


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0
    n_experts_pad: int = 0           # allocated experts (0 -> n_experts); pad
    moe_d_ff: int = 0                # so the expert axis divides the TP width
    capacity_factor: float = 1.25
    # --- attention flavour ---
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention
    local_global_period: int = 0     # gemma2: every 2nd layer global
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    attn_scale: float = 0.0          # 0 -> 1/sqrt(head_dim)
    norm_eps: float = 1e-6
    norm_type: str = "rms"           # rms | layer
    post_norms: bool = False         # gemma2 sandwich norms
    tie_embeddings: bool = True
    mlp_act: str = "silu"            # silu | gelu
    mlp_gated: bool = True           # gated (3-matrix) vs classic (2-matrix)
    qkv_bias: bool = False
    embed_scale: bool = False        # gemma2 multiplies embeddings by sqrt(d)
    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    attn_every: int = 0              # zamba: shared attn block period
    slstm_every: int = 0             # xlstm: one sLSTM per group of this size
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0
    # --- vlm ---
    n_patches: int = 0
    mrope_sections: tuple[int, ...] = ()
    # --- dtypes / training ---
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    moment_dtype: Any = jnp.float32
    remat: bool = True
    fsdp_pod: bool = False           # shard params over pod axis too (kimi)
    attn_chunk: int = 1024           # KV block for chunked (flash) attention
    chunked_attn_min_len: int = 8192
    # --- perf-variant knobs (EXPERIMENTS.md §Perf; defaults = baseline) ---
    attn_probs_bf16: bool = False    # store softmax blocks in bf16
    moe_group_dispatch: bool = False # per-sequence dispatch groups (no global sort)
    moe_ep_shard_map: bool = False   # explicit EP dispatch inside shard_map
                                     # (replicated-dispatch + psum combine;
                                     # bypasses GSPMD gather partialization)
    ssm_chunk: int = 128             # SSD / mLSTM chunk length
    ssd_fold_decay: bool = False     # fold exp(cumsum) into B/C, skip decay tensor
    slstm_reshard: bool = False      # reshard seq->replicated around the sLSTM
                                     # time scan (else every step collects the
                                     # sequence-sharded slice = per-step comms)
    remat_policy: str = "nothing"    # nothing | dots (save matmul outputs)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def model_flops_per_token(self) -> float:
        """6 * N(active) — the standard training-FLOPs model."""
        return 6.0 * self.active_params()

    def active_params(self) -> float:
        """Parameter count that participates per token (MoE: top-k only)."""
        d, hd = self.d_model, self.hd
        per_layer = d * (self.n_heads + 2 * self.n_kv_heads + 0) * hd  # qkv
        per_layer += self.n_heads * hd * d                              # out
        n_mats = 3 if self.mlp_gated else 2
        if self.is_moe:
            per_layer += n_mats * d * self.moe_d_ff * self.n_experts_active
            per_layer += d * self.n_experts                             # router
        elif self.d_ff:
            per_layer += n_mats * d * self.d_ff
        total = self.n_layers * per_layer
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return float(total)

    def total_params(self) -> float:
        d = self.d_model
        n_mats = 3 if self.mlp_gated else 2
        per_layer = d * (self.n_heads + 2 * self.n_kv_heads) * self.hd
        per_layer += self.n_heads * self.hd * d
        if self.is_moe:
            per_layer += 3 * d * self.moe_d_ff * self.n_experts + d * self.n_experts
        elif self.d_ff:
            per_layer += n_mats * d * self.d_ff
        total = self.n_layers * per_layer
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return float(total)


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------
BATCH_AXES = ("pod", "data")
SEQ_AXIS = "model"


def logical_batch_spec(batch: int, mesh) -> tuple:
    """Shard batch over as many of (pod, data) as divide it."""
    axes = [a for a in BATCH_AXES if a in mesh.axis_names]
    use = []
    div = 1
    for a in axes:
        if batch % (div * mesh.shape[a]) == 0 and mesh.shape[a] > 1:
            use.append(a)
            div *= mesh.shape[a]
    return tuple(use) if use else (None,)


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def activation_spec(mesh_axes: tuple[str, ...] = ("pod", "data", "model")) -> P:
    """(B, S, D) activations: batch over (pod,data), seq over model."""
    return P(BATCH_AXES, SEQ_AXIS, None)


# ---------------------------------------------------------------------------
# Initializers / norms
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float, *, offset: float = 1.0) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (offset + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(hd: int, theta: float, dtype=jnp.float32) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=dtype) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: tuple[int, ...]
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): positions (B, 3, S) for (t, h, w) axes.

    The hd/2 frequency lanes are split into `sections` (summing to hd/2); each
    section rotates by its own position channel.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # Build per-lane positions by selecting the section's position channel.
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=hd // 2
    )  # static repeat
    pos = positions.astype(jnp.float32)  # (B, 3, S)
    lane_pos = jnp.take(pos, sec_id, axis=1)  # (B, hd/2, S)
    angles = jnp.einsum("bks,k->bsk", lane_pos, freqs)  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, d_kv_src: int | None = None) -> Params:
    d, hd = cfg.d_model, cfg.hd
    dsrc = d_kv_src or d
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), cfg.param_dtype),
        "wk": dense_init(ks[1], (dsrc, cfg.n_kv_heads * hd), cfg.param_dtype),
        "wv": dense_init(ks[2], (dsrc, cfg.n_kv_heads * hd), cfg.param_dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.param_dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.param_dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.param_dtype)
    return p


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, Hk, hd) -> (B, S, H, hd) by repeating groups."""
    b, s, hk, hd = k.shape
    rep = n_heads // hk
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def attention_scores_mask(
    q_pos: jax.Array, k_pos: jax.Array, *, causal: bool, window
) -> jax.Array:
    """(..., Sq, Sk) boolean mask. q_pos/k_pos are int32 position vectors.

    `window` may be a python int or a traced scalar (per-layer scanned
    metadata, e.g. gemma2's alternating local/global pattern); 0 disables it.
    """
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    mask = jnp.ones(diff.shape, bool)
    if causal:
        mask &= diff >= 0
    if isinstance(window, int):
        if window > 0:
            mask &= diff < window
    else:
        w = jnp.asarray(window, jnp.int32)
        mask &= (w <= 0) | (diff < w)
    return mask


def multi_head_attention(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, Hk, hd)
    v: jax.Array,            # (B, Sk, Hk, hd)
    *,
    causal: bool,
    window: int = 0,
    attn_softcap: float = 0.0,
    scale: float = 0.0,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,   # valid KV length (decode)
    chunk: int = 0,                    # 0 = direct; else chunked flash
    probs_bf16: bool = False,
) -> jax.Array:
    """Unified attention. Returns (B, Sq, H, hd).

    Direct path materializes (B, H, Sq, Sk) scores; the chunked path scans
    over KV blocks with an online softmax (jnp flash attention) so long
    prefills never materialize the quadratic score tensor. Both paths accept
    GQA by expanding KV heads.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = scale or (1.0 / math.sqrt(hd))
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q_pos = q_offset + jnp.arange(sq, dtype=jnp.int32)
    k_pos = jnp.arange(sk, dtype=jnp.int32)

    if chunk and sk > chunk:
        return _chunked_attention(
            q, k, v, scale=scale, causal=causal, window=window,
            attn_softcap=attn_softcap, q_pos=q_pos, k_pos=k_pos,
            kv_len=kv_len, chunk=chunk, probs_bf16=probs_bf16,
        )

    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    scores = softcap(scores, attn_softcap)
    mask = attention_scores_mask(q_pos, k_pos, causal=causal, window=window)
    if kv_len is not None:
        mask &= k_pos[None, :] < kv_len[:, None] if kv_len.ndim else k_pos < kv_len
    scores = jnp.where(mask, scores, -1e30)
    probs_dtype = jnp.bfloat16 if probs_bf16 else q.dtype
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(probs_dtype)
    from jax.ad_checkpoint import checkpoint_name

    probs = checkpoint_name(probs, "attn_probs")
    return jnp.einsum(
        "bhqk,bkhd->bqhd", probs, v.astype(probs_dtype),
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)


def _chunked_attention(
    q, k, v, *, scale, causal, window, attn_softcap, q_pos, k_pos, kv_len, chunk,
    probs_bf16: bool = False,
):
    """Online-softmax attention scanned over KV chunks (jnp flash attention).

    Memory per step is O(B * Sq * H * chunk) instead of O(B * H * Sq * Sk).
    Serves as the CPU-lowerable oracle for the Pallas flash kernel.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max // 2)
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(n_chunks, chunk)

    qf = q.astype(jnp.float32)

    def step(carry, blk):
        acc, m, l = carry
        kb, vb, kpb = blk
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32)) * scale
        s = softcap(s, attn_softcap)
        mask = attention_scores_mask(q_pos, kpb, causal=causal, window=window)
        if kv_len is not None:
            mask = mask & (kpb[None, :] < kv_len)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        if probs_bf16:
            # the (B,H,Sq,BK) probability block is the traffic hot spot;
            # bf16 halves it (accumulation stays f32 via preferred type)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(jnp.bfloat16), vb.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32,
            )
        else:
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)
            )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, hd), jnp.float32)
    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, kp))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_block(
    params: Params,
    x: jax.Array,                   # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array,           # (B, S) or (B, 3, S) for M-RoPE
    causal: bool = True,
    window: int = 0,
    kv_src: jax.Array | None = None,   # cross-attention source
    cache: dict | None = None,          # {"k","v","pos"} decode cache
    use_rope: bool = True,
) -> tuple[jax.Array, dict | None]:
    """Projection + RoPE + attention + output projection.

    With `cache`, runs one decode step: writes K/V at cache["pos"] and attends
    over the valid prefix. Returns (out, new_cache).
    """
    b, s, d = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = kv_src if kv_src is not None else x

    q = (x @ params["wq"].astype(cfg.dtype)).reshape(b, s, h, hd)
    k = (src @ params["wk"].astype(cfg.dtype)).reshape(b, src.shape[1], hk, hd)
    v = (src @ params["wv"].astype(cfg.dtype)).reshape(b, src.shape[1], hk, hd)
    if "bq" in params:
        q = q + params["bq"].astype(cfg.dtype).reshape(h, hd)
        k = k + params["bk"].astype(cfg.dtype).reshape(hk, hd)
        v = v + params["bv"].astype(cfg.dtype).reshape(hk, hd)

    if use_rope and kv_src is None:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and kv_src is None and "pos" in cache:
        # Decode: append to sequence-sharded KV cache.
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        ck = constrain(ck, P(BATCH_AXES, SEQ_AXIS, None, None))
        cv = constrain(cv, P(BATCH_AXES, SEQ_AXIS, None, None))
        new_cache = {"k": ck, "v": cv, "pos": pos + s}
        # Causal with q_offset covers both decode (s=1) and prefill (s=S):
        # entries beyond the write position are masked by causality.
        out = multi_head_attention(
            q, ck.astype(cfg.dtype), cv.astype(cfg.dtype),
            causal=True, window=window, attn_softcap=cfg.attn_softcap,
            scale=cfg.attn_scale, q_offset=pos,
        )
    elif cache is not None:
        # Cross-attention with precomputed (static) cache.
        out = multi_head_attention(
            q, cache["k"].astype(cfg.dtype), cache["v"].astype(cfg.dtype),
            causal=False, attn_softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        )
        new_cache = cache
    else:
        # Training / prefill. KV replicated over the model (sequence) axis so
        # the q-sharded chunked scan needs no per-block collectives.
        k = constrain(k, P(BATCH_AXES, None, None, None))
        v = constrain(v, P(BATCH_AXES, None, None, None))
        chunk = cfg.attn_chunk if s >= cfg.chunked_attn_min_len else 0
        out = multi_head_attention(
            q, k, v, causal=causal, window=window,
            attn_softcap=cfg.attn_softcap, scale=cfg.attn_scale, chunk=chunk,
            probs_bf16=cfg.attn_probs_bf16,
        )

    out = out.reshape(b, s, h * hd) @ params["wo"].astype(cfg.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[1], (d, f), cfg.param_dtype),
        "w_down": dense_init(ks[2], (f, d), cfg.param_dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[0], (d, f), cfg.param_dtype)
    return p


def mlp_block(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    u = x @ params["w_up"].astype(cfg.dtype)
    if cfg.mlp_gated:
        g = act(x @ params["w_gate"].astype(cfg.dtype))
        h = g * u
    else:
        h = act(u)
    return h @ params["w_down"].astype(cfg.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (scatter dispatch, no one-hot einsum)
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ep = cfg.n_experts_pad or e
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (ep, d, f), cfg.param_dtype),
        "w_up": dense_init(ks[2], (ep, d, f), cfg.param_dtype),
        "w_down": dense_init(ks[3], (ep, f, d), cfg.param_dtype),
    }


def moe_block(params: Params, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Top-k capacity-bounded MoE with sort-based scatter dispatch.

    The classic GShard one-hot dispatch einsum costs 2*T*E*C*D flops — at 384
    experts that is ~400x the useful expert compute. We instead sort token
    replicas by expert, compute in-expert positions from cumulative counts,
    and *scatter* into a (E, C, D) buffer: only data movement, no fake flops.
    This is the same static-capacity/padding discipline as the BPMF bucket
    planner (DESIGN.md §5). Expert weights are sharded experts->model; XLA
    partitions the scatter/batched-matmul/gather pipeline.

    Returns (out (B,S,D), aux_loss scalar).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    if cfg.moe_ep_shard_map and s * k >= 4 * e:
        mesh = get_active_mesh()
        if mesh is not None and mesh.shape.get(SEQ_AXIS, 1) > 1:
            return _moe_ep_shard_map(params, x, cfg, mesh)
    if cfg.moe_group_dispatch and s * k >= 4 * e:
        return _moe_grouped(params, x, cfg)
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32)) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch-style).
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)                                 # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1)

    order = jnp.argsort(flat_e)                                # stable enough
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    offsets = jnp.cumsum(counts) - counts                      # exclusive
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - offsets[se]

    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    cap = max(cap, 1)
    keep = pos_in_e < cap
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)

    e_pad = cfg.n_experts_pad or e
    buf = jnp.zeros((e_pad, cap, d), cfg.dtype)
    gathered = jnp.where(keep[:, None], xf[st_], 0.0)
    buf = buf.at[se, safe_pos].add(gathered.astype(cfg.dtype))
    buf = constrain(buf, P(SEQ_AXIS, None, None))

    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(cfg.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(cfg.dtype))
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, params["w_down"].astype(cfg.dtype))
    y = constrain(y, P(SEQ_AXIS, None, None))

    back = y[se, safe_pos]                                     # (T*k, D)
    back = jnp.where(keep[:, None], back, 0.0) * sw[:, None].astype(cfg.dtype)
    out = jnp.zeros((t, d), cfg.dtype).at[st_].add(back)
    out = out.reshape(b, s, d)
    return out, aux


def _moe_ep_shard_map(
    params: Params, x: jax.Array, cfg: ModelConfig, mesh
) -> tuple[jax.Array, jax.Array]:
    """Explicit expert parallelism inside shard_map (perf variant round 2).

    GSPMD's auto-partitioner resolves the dispatch gather/scatter with
    partial-result all-reduces (5.4 TB/device/step on kimi-k2 — §Perf).
    Inside shard_map, nothing is second-guessed: tokens are replicated over
    the model axis (one boundary all-gather); each model shard routes *all*
    local tokens but scatters/computes only its own E/P experts, and the
    partial outputs are psum'ed over 'model'. Comm per layer = token
    activations once (gather) + once (reduce) — the replicated-dispatch EP
    scheme. The capacity/sort machinery is the group-local dispatch reused
    on purely local arrays.
    """
    b, s, d = x.shape
    e, kk = cfg.n_experts, cfg.n_experts_active
    e_pad = cfg.n_experts_pad or e
    pm = mesh.shape[SEQ_AXIS]
    assert e_pad % pm == 0, (e_pad, pm)
    e_loc = e_pad // pm
    batch_axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)
    fsdp_axes = tuple(
        a for a in (("pod", "data") if cfg.fsdp_pod else ("data",))
        if a in mesh.axis_names
    )
    import numpy as _np

    fsdp_size = int(_np.prod([mesh.shape[a] for a in fsdp_axes])) if fsdp_axes else 1
    f = cfg.moe_d_ff
    # expert weights follow param_pspecs: experts->model, largest dim->fsdp
    w_shard_ok = fsdp_size > 1 and d % fsdp_size == 0

    def region(xl, router, wg, wu, wd):
        # xl: (B_loc, S, D) replicated over model; w*: (E_loc, D(/fsdp), F)
        if w_shard_ok:
            wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axes, axis=2, tiled=True)
        m_idx = jax.lax.axis_index(SEQ_AXIS)
        bl = xl.shape[0]

        logits = xl.astype(jnp.float32) @ router                 # (B_loc, S, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, kk)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        me = probs.mean((0, 1))
        ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (bl * s * kk)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux

        sk = s * kk
        flat_e = top_e.reshape(bl, sk)
        flat_t = jnp.broadcast_to(
            jnp.repeat(jnp.arange(s, dtype=jnp.int32), kk), (bl, sk)
        )
        flat_w = top_w.reshape(bl, sk)
        order = jnp.argsort(flat_e, axis=1)
        se = jnp.take_along_axis(flat_e, order, 1)
        st_ = jnp.take_along_axis(flat_t, order, 1)
        sw = jnp.take_along_axis(flat_w, order, 1)
        gidx = jnp.arange(bl, dtype=jnp.int32)[:, None]

        counts = jnp.zeros((bl, e), jnp.int32).at[gidx, se].add(1)
        offsets = jnp.cumsum(counts, axis=1) - counts
        pos = jnp.arange(sk, dtype=jnp.int32)[None, :] - jnp.take_along_axis(offsets, se, 1)
        cap = max(1, int(math.ceil(sk / e * cfg.capacity_factor)))
        se_loc = se - m_idx * e_loc
        keep = (pos < cap) & (se_loc >= 0) & (se_loc < e_loc)   # my experts only
        safe_e = jnp.clip(se_loc, 0, e_loc - 1)
        safe_pos = jnp.where(keep, pos, cap - 1)

        tok = jnp.take_along_axis(xl, st_[..., None], 1).astype(cfg.dtype)
        gathered = jnp.where(keep[..., None], tok, jnp.zeros((), cfg.dtype))
        buf = jnp.zeros((bl, e_loc, cap, d), cfg.dtype)
        buf = buf.at[gidx, safe_e, safe_pos].add(gathered)

        act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
        g = jnp.einsum("becd,edf->becf", buf, wg.astype(cfg.dtype))
        u = jnp.einsum("becd,edf->becf", buf, wu.astype(cfg.dtype))
        y = jnp.einsum("becf,efd->becd", act(g) * u, wd.astype(cfg.dtype))

        back = y[gidx, safe_e, safe_pos]
        back = jnp.where(keep[..., None], back, jnp.zeros((), cfg.dtype))
        back = back * sw[..., None].astype(cfg.dtype)
        out = jnp.zeros((bl, s, d), cfg.dtype).at[gidx, st_].add(back)
        out = jax.lax.psum(out, SEQ_AXIS)                        # combine experts
        return out, aux

    bspec = batch_axes if batch_axes else None
    w_spec = P(SEQ_AXIS, fsdp_axes if w_shard_ok else None, None)
    wd_spec = P(SEQ_AXIS, None, fsdp_axes if w_shard_ok else None)
    from repro.compat import shard_map as _shard_map

    out, aux = _shard_map(
        region,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),    # tokens replicated over model
            P(None, None),           # router replicated
            w_spec, w_spec, wd_spec,
        ),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    out = constrain(out, P(BATCH_AXES, SEQ_AXIS, None))
    return out, aux


def _moe_grouped(params: Params, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Per-sequence dispatch groups (perf variant, EXPERIMENTS.md §Perf).

    The global-sort dispatch sorts B*S*k token replicas across the whole
    batch — under GSPMD that drags an all-gather of every token through the
    sort each layer. Grouping by sequence keeps routing, sort, and capacity
    local to each (pod,data) shard (the paper's locality-by-partitioning,
    Sec 4.2): the only cross-shard movement left is the (G, E, C, D) buffer
    resharding to expert-parallel layout — the EP all-to-all.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    e_pad = cfg.n_experts_pad or e

    # Own whole sequences per (pod,data) shard: routing, sort and the
    # capacity scatter then touch only local data. Without this, the scatter
    # reads seq-sharded tokens into a model-sharded buffer and XLA emits
    # full-buffer all-reduces (5.4 TB/device/step on kimi — §Perf).
    x = constrain(x, P(BATCH_AXES, None, None))
    logits = x.astype(jnp.float32) @ params["router"]          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                     # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean((0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    sk = s * k
    flat_e = top_e.reshape(b, sk)                              # per-group replicas
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k), (b, sk)
    )
    flat_w = top_w.reshape(b, sk)

    order = jnp.argsort(flat_e, axis=1)                        # group-local sort
    se = jnp.take_along_axis(flat_e, order, 1)
    st_ = jnp.take_along_axis(flat_t, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    gidx = jnp.arange(b, dtype=jnp.int32)[:, None]

    counts = jnp.zeros((b, e), jnp.int32).at[gidx, se].add(1)
    offsets = jnp.cumsum(counts, axis=1) - counts
    pos = jnp.arange(sk, dtype=jnp.int32)[None, :] - jnp.take_along_axis(offsets, se, 1)
    cap = max(1, int(math.ceil(sk / e * cfg.capacity_factor)))
    keep = pos < cap
    safe_pos = jnp.where(keep, pos, cap - 1)

    tok = jnp.take_along_axis(x, st_[..., None], 1, mode="clip").astype(cfg.dtype)
    zero = jnp.zeros((), cfg.dtype)                            # keep bf16 —
    gathered = jnp.where(keep[..., None], tok, zero)           # 0.0 promotes f32
    gathered = constrain(gathered, P(BATCH_AXES, None, None))  # D stays whole
    buf = jnp.zeros((b, e_pad, cap, d), cfg.dtype)
    buf = buf.at[gidx, se, safe_pos].add(gathered)
    buf = constrain(buf, P(BATCH_AXES, SEQ_AXIS, None, None))  # EP all-to-all

    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(cfg.dtype))
    u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(cfg.dtype))
    y = jnp.einsum("becf,efd->becd", act(g) * u, params["w_down"].astype(cfg.dtype))
    y = constrain(y, P(BATCH_AXES, SEQ_AXIS, None, None))

    y = constrain(y, P(BATCH_AXES, None, None, None))          # combine a2a back
    back = y.at[gidx, se, safe_pos].get(mode="clip")           # (B, sk, D)
    zero = jnp.zeros((), cfg.dtype)
    back = jnp.where(keep[..., None], back, zero) * sw[..., None].astype(cfg.dtype)
    back = constrain(back, P(BATCH_AXES, None, None))
    out = jnp.zeros((b, s, d), cfg.dtype).at[gidx, st_].add(back)
    out = constrain(out, P(BATCH_AXES, SEQ_AXIS, None))
    return out, aux
