"""Host-side P x P grid partitioning of R for the distributed sampler.

Mirrors the paper's Sec 4.2: U and V are row-sharded across nodes; R is
reordered into a P x P block grid so that shard p's item updates touch
counterpart block q only during ring step (p - q) mod P. Shard assignment is
LPT (longest-processing-time) bin packing under the paper's workload model
`cost = fixed + c * degree`, which is the static equivalent of TBB work
stealing. Every (p, q) block is padded to the global max row count — the
padding ratio IS the residual load imbalance and is reported in the stats.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.core.buckets import workload_model
from repro.data.sparse import SparseRatings


@dataclass(frozen=True)
class EntityPartition:
    shard: np.ndarray        # (N,) shard id per entity
    local: np.ndarray        # (N,) local slot within its shard
    n_loc: int               # padded per-shard entity count
    ids: np.ndarray          # (P, n_loc) global entity id, -1 for padding


def partition_entities(degrees: np.ndarray, n_shards: int) -> EntityPartition:
    """LPT assignment via a min-heap of shard loads: O(N log P) instead of
    the per-entity `np.argmin` scan's O(N * P), so million-entity partitions
    no longer dominate plan build time. Assignment is bit-identical to the
    argmin formulation (ties broken toward the lowest shard id; each
    shard's load accumulates in the same order) — pinned by a regression
    test.
    """
    n = len(degrees)
    cost = workload_model(degrees)
    order = np.argsort(-cost, kind="stable")
    count = np.zeros(n_shards, dtype=np.int64)
    shard = np.zeros(n, dtype=np.int32)
    local = np.zeros(n, dtype=np.int32)
    # (load, shard id) tuples: equal loads pop lowest-id first, matching
    # np.argmin's first-minimum rule. The initial list is already a heap.
    heap = [(0.0, p) for p in range(n_shards)]
    for e in order:
        load, p = heap[0]
        shard[e] = p
        local[e] = count[p]
        count[p] += 1
        heapq.heapreplace(heap, (load + cost[e], p))
    n_loc = int(count.max())
    ids = np.full((n_shards, n_loc), -1, dtype=np.int32)
    ids[shard, local] = np.arange(n, dtype=np.int32)
    return EntityPartition(shard=shard, local=local, n_loc=n_loc, ids=ids)


@dataclass(frozen=True)
class GridPlan:
    """Ring-sweep plan for updating one entity set from its counterpart.

    indices/values/mask: (P, P, R, W) — [p, q] holds the width-W padded rows
    of shard p's items whose ratings touch counterpart block q, with indices
    LOCAL to block q. seg: (P, P, R) local item slot each row feeds
    (n_loc = padding slot). R is the max row count over all (p, q). Rows in
    a block are sorted by local item slot (pad rows last), so `seg` is
    nondecreasing per block.

    seg_dense/seg_map support the fused gather-syrk engine's in-kernel
    segment reduction, which needs DENSE nondecreasing segment ids:
    seg_dense[p, q] renumbers a block's distinct seg values 0..d-1 in row
    order; seg_map[p, q, j] is the local item slot dense segment j feeds
    (n_loc for the pad segment and for unused trailing entries).
    """

    n_shards: int
    n_loc: int               # local item slots per shard
    n_counter_loc: int       # counterpart block size
    width: int
    indices: np.ndarray
    values: np.ndarray
    mask: np.ndarray
    seg: np.ndarray
    item_ids: np.ndarray     # (P, n_loc) global ids (-1 pad)
    nnz: int
    seg_dense: np.ndarray    # (P, P, R) dense per-block segment ids
    seg_map: np.ndarray      # (P, P, R) local item slot per dense segment

    @property
    def padded_lanes(self) -> int:
        return int(np.prod(self.indices.shape))

    def stats(self) -> dict:
        rows_used = int(self.mask.any(-1).sum())
        return {
            "shards": self.n_shards,
            "rows_per_block": int(self.indices.shape[2]),
            "width": self.width,
            "nnz": self.nnz,
            "lane_efficiency": round(self.nnz / max(self.padded_lanes, 1), 4),
            "row_fill": round(rows_used / max(np.prod(self.indices.shape[:3]), 1), 4),
        }


def build_grid_plan(
    ratings: SparseRatings,
    item_part: EntityPartition,
    counter_part: EntityPartition,
    *,
    width: int | str = 32,
) -> GridPlan:
    """Plan updates of the ROW entities of `ratings` from its COLUMN entities.

    ``width="auto"`` picks the padded-lane-minimizing row width for this
    grid's degree profile (the distributed analogue of the balanced bucket
    planner): every candidate lane-rounded width w is scored by
    R_max(w) * w — the per-block padded footprint the sweep actually
    allocates — and ties go to the narrower width.
    """
    p_sh = item_part.shard[ratings.rows]
    q_sh = counter_part.shard[ratings.cols]
    n_shards = item_part.ids.shape[0]

    # group ratings by (p, q, local_item)
    rows_acc: dict[tuple[int, int], list] = {}
    order = np.lexsort((ratings.cols, ratings.rows))
    r_sorted = ratings.rows[order]
    c_sorted = ratings.cols[order]
    v_sorted = ratings.vals[order]
    pq_rows: dict[tuple[int, int], dict[int, list]] = {}
    for rr, cc, vv in zip(r_sorted, c_sorted, v_sorted):
        p = int(item_part.shard[rr])
        q = int(counter_part.shard[cc])
        d = pq_rows.setdefault((p, q), {})
        d.setdefault(int(item_part.local[rr]), []).append(
            (int(counter_part.local[cc]), float(vv))
        )

    if width == "auto":
        lens = {pq: np.array([len(lst) for lst in d.values()], np.int64)
                for pq, d in pq_rows.items()}
        uniq = (np.unique(np.concatenate(list(lens.values())))
                if lens else np.array([1], np.int64))
        cands = sorted({int(min(512, max(4, -(-int(L) // 4) * 4))) for L in uniq})

        def padded_lanes(w):
            r = max((int(np.sum(-(-l // w))) for l in lens.values()), default=1)
            return max(r, 1) * w

        width = min(cands, key=lambda w: (padded_lanes(w), w))
    width = int(width)

    # rows per (p, q) block after width-chunking
    def n_rows(d):
        return sum(-(-len(lst) // width) for lst in d.values())

    r_max = max((n_rows(d) for d in pq_rows.values()), default=1)
    r_max = max(r_max, 1)

    idx = np.zeros((n_shards, n_shards, r_max, width), np.int32)
    val = np.zeros((n_shards, n_shards, r_max, width), np.float32)
    msk = np.zeros((n_shards, n_shards, r_max, width), np.float32)
    seg = np.full((n_shards, n_shards, r_max), item_part.n_loc, np.int32)

    for (p, q), d in pq_rows.items():
        r = 0
        # rows sorted by local item slot -> seg nondecreasing within a block
        # (pad rows carry n_loc and land last), the fused-engine invariant
        for litem, lst in sorted(d.items()):
            for c0 in range(0, len(lst), width):
                chunk = lst[c0 : c0 + width]
                for w, (lc, v) in enumerate(chunk):
                    idx[p, q, r, w] = lc
                    val[p, q, r, w] = v
                    msk[p, q, r, w] = 1.0
                seg[p, q, r] = litem
                r += 1

    # dense per-block renumbering of the (sorted) seg values + the map back
    # to local item slots, for the fused engine's in-kernel reduction
    seg_dense = np.zeros((n_shards, n_shards, r_max), np.int32)
    seg_map = np.full((n_shards, n_shards, r_max), item_part.n_loc, np.int32)
    for p in range(n_shards):
        for q in range(n_shards):
            s = seg[p, q]
            change = np.empty(r_max, bool)
            change[0] = True
            change[1:] = s[1:] != s[:-1]
            dense = np.cumsum(change) - 1
            seg_dense[p, q] = dense
            seg_map[p, q, : int(dense[-1]) + 1] = s[change]

    return GridPlan(
        n_shards=n_shards,
        n_loc=item_part.n_loc,
        n_counter_loc=counter_part.n_loc,
        width=width,
        indices=idx,
        values=val,
        mask=msk,
        seg=seg,
        item_ids=item_part.ids,
        nnz=ratings.nnz,
        seg_dense=seg_dense,
        seg_map=seg_map,
    )
