"""The paper's primary contribution: distributed BPMF with load-balanced
bucketed sweeps and asynchronous (ring-pipelined) communication."""
from repro.core.buckets import BucketPlan, plan_buckets, workload_model
from repro.core.gibbs import BPMFState, GibbsSampler, TRAIN_ENGINES
from repro.core.sgld import DistributedSGLD, SGLDSampler
from repro.core.als import ALS, ALSState
from repro.core.hyper import NWPrior, HyperParams, default_prior, sample_normal_wishart

__all__ = [
    "BucketPlan",
    "plan_buckets",
    "workload_model",
    "BPMFState",
    "GibbsSampler",
    "SGLDSampler",
    "DistributedSGLD",
    "TRAIN_ENGINES",
    "ALS",
    "ALSState",
    "NWPrior",
    "HyperParams",
    "default_prior",
    "sample_normal_wishart",
]
