"""Distributed BPMF: ring-pipelined (async) and all-gather (sync) samplers.

The paper's central result (Sec 4.3, Fig 5-6) is that one-sided asynchronous
communication (GASPI) hides ~85% of communication behind computation while
bulk-synchronous exchange hides none. The TPU-idiomatic equivalent:

  "allgather"     : all_gather the counterpart factor matrix, then sweep —
                    all communication up front, none overlapped.
  "ring"          : the counterpart matrix stays sharded; each of P pipeline
                    steps computes partial precision contributions against
                    the currently-held block while lax.ppermute forwards it —
                    the permute of step s+1 has no data dependence on the
                    syrk of step s, so XLA's latency-hiding scheduler runs
                    them concurrently (the "both" region of the paper's
                    Fig 6). Phases stay sequential: the user phase waits for
                    the full v draw.
  "async"         : the stale-tolerant pipeline (paper Sec 4.3). BOTH phases
                    ride ONE ring scan: each step issues the next blocks'
                    ppermutes before either accumulate consumes its held
                    operand, then accumulates movie stats against the held u
                    block and user stats against the held v block. The user
                    update therefore reads the PREVIOUS sweep's v — stale by
                    exactly one draw, the bounded staleness Gibbs tolerates
                    (arXiv 2004.02561, 1503.01596): the chain decouples into
                    two interleaved samplers whose draws are each exactly
                    conditional, so the stationary distribution is unchanged
                    and only burn-in lengthens (~2x in sweeps, repaid >2x in
                    wall clock at moderate P). Halves the scan count per
                    sweep and removes the inter-phase barrier.

All modes share plans, keys, and per-item noise (folded from global item
ids), so they produce bit-comparable samples — the accuracy-parity claim of
Sec 5.2 is testable exactly: an async sweep's v draw is bit-identical to the
ring sweep's from the same state (the movie phase consumes identical
inputs); only the u draw sees the one-sweep-older v.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.gibbs import chol_subst_solve
from repro.core.hyper import (
    HyperParams,
    NWPrior,
    default_prior,
    init_hyper,
    sample_normal_wishart,
)
from repro.core.partition import GridPlan, build_grid_plan, partition_entities
from repro.data.sparse import SparseRatings

AXIS = "items"


# jax.shard_map shim (check_vma vs check_rep across jax versions) — shared
# with models/layers.py and the distributed tests
from repro.compat import shard_map as _shard_map


class DistState(NamedTuple):
    u: jax.Array          # (P, m_loc, K) user factors, sharded over AXIS
    v: jax.Array          # (P, n_loc, K)
    hyper_u: HyperParams
    hyper_v: HyperParams
    key: jax.Array
    step: jax.Array
    # async mode only (None otherwise): the v the u draw was conditioned
    # on — one sweep stale. The stale-by-one sweep interleaves two valid
    # Gibbs chains, so (u, v) at the same step are draws from DIFFERENT
    # chains whose latent rotations drift apart; predictions must pair u
    # with v_eval, the jointly-coupled sample.
    v_eval: jax.Array | None = None


# stats engines the distributed sweep supports: the einsum reference and
# the fused gather-syrk kernel (core.gibbs.ENGINES documents the family)
DIST_ENGINES = ("einsum", "fused")

# exchange modes: see the module docstring
DIST_MODES = ("ring", "allgather", "async")


def _per_item_noise(key: jax.Array, item_ids: jax.Array, k: int) -> jax.Array:
    """Noise keyed by global item id — layout-independent determinism.

    The whole id vector is folded into per-item keys in one vmapped
    threefry call, then the noise drawn in one vmapped normal
    (`jax.random.fold_in` itself accepts only scalars); under jit the pair
    fuses into a single launch. Bit-identical to folding each id
    separately — pinned by a regression test, since the ring/allgather
    parity argument depends on these exact bits.
    """
    ids = jnp.maximum(item_ids, 0)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)
    return jax.vmap(lambda kk: jax.random.normal(kk, (k,), jnp.float32))(keys)


def _accumulate_block(counter_blk, idx, val, msk, seg, seg_dense, seg_map,
                      n_loc, *, engine="einsum"):
    """Partial (prec, rhs) of local items against one counterpart block.

    einsum: gathered block + row-level einsums + segment_sum (the
    equivalence-tested reference). fused: `ops.gather_syrk_seg` — the
    counterpart block is gathered in-kernel against the dense per-block
    segment ids and the per-segment outputs scatter once through seg_map
    (slot n_loc collects the padding and is dropped).
    """
    if engine == "fused":
        from repro.kernels import ops as kops

        r = idx.shape[0]
        k = counter_blk.shape[-1]
        prec_seg, rhs_seg = kops.gather_syrk_seg(
            idx, val, msk, seg_dense, r, counter_blk
        )
        prec = jnp.zeros((n_loc + 1, k, k), jnp.float32).at[seg_map].add(
            prec_seg
        )[:n_loc]
        rhs = jnp.zeros((n_loc + 1, k), jnp.float32).at[seg_map].add(
            rhs_seg
        )[:n_loc]
        return prec, rhs
    vg = counter_blk[idx]                            # (R, W, K)
    vm = vg * msk[..., None]
    prec_rows = jnp.einsum("rwk,rwl->rkl", vm, vm, preferred_element_type=jnp.float32)
    rhs_rows = jnp.einsum("rwk,rw->rk", vm, val * msk)
    prec = jax.ops.segment_sum(prec_rows, seg, n_loc + 1)[:n_loc]
    rhs = jax.ops.segment_sum(rhs_rows, seg, n_loc + 1)[:n_loc]
    return prec, rhs


def _phase_ring(key, counter_blk, plans, item_ids, hyper, alpha, n_shards,
                engine):
    """One ring half-sweep: resample local items given sharded counterpart.

    plans: (P, R, W) arrays (this shard's slice of the grid plan) keyed by
    source block id. At ring step s, this device holds block
    (pid - s) mod P; the matching plan slice is selected dynamically.
    """
    idx_all, val_all, msk_all, seg_all, segd_all, segm_all = plans
    n_loc = item_ids.shape[0]
    k = counter_blk.shape[-1]
    pid = jax.lax.axis_index(AXIS)

    def step(carry, s):
        blk, prec, rhs = carry
        src = jnp.mod(pid - s, n_shards)
        take = lambda a: jnp.take(a, src, axis=0)
        dp, dr = _accumulate_block(
            blk, take(idx_all), take(val_all), take(msk_all), take(seg_all),
            take(segd_all), take(segm_all), n_loc, engine=engine,
        )
        # forward the block; independent of this step's accumulate -> overlap
        blk = jax.lax.ppermute(
            blk, AXIS, [(i, (i + 1) % n_shards) for i in range(n_shards)]
        )
        return (blk, prec + dp, rhs + dr), None

    prec0 = jnp.zeros((n_loc, k, k), jnp.float32)
    rhs0 = jnp.zeros((n_loc, k), jnp.float32)
    (blk, prec, rhs), _ = jax.lax.scan(
        step, (counter_blk, prec0, rhs0), jnp.arange(n_shards)
    )
    return _finish_phase(key, prec, rhs, item_ids, hyper, alpha)


def _finish_phase(key, prec, rhs, item_ids, hyper, alpha):
    """Raw accumulated stats -> posterior draw for this shard's items."""
    k = rhs.shape[-1]
    prec = hyper.lam[None] + alpha * prec
    rhs = (hyper.lam @ hyper.mu)[None] + alpha * rhs
    z = _per_item_noise(key, item_ids, k)
    new = _chol_sample(prec, rhs, z)
    return jnp.where(item_ids[:, None] >= 0, new, 0.0)


def _phase_ring_async(k_v, k_u, u_blk, v_blk, v_plans, u_plans, v_ids, u_ids,
                      hyper_v, hyper_u, alpha, n_shards, engine):
    """Both Gibbs phases fused into ONE stale-tolerant ring scan.

    Each step first issues the ppermutes that deliver step s+1's blocks —
    they read only the held (u, v) blocks, never this step's accumulates, so
    the collectives are in flight for the entire accumulate pair — then
    accumulates movie stats against the held u block and user stats against
    the held v block. v comes from the carry (previous sweep's draw): the
    user update is stale by exactly one sweep. One scan of P steps replaces
    ring mode's two, and the user phase no longer waits on the full v draw.

    The movie accumulation consumes inputs bit-identical to ring mode's, in
    the same order, so from equal states the v draw matches ring
    bit-for-bit (pinned by a parity test).
    """
    n_v = v_ids.shape[0]
    n_u = u_ids.shape[0]
    k = u_blk.shape[-1]
    pid = jax.lax.axis_index(AXIS)
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def step(carry, s):
        ub, vb, pv, rv, pu, ru = carry
        src = jnp.mod(pid - s, n_shards)
        take = lambda plans: tuple(jnp.take(a, src, axis=0) for a in plans)
        # next blocks, issued before either accumulate touches the held ones
        ub_next = jax.lax.ppermute(ub, AXIS, fwd)
        vb_next = jax.lax.ppermute(vb, AXIS, fwd)
        dpv, drv = _accumulate_block(ub, *take(v_plans), n_v, engine=engine)
        dpu, dru = _accumulate_block(vb, *take(u_plans), n_u, engine=engine)
        return (ub_next, vb_next, pv + dpv, rv + drv, pu + dpu, ru + dru), None

    init = (
        u_blk, v_blk,
        jnp.zeros((n_v, k, k), jnp.float32), jnp.zeros((n_v, k), jnp.float32),
        jnp.zeros((n_u, k, k), jnp.float32), jnp.zeros((n_u, k), jnp.float32),
    )
    (_, _, pv, rv, pu, ru), _ = jax.lax.scan(step, init, jnp.arange(n_shards))
    v_new = _finish_phase(k_v, pv, rv, v_ids, hyper_v, alpha)
    u_new = _finish_phase(k_u, pu, ru, u_ids, hyper_u, alpha)
    return v_new, u_new


def _phase_allgather(key, counter_blk, plan_full, item_ids, hyper, alpha,
                     engine):
    """Sync baseline: gather the whole counterpart, then sweep locally."""
    full = jax.lax.all_gather(counter_blk, AXIS)      # (P, n_loc, K)
    full = full.reshape(-1, full.shape[-1])
    idx, val, msk, seg, seg_dense, seg_map = plan_full
    n_loc = item_ids.shape[0]
    prec, rhs = _accumulate_block(
        full, idx, val, msk, seg, seg_dense, seg_map, n_loc, engine=engine
    )
    return _finish_phase(key, prec, rhs, item_ids, hyper, alpha)


def _chol_sample(prec, rhs, z):
    # batch-vectorized substitution (core.gibbs): XLA's batched triangular
    # solve dispatches per batch element on CPU and dominated the sweep
    return chol_subst_solve(jnp.linalg.cholesky(prec), rhs, z)


def _stats(x, valid):
    xm = jnp.where(valid[:, None], x, 0.0)
    sum_x = jax.lax.psum(xm.sum(0), AXIS)
    sum_xxt = jax.lax.psum(
        jnp.einsum("nk,nl->kl", xm, xm, preferred_element_type=jnp.float32), AXIS
    )
    n = jax.lax.psum(valid.sum(), AXIS)
    return sum_x, sum_xxt, n


def make_sweep(mesh: Mesh, mode: str, alpha: float, prior: NWPrior,
               engine: str = "einsum"):
    """shard_map'd full Gibbs sweep (both phases + fused hyper stats).

    Standalone so the production-mesh dry-run can lower it against
    ShapeDtypeStruct plans without building a real plan. `engine` picks the
    per-block stats path (DIST_ENGINES); plans are 6-tuples
    (idx, val, msk, seg, seg_dense, seg_map).
    """
    if engine not in DIST_ENGINES:
        raise ValueError(f"engine must be one of {DIST_ENGINES}, got {engine!r}")
    if mode not in DIST_MODES:
        raise ValueError(f"mode must be one of {DIST_MODES}, got {mode!r}")
    n_shards = mesh.shape[AXIS]

    def sweep(state: DistState, u_plans, v_plans, u_ids, v_ids):
        key, k_hv, k_v, k_hu, k_u = jax.random.split(state.key, 5)
        # strip the sharded leading axis (local block views)
        u_plans = tuple(a[0] for a in u_plans)
        v_plans = tuple(a[0] for a in v_plans)
        u_ids = u_ids[0]
        v_ids = v_ids[0]

        # both hyper draws read the PREVIOUS sweep's factors in every mode
        # (sync modes too: su below uses state.u, not u_new) — so async can
        # hoist them above its fused scan without changing a single bit
        sv = _stats(state.v[0], v_ids >= 0)
        hyper_v = sample_normal_wishart(k_hv, *sv, prior)
        if mode == "async":
            su = _stats(state.u[0], u_ids >= 0)
            hyper_u = sample_normal_wishart(k_hu, *su, prior)
            v_new, u_new = _phase_ring_async(
                k_v, k_u, state.u[0], state.v[0], v_plans, u_plans,
                v_ids, u_ids, hyper_v, hyper_u, alpha, n_shards, engine,
            )
            return DistState(
                u=u_new[None], v=v_new[None],
                hyper_u=hyper_u, hyper_v=hyper_v,
                key=key, step=state.step + 1,
                v_eval=state.v,   # u_new conditioned on this v
            )

        # movies phase
        if mode == "ring":
            v_new = _phase_ring(k_v, state.u[0], v_plans, v_ids, hyper_v,
                                alpha, n_shards, engine)
        else:
            v_new = _phase_allgather(k_v, state.u[0], v_plans, v_ids, hyper_v,
                                     alpha, engine)

        su = _stats(state.u[0], u_ids >= 0)
        hyper_u = sample_normal_wishart(k_hu, *su, prior)
        if mode == "ring":
            u_new = _phase_ring(k_u, v_new, u_plans, u_ids, hyper_u,
                                alpha, n_shards, engine)
        else:
            u_new = _phase_allgather(k_u, v_new, u_plans, u_ids, hyper_u,
                                     alpha, engine)

        return DistState(
            u=u_new[None], v=v_new[None], hyper_u=hyper_u, hyper_v=hyper_v,
            key=key, step=state.step + 1,
        )

    state_spec = DistState(
        u=P(AXIS), v=P(AXIS),
        hyper_u=HyperParams(P(), P()), hyper_v=HyperParams(P(), P()),
        key=P(), step=P(),
        v_eval=P(AXIS) if mode == "async" else None,
    )
    plans_in = tuple(P(AXIS) for _ in range(6))
    return _shard_map(
        sweep,
        mesh=mesh,
        in_specs=(state_spec, plans_in, plans_in, P(AXIS), P(AXIS)),
        out_specs=state_spec,
        check_vma=False,
    )


class DistributedBPMF:
    """Multi-device BPMF over a 1-D mesh, paper Sec 4 faithful."""

    def __init__(
        self,
        ratings: SparseRatings,
        test: SparseRatings | None = None,
        *,
        mesh: Mesh | None = None,
        k: int = 32,
        alpha: float = 1.5,
        width: int | str = 32,       # "auto": degree-aware grid width
        mode: str = "ring",          # ring | allgather | async (DIST_MODES)
        engine: str = "einsum",      # einsum | fused (DIST_ENGINES)
        seed: int = 0,
    ):
        if mode not in DIST_MODES:
            raise ValueError(f"mode must be one of {DIST_MODES}, got {mode!r}")
        if mesh is None:
            n = len(jax.devices())
            mesh = jax.make_mesh((n,), (AXIS,))
        self.mesh = mesh
        self.n_shards = mesh.shape[AXIS]
        self.k = k
        self.alpha = alpha
        self.mode = mode
        self.engine = engine
        self.global_mean = ratings.mean()
        self.test = test
        centered = ratings.centered()

        p = self.n_shards
        self.u_part = partition_entities(centered.degrees(0), p)
        self.v_part = partition_entities(centered.degrees(1), p)
        # user-update plan: rows = users, counterpart = movies
        self.u_plan = build_grid_plan(centered, self.u_part, self.v_part, width=width)
        self.v_plan = build_grid_plan(
            centered.transpose(), self.v_part, self.u_part, width=width
        )
        self.prior = default_prior(k)
        self._sweep = self._build_sweep()

    # ------------------------------------------------------------------
    def _device_plans(self, plan: GridPlan):
        """Grid plan arrays, sharded over dim 0 (the owning shard)."""
        sh = NamedSharding(self.mesh, P(AXIS))
        to_dev = lambda a: jax.device_put(jnp.asarray(a), sh)
        ring = (
            to_dev(plan.indices),
            to_dev(plan.values),
            to_dev(plan.mask),
            to_dev(plan.seg),
            to_dev(plan.seg_dense),
            to_dev(plan.seg_map),
        )
        ids = to_dev(plan.item_ids)
        return ring, ids

    def _flat_plans(self, plan: GridPlan):
        """Per-shard flattened plan vs the FULL counterpart (allgather mode).

        Block-local indices are rebased to gathered-global offsets q*n_loc+i.
        The per-block dense segment ids are rebased the same way (cumulative
        per-block segment counts), so the flattened seg_dense stays dense
        and nondecreasing — the fused engine's invariant.
        """
        p, _, r, w = plan.indices.shape
        offs = (np.arange(p) * plan.n_counter_loc)[None, :, None, None]
        idx = plan.indices + offs.astype(np.int32)

        # flatten dense segments across the q blocks of each shard row
        n_dense = plan.seg_dense[:, :, -1] + 1            # (P, P) segs per block
        seg_dense = np.zeros((p, p * r), np.int32)
        seg_map = np.full((p, p * r), plan.n_loc, np.int32)
        for pp in range(p):
            off = 0
            pos = 0
            for q in range(p):
                d = int(n_dense[pp, q])
                seg_dense[pp, q * r:(q + 1) * r] = plan.seg_dense[pp, q] + off
                seg_map[pp, pos:pos + d] = plan.seg_map[pp, q, :d]
                off += d
                pos += d

        sh = NamedSharding(self.mesh, P(AXIS))
        to_dev = lambda a: jax.device_put(jnp.asarray(a), sh)
        return (
            to_dev(idx.reshape(p, p * r, w)),
            to_dev(plan.values.reshape(p, p * r, w)),
            to_dev(plan.mask.reshape(p, p * r, w)),
            to_dev(plan.seg.reshape(p, p * r)),
            to_dev(seg_dense),
            to_dev(seg_map),
        )

    def _build_sweep(self):
        self.u_ring, self.u_ids = self._device_plans(self.u_plan)
        self.v_ring, self.v_ids = self._device_plans(self.v_plan)
        if self.mode == "allgather":
            self.u_flat = self._flat_plans(self.u_plan)
            self.v_flat = self._flat_plans(self.v_plan)

        mapped = make_sweep(self.mesh, self.mode, self.alpha, self.prior,
                            engine=self.engine)
        # ring and async share the per-block grid plans; only allgather
        # needs the flattened full-counterpart layout
        u_plans = self.u_flat if self.mode == "allgather" else self.u_ring
        v_plans = self.v_flat if self.mode == "allgather" else self.v_ring

        @jax.jit
        def run(state):
            return mapped(state, u_plans, v_plans, self.u_ids, self.v_ids)

        return run

    # ------------------------------------------------------------------
    def init(self, seed: int = 0) -> DistState:
        key = jax.random.PRNGKey(seed)
        ku, kv, key = jax.random.split(key, 3)
        p = self.n_shards
        sh = NamedSharding(self.mesh, P(AXIS))
        # replicate the small leaves explicitly: the sweep's outputs carry
        # these shardings, so an init state laid out any other way makes the
        # SECOND sweep recompile — the whole first-sweeps timing window used
        # to be compile time (the fig5 "efficiency plateau" artifact)
        rep = NamedSharding(self.mesh, P())
        u = 0.1 * jax.random.normal(ku, (p, self.u_part.n_loc, self.k), jnp.float32)
        v = 0.1 * jax.random.normal(kv, (p, self.v_part.n_loc, self.k), jnp.float32)
        v_dev = jax.device_put(v, sh)
        return DistState(
            u=jax.device_put(u, sh),
            v=v_dev,
            hyper_u=jax.device_put(init_hyper(self.k), rep),
            hyper_v=jax.device_put(init_hyper(self.k), rep),
            key=jax.device_put(key, rep),
            step=jax.device_put(jnp.zeros((), jnp.int32), rep),
            v_eval=v_dev if self.mode == "async" else None,
        )

    def sweep(self, state: DistState) -> DistState:
        return self._sweep(state)

    def gather_factors(self, state: DistState, *, coupled: bool = True):
        """(M, K), (N, K) in global entity order (host-side, for eval).

        In async mode the u draw conditioned on the PREVIOUS sweep's v, so
        the jointly-coupled posterior sample — the one predictions must
        use — is (u, v_eval). The fresh-but-uncoupled v (what the next
        sweep consumes, and what ring's first sweep matches bit-for-bit)
        is returned with coupled=False.
        """
        v_src = state.v if (state.v_eval is None or not coupled) else state.v_eval
        u = np.asarray(state.u).reshape(-1, self.k)
        v = np.asarray(v_src).reshape(-1, self.k)
        m = self.u_part.shard.shape[0]
        n = self.v_part.shard.shape[0]
        uo = np.zeros((m, self.k), np.float32)
        vo = np.zeros((n, self.k), np.float32)
        uo[self.u_part.ids[self.u_part.ids >= 0]] = u[
            (self.u_part.ids >= 0).reshape(-1)
        ]
        vo[self.v_part.ids[self.v_part.ids >= 0]] = v[
            (self.v_part.ids >= 0).reshape(-1)
        ]
        return uo, vo

    def rmse(self, state: DistState) -> float:
        if self.test is None:
            return float("nan")
        u, v = self.gather_factors(state)
        pred = np.einsum("nk,nk->n", u[self.test.rows], v[self.test.cols]) + self.global_mean
        return float(np.sqrt(np.mean((pred - self.test.vals) ** 2)))

    # run() bounds the async dispatch queue: XLA's CPU collectives
    # rendezvous per run id, and a deep enough pipeline of un-synced
    # collective programs lets the per-device threads skew until three
    # ranks wait on a rendezvous the fourth never joins (observed as a
    # hard hang past ~300 queued SGLD steps on forced host devices).
    # Draining every sync_every dispatches keeps the threads aligned at
    # negligible cost (a Gibbs sweep dwarfs the round trip; SGLD steps
    # lose ~nothing at depth 16 vs unbounded).
    sync_every = 16
    verbose_every = 5

    def run(self, n_sweeps: int, seed: int = 0, verbose: bool = False) -> DistState:
        state = self.init(seed)
        for i in range(n_sweeps):
            state = self.sweep(state)
            if i % self.sync_every == self.sync_every - 1:
                jax.block_until_ready(state.u)
            if verbose and (i % self.verbose_every == 0 or i == n_sweeps - 1):
                print(f"sweep {i:3d} rmse {self.rmse(state):.4f}")
        jax.block_until_ready(state.u)
        return state
