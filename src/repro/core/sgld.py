"""Minibatch SGLD trainers: per-step cost decoupled from dataset size.

Exact Gibbs (core.gibbs / core.distributed) touches every rating each
sweep, so training cost grows linearly with the dataset no matter how fast
the per-rating kernels get. Stochastic gradient Langevin dynamics (Welling
& Teh 2011; distributed for matrix factorization by Ahn et al., arXiv
1503.01596) replaces the exact conditional draw with a noisy gradient step

    x <- x + (eps/2) G (grad log p(x | rest))  +  sqrt(eps G) z,   z ~ N(0, I)

whose likelihood gradient is estimated from a minibatch of rating-plan
rows and rescaled by the inverse inclusion probability, so each step costs
O(|minibatch|) regardless of |ratings|. Crucially the samplers here are
NOT a fork of the data layout: minibatch rows are subsampled from the SAME
bucketed plans (`core.buckets`) and grid plans (`core.partition`) the
Gibbs engines sweep, so the planner, the distributed exchange
(ring/allgather/async), and the serving hand-off all carry over.

Three deliberate choices, each load-bearing:

* Sampling is uniform-with-replacement over PLAN ROWS (`jax.random.randint`),
  not a permutation — drawing s row ids is O(s), while a permutation is
  O(rows) and would silently reintroduce the dataset-size term this engine
  exists to remove. A row of width w carries up to w ratings of one
  entity; scaling each sampled row's gradient by rows/s makes the
  estimator exactly unbiased for the full-plan gradient (padding rows are
  masked to zero, identical to the Gibbs treatment).
* The per-entity preconditioner takes its SHAPE from the degree profile
  the balanced planner fits widths to — G_i = 1 / (lam_bar + alpha d_i
  sig2_bar) — but calibrates the two amplitudes online: lam_bar is the
  mean diagonal of the current hyper precision and sig2_bar the current
  per-coordinate second moment of the counterpart factors. Factor
  coordinates live at scale ~1/sqrt(K), so a fixed 1/(1 + alpha d) gain
  would understate the prior curvature by ~K and diverge. As in pSGLD,
  the state-dependent-preconditioner drift term is ignored.
* Hyperparameters keep their EXACT Normal-Wishart Gibbs draw each step
  (sufficient statistics are O(entities), not O(ratings)) — the mixed
  Gibbs/SGLD scheme of Ahn et al. Half-steps alternate exactly like the
  Gibbs sweep: movies from (minibatch, U), users from (minibatch, V).

`SGLDSampler` subclasses `GibbsSampler`, inheriting plans, the
posterior-predictive accumulator, and the serving hand-off (`run(store=...,
publish=...)` retains and publishes draws through the identical
SAMPLE_KEYS schema). `DistributedSGLD` subclasses `DistributedBPMF`,
riding the same block partition and all three exchange modes; async mode
keeps the stale-by-one `v_eval` semantics.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map as _shard_map
from repro.core.distributed import (
    AXIS,
    DIST_MODES,
    DistributedBPMF,
    DistState,
    _per_item_noise,
    _stats,
)
from repro.core.gibbs import DeviceBucket, GibbsSampler, factor_stats
from repro.core.hyper import HyperParams, NWPrior, sample_normal_wishart
from repro.data.sparse import SparseRatings
from repro.optim.schedule import sgld_step_schedule

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# shared numerics (single-host and distributed phases both route through
# these; the exactness unit tests pin them against dense numpy)
# ---------------------------------------------------------------------------

def row_grads(factors, counterpart, idx, val, msk, items):
    """Per-row likelihood gradient contributions for the row's owning entity.

    For plan rows (idx (s, w) counterpart ids, val/msk (s, w)) owned by
    entities `items` (s,), returns (s, K) rows of
        g_row = sum_w msk * (r - u_item . v_j) * v_j
    i.e. d/du of -0.5 * sum (r - u.v)^2 restricted to the row's ratings.
    The caller scatter-adds rows into their entities and scales by alpha
    and the inverse inclusion probability.
    """
    vg = counterpart[idx]                               # (s, w, K)
    ug = factors[items]                                 # (s, K)
    pred = jnp.einsum("sk,swk->sw", ug, vg)
    resid = (val - pred) * msk
    return jnp.einsum("sw,swk->sk", resid, vg)


def minibatch_likelihood_grad(
    key: jax.Array,
    factors: jax.Array,
    counterpart: jax.Array,
    buckets: Sequence[DeviceBucket],
    n_rows: Sequence[int],
    scales: Sequence[float],
) -> jax.Array:
    """Unbiased minibatch estimate of the full-plan likelihood gradient.

    Per bucket b, draws n_rows[b] row ids uniformly with replacement
    (O(n_rows), dataset-size independent) and scales the summed row
    gradients by scales[b] = rows_b / n_rows[b]. A bucket whose quota
    covers every row short-circuits to the exact sum over arange(rows) —
    so a large enough minibatch degrades gracefully to full-gradient
    Langevin, which is what the exactness tests pin.
    """
    g = jnp.zeros_like(factors)
    for b, (bucket, s_b, scale) in enumerate(zip(buckets, n_rows, scales)):
        r_total = bucket.indices.shape[0]
        if s_b >= r_total:
            rows = jnp.arange(r_total)
        else:
            kb = jax.random.fold_in(key, b)
            rows = jax.random.randint(kb, (s_b,), 0, r_total)
        items = bucket.seg_item_ids[bucket.seg_ids[rows]]
        g_rows = row_grads(
            factors, counterpart,
            bucket.indices[rows], bucket.values[rows], bucket.mask[rows],
            items,
        )
        g = g.at[items].add(scale * g_rows)
    return g


def precond_gain(degrees, alpha, lam_bar, sig2_bar):
    """Per-entity SGLD gain G_i = 1 / (lam_bar + alpha * d_i * sig2_bar).

    `degrees` is the planner's per-entity rating-count profile; `lam_bar`
    (mean diagonal of the hyper precision) and `sig2_bar` (per-coordinate
    second moment of the counterpart factors) calibrate the prior and
    likelihood curvature scales online. G_i approximates the inverse
    per-coordinate posterior precision, so the effective per-coordinate
    step eps * G_i * P_i stays ~eps across the degree spectrum.
    """
    return 1.0 / (lam_bar + alpha * degrees * sig2_bar)


def langevin_update(key, factors, grad, gain, eps, temperature, clip=3.0):
    """x + (eps/2) G grad + sqrt(eps G T) z, gain per entity (broadcast over K).

    The drift is clipped elementwise to `clip` times the T=1 noise scale
    sqrt(eps G) — a scale-free trust region. Inverse-inclusion scaling
    makes rare wide-row draws kick popular entities by multiples of the
    factor scale (variance ~ scale * row energy), and un-clipped those
    kicks feed back through the residuals into a runaway. At equilibrium
    the typical drift is ~sqrt(eps) noise-scales, far inside the clip, so
    the stationary distribution is untouched; only transient and
    outlier-minibatch kicks are bounded. clip=None disables.
    """
    z = jax.random.normal(key, factors.shape, factors.dtype)
    step = eps * gain[:, None]
    drift = 0.5 * step * grad
    if clip is not None:
        # tied to the T=1 noise scale, NOT the tempered one — a cooled
        # chain (temperature < 1, e.g. during warmup) must keep its drift
        lim = clip * jnp.sqrt(step)
        drift = jnp.clip(drift, -lim, lim)
    return factors + drift + jnp.sqrt(step * temperature) * z


def _lam_bar(hyper: HyperParams) -> jax.Array:
    k = hyper.lam.shape[-1]
    return jnp.trace(hyper.lam) / k


def effective_temperature(step, temperature: float, temp_warmup: int):
    """Annealed temperature: ramps 0 -> `temperature` linearly over the
    first `temp_warmup` steps (0 disables — constant temperature).

    During the ramp the chain is preconditioned minibatch SGD with damped
    injected noise — the stochastic-optimization phase of Welling & Teh's
    SGLD picture — which descends to the posterior bulk far faster than
    the full-temperature chain (the injected noise otherwise dominates
    the early drift signal). Annealed steps land inside burn-in, which is
    discarded anyway; only the T = `temperature` regime is sampled from."""
    if temp_warmup <= 0:
        return temperature
    ramp = jnp.minimum(1.0, step.astype(jnp.float32) / temp_warmup)
    return temperature * ramp


def data_init_scale(vals: np.ndarray, k: int) -> float:
    """Init-factor std matched to the data: k * s^4 ~= var(ratings), so
    u.v predictions start at the ratings' scale instead of ~0.

    The Gibbs engines don't care (one exact sweep snaps factors to the
    conditional posterior regardless of init), but SGLD bootstraps
    through a feedback loop — small factors -> large hyper precision ->
    tiny preconditioned gain -> factors grow slowly — that a 0.1-scale
    init turns into hundreds of wasted steps on well-populated data.
    Floored at the Gibbs 0.1 so degenerate/empty data keeps the old
    behavior."""
    var = float(np.var(vals)) if len(vals) else 0.0
    return max(0.1, (max(var, 1e-8) / k) ** 0.25)


def alloc_minibatch(plan_host, lanes_budget: int):
    """Split a lane budget across a plan's buckets, proportional to each
    bucket's share of total padded lanes (rows * width): wide buckets get
    fewer rows so every bucket contributes ~equal compute. Returns
    (rows_per_bucket, inverse_inclusion_scales); a bucket capped at its
    own row count gets scale 1.0 (exact)."""
    rows = np.array([b.indices.shape[0] for b in plan_host.buckets], np.float64)
    lanes = rows * np.array([b.width for b in plan_host.buckets], np.float64)
    total = lanes.sum()
    n_rows, scales = [], []
    for b, r, l in zip(plan_host.buckets, rows, lanes):
        s = int(min(r, max(1.0, round(lanes_budget * l / total / b.width))))
        n_rows.append(s)
        scales.append(float(r) / s)
    return tuple(n_rows), tuple(scales)


# ---------------------------------------------------------------------------
# single-host sampler
# ---------------------------------------------------------------------------

class SGLDSampler(GibbsSampler):
    """Single-host minibatch SGLD over the same bucketed plans as Gibbs.

    `minibatch` is a PADDED-LANE budget per half-step: each bucket samples
    ~minibatch * share_of_lanes / width rows, so the per-step gather and
    einsum cost tracks the budget, not the dataset (sum s_b * w_b ~=
    minibatch). Steps are ~|ratings| / minibatch cheaper than a Gibbs
    sweep; run correspondingly more of them (`burn_in` and `thin` are in
    steps). Everything downstream of the chain — posterior-predictive
    RMSE, SampleStore retention, PublicationChannel publishes — is
    inherited unchanged from GibbsSampler.
    """

    verbose_every = 50

    def __init__(
        self,
        ratings: SparseRatings,
        test: SparseRatings | None = None,
        *,
        k: int = 64,
        alpha: float = 1.5,
        burn_in: int = 200,
        widths="balanced",
        minibatch: int = 4096,
        step_size: float = 0.3,
        step_decay: float = 0.33,
        step_t0: float = 100.0,
        temperature: float = 1.0,
        temp_warmup: int = 0,
        precondition: bool = True,
        clip: float | None = 3.0,
        hyper_every: int = 1,
        accum_every: int = 1,
        dtype=jnp.float32,
    ):
        self.minibatch = int(minibatch)
        self.step_size = float(step_size)
        self.step_decay = float(step_decay)
        self.step_t0 = float(step_t0)
        self.temperature = float(temperature)
        self.temp_warmup = int(temp_warmup)
        self.precondition = bool(precondition)
        self.clip = None if clip is None else float(clip)
        # Per-step costs the minibatch does NOT bound, thinned under
        # lax.cond so skipped steps pay nothing: the exact NW hyper draw
        # is O(entities * K^2) (sufficient-stats syrk) and the
        # posterior-predictive accumulation is O(|test| * K). Both are
        # slowly-mixing relative to the factor chain, so drawing hypers /
        # accumulating every few steps is standard MCMC thinning, not an
        # approximation of the stationary distribution.
        self.hyper_every = int(hyper_every)
        self.accum_every = int(accum_every)
        super().__init__(
            ratings, test, k=k, alpha=alpha, burn_in=burn_in, widths=widths,
            engine="einsum", dtype=dtype,
        )
        self.user_rows, self.user_scales = alloc_minibatch(
            self.user_plan_host, self.minibatch
        )
        self.item_rows, self.item_scales = alloc_minibatch(
            self.item_plan_host, self.minibatch
        )
        # the planner's degree profile, reused as the preconditioner shape
        self.deg_u = jnp.asarray(ratings.degrees(0).astype(np.float32))
        self.deg_v = jnp.asarray(ratings.degrees(1).astype(np.float32))
        self.init_scale = data_init_scale(ratings.vals, self.k)

    def init(self, seed: int = 0):
        state = super().init(seed)
        s = self.init_scale / 0.1
        return state._replace(u=state.u * s, v=state.v * s)

    def _gain(self, degrees, hyper, counterpart):
        if not self.precondition:
            return jnp.ones_like(degrees)
        # per-coordinate second moment of the counterpart = the trace of
        # its sum_xxt / (n k), but computed in O(n k) — no syrk needed
        sig2 = jnp.mean(counterpart * counterpart)
        return precond_gain(degrees, self.alpha, _lam_bar(hyper), sig2)

    # --- one SGLD step (two preconditioned Langevin half-steps) ---
    def _sweep_impl(self, state):
        key, k_hv, k_hu, k_sv, k_su, k_nv, k_nu = jax.random.split(state.key, 7)
        eps = sgld_step_schedule(
            state.step, peak=self.step_size, decay=self.step_decay,
            t0=self.step_t0,
        )
        temp = effective_temperature(
            state.step, self.temperature, self.temp_warmup
        )

        # exact Normal-Wishart hyper draws from the previous factors (the
        # mixed scheme: sufficient stats are O(entities), never
        # O(ratings)); thinned every hyper_every steps behind a cond so
        # the O(entities * K^2) stats syrk is skipped entirely in between
        def draw_hypers(_):
            sv = factor_stats(state.v)
            su = factor_stats(state.u)
            return (
                sample_normal_wishart(k_hv, sv.sum_x, sv.sum_xxt, sv.n, self.prior),
                sample_normal_wishart(k_hu, su.sum_x, su.sum_xxt, su.n, self.prior),
            )

        hyper_v, hyper_u = jax.lax.cond(
            jnp.mod(state.step, self.hyper_every) == 0,
            draw_hypers, lambda _: (state.hyper_v, state.hyper_u), None,
        )

        # movies half-step: minibatch gradient of V given U
        g_lik = minibatch_likelihood_grad(
            k_sv, state.v, state.u, self.item_buckets,
            self.item_rows, self.item_scales,
        )
        grad_v = self.alpha * g_lik - (state.v - hyper_v.mu) @ hyper_v.lam
        v_new = langevin_update(
            k_nv, state.v, grad_v,
            self._gain(self.deg_v, hyper_v, state.u), eps, temp,
            clip=self.clip,
        )

        # users half-step: minibatch gradient of U given the new V
        g_lik = minibatch_likelihood_grad(
            k_su, state.u, v_new, self.user_buckets,
            self.user_rows, self.user_scales,
        )
        grad_u = self.alpha * g_lik - (state.u - hyper_u.mu) @ hyper_u.lam
        u_new = langevin_update(
            k_nu, state.u, grad_u,
            self._gain(self.deg_u, hyper_u, v_new), eps, temp,
            clip=self.clip,
        )

        # posterior-predictive accumulation, thinned: the O(|test| * K)
        # einsum runs only on accumulated steps (cond, not where — the
        # skipped branch must cost nothing for per-step cost to stay
        # decoupled from |test|)
        collect = (state.step >= self.burn_in) & (
            jnp.mod(state.step - self.burn_in, self.accum_every) == 0
        )

        def accum(carry):
            ps, pc = carry
            preds = (
                jnp.einsum("nk,nk->n", u_new[self.test_rows], v_new[self.test_cols])
                + self.global_mean
            )
            return ps + preds, pc + 1

        pred_sum, pred_count = jax.lax.cond(
            collect, accum, lambda c: c, (state.pred_sum, state.pred_count)
        )

        return state._replace(
            u=u_new, v=v_new, hyper_u=hyper_u, hyper_v=hyper_v,
            key=key, step=state.step + 1,
            pred_sum=pred_sum, pred_count=pred_count,
        )


# ---------------------------------------------------------------------------
# distributed sampler: same grid partition + exchange modes as Gibbs
# ---------------------------------------------------------------------------

class SGLDConfig(NamedTuple):
    step_size: float
    step_decay: float
    step_t0: float
    temperature: float
    temp_warmup: int
    u_rows: int          # sampled rows per (shard, block) in the user phase
    v_rows: int
    precondition: bool
    clip: float | None


def _sgld_grad_block(factors_pad, counter_blk, idx, val, msk, seg, n_loc,
                     key, s_rows):
    """Scaled minibatch gradient of local items against one counterpart
    block. `factors_pad` is the local factor block with a zero pad slot
    appended (seg == n_loc rows are plan padding; their msk is zero, so
    they contribute nothing — sampling them merely wastes a lane, the
    same deal the Gibbs engines accept)."""
    r_total = idx.shape[0]
    if s_rows < r_total:
        rows = jax.random.randint(key, (s_rows,), 0, r_total)
        scale = r_total / s_rows
        idx, val, msk, seg = idx[rows], val[rows], msk[rows], seg[rows]
    else:
        scale = 1.0
    k = counter_blk.shape[-1]
    g_rows = row_grads(factors_pad, counter_blk, idx, val, msk, seg)
    g = jnp.zeros((n_loc + 1, k), jnp.float32).at[seg].add(g_rows)
    return scale * g[:n_loc]


def _pad_slot(factors_loc):
    k = factors_loc.shape[-1]
    return jnp.concatenate(
        [factors_loc, jnp.zeros((1, k), factors_loc.dtype)]
    )


def _sgld_phase_ring(key_sel, counter_blk, plans, factors_loc, n_shards,
                     s_rows):
    """Accumulate the minibatch likelihood gradient over the P ring steps.

    Identical overlap structure to the Gibbs ring phase: the ppermute of
    step s+1 has no data dependence on step s's gradient block, so the
    collective hides behind the compute. Selection keys fold (shard, ring
    step) into the phase key — distinct blocks draw independent rows.
    """
    idx_all, val_all, msk_all, seg_all = plans[:4]
    n_loc = factors_loc.shape[0]
    k = factors_loc.shape[-1]
    pid = jax.lax.axis_index(AXIS)
    f_pad = _pad_slot(factors_loc)
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def step(carry, s):
        blk, g = carry
        src = jnp.mod(pid - s, n_shards)
        take = lambda a: jnp.take(a, src, axis=0)
        kb = jax.random.fold_in(jax.random.fold_in(key_sel, pid), s)
        dg = _sgld_grad_block(
            f_pad, blk, take(idx_all), take(val_all), take(msk_all),
            take(seg_all), n_loc, kb, s_rows,
        )
        blk = jax.lax.ppermute(blk, AXIS, fwd)
        return (blk, g + dg), None

    g0 = jnp.zeros((n_loc, k), jnp.float32)
    (_, g), _ = jax.lax.scan(step, (counter_blk, g0), jnp.arange(n_shards))
    return g


def _sgld_phase_allgather(key_sel, counter_blk, plan_full, factors_loc,
                          n_shards, s_rows):
    """Sync baseline: gather the whole counterpart, one flat-plan draw."""
    full = jax.lax.all_gather(counter_blk, AXIS)
    full = full.reshape(-1, full.shape[-1])
    idx, val, msk, seg = plan_full[:4]
    n_loc = factors_loc.shape[0]
    pid = jax.lax.axis_index(AXIS)
    kb = jax.random.fold_in(key_sel, pid)
    return _sgld_grad_block(
        _pad_slot(factors_loc), full, idx, val, msk, seg, n_loc, kb,
        n_shards * s_rows,
    )


def _sgld_phase_async(kv_sel, ku_sel, u_blk, v_blk, v_plans, u_plans,
                      v_loc, u_loc, n_shards, v_rows, u_rows):
    """Both half-step gradients fused into ONE stale-tolerant ring scan.

    As in the Gibbs async mode, each step issues the next blocks'
    ppermutes before either gradient consumes its held operand, and the
    user gradient reads the PREVIOUS step's v (the carry) — stale by
    exactly one SGLD step, far inside the staleness Gibbs itself
    tolerates. The caller pairs the returned u with v_eval = the stale v.
    """
    n_v = v_loc.shape[0]
    n_u = u_loc.shape[0]
    k = u_blk.shape[-1]
    pid = jax.lax.axis_index(AXIS)
    fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    vp = _pad_slot(v_loc)
    up = _pad_slot(u_loc)

    def step(carry, s):
        ub, vb, gv, gu = carry
        src = jnp.mod(pid - s, n_shards)
        take = lambda plans: tuple(jnp.take(a, src, axis=0) for a in plans[:4])
        ub_next = jax.lax.ppermute(ub, AXIS, fwd)
        vb_next = jax.lax.ppermute(vb, AXIS, fwd)
        kbv = jax.random.fold_in(jax.random.fold_in(kv_sel, pid), s)
        kbu = jax.random.fold_in(jax.random.fold_in(ku_sel, pid), s)
        dgv = _sgld_grad_block(vp, ub, *take(v_plans), n_v, kbv, v_rows)
        dgu = _sgld_grad_block(up, vb, *take(u_plans), n_u, kbu, u_rows)
        return (ub_next, vb_next, gv + dgv, gu + dgu), None

    init = (
        u_blk, v_blk,
        jnp.zeros((n_v, k), jnp.float32), jnp.zeros((n_u, k), jnp.float32),
    )
    (_, _, gv, gu), _ = jax.lax.scan(step, init, jnp.arange(n_shards))
    return gv, gu


def _sgld_finish(k_noise, factors, g_lik, item_ids, hyper, alpha, gain,
                 eps, temperature, clip=3.0):
    """Gradient + prior + per-item noise -> preconditioned Langevin step.

    Noise is keyed by GLOBAL item id (`_per_item_noise`), so like the
    Gibbs modes the update is layout-independent; pad slots (id < 0) are
    zeroed after the step. The drift carries the same noise-std trust
    region as `langevin_update` (see there for why)."""
    grad = alpha * g_lik - (factors - hyper.mu) @ hyper.lam
    z = _per_item_noise(k_noise, item_ids, factors.shape[-1])
    step = eps * gain[:, None]
    drift = 0.5 * step * grad
    if clip is not None:
        lim = clip * jnp.sqrt(step)
        drift = jnp.clip(drift, -lim, lim)
    new = factors + drift + jnp.sqrt(step * temperature) * z
    return jnp.where(item_ids[:, None] >= 0, new, 0.0)


def make_sgld_sweep(mesh: Mesh, mode: str, alpha: float, prior: NWPrior,
                    cfg: SGLDConfig):
    """shard_map'd SGLD step over grid plans: peer of distributed.make_sweep.

    Plans are the same 6-tuples the Gibbs sweep takes (only idx/val/msk/seg
    are consumed — gradients need no dense-segment relabeling); the two
    extra operands are the per-shard degree vectors feeding the
    preconditioner."""
    if mode not in DIST_MODES:
        raise ValueError(f"mode must be one of {DIST_MODES}, got {mode!r}")
    n_shards = mesh.shape[AXIS]

    def sweep(state: DistState, u_plans, v_plans, u_ids, v_ids, u_deg, v_deg):
        key, k_hv, k_hu, k_sv, k_su, k_nv, k_nu = jax.random.split(state.key, 7)
        u_plans = tuple(a[0] for a in u_plans)
        v_plans = tuple(a[0] for a in v_plans)
        u_ids, v_ids = u_ids[0], v_ids[0]
        u_deg, v_deg = u_deg[0], v_deg[0]
        eps = sgld_step_schedule(
            state.step, peak=cfg.step_size, decay=cfg.step_decay,
            t0=cfg.step_t0,
        )
        temp = effective_temperature(
            state.step, cfg.temperature, cfg.temp_warmup
        )

        # exact hyper draws from psum'd sufficient stats (previous factors)
        sv = _stats(state.v[0], v_ids >= 0)
        hyper_v = sample_normal_wishart(k_hv, *sv, prior)
        su = _stats(state.u[0], u_ids >= 0)
        hyper_u = sample_normal_wishart(k_hu, *su, prior)

        def gain(deg, hyper, counter_stats):
            if not cfg.precondition:
                return jnp.ones_like(deg)
            _, sum_xxt, n = counter_stats
            sig2 = jnp.trace(sum_xxt) / (n * state.u.shape[-1])
            return precond_gain(deg, alpha, _lam_bar(hyper), sig2)

        g_v = gain(v_deg, hyper_v, su)
        g_u = gain(u_deg, hyper_u, sv)

        if mode == "async":
            glv, glu = _sgld_phase_async(
                k_sv, k_su, state.u[0], state.v[0], v_plans, u_plans,
                state.v[0], state.u[0], n_shards, cfg.v_rows, cfg.u_rows,
            )
            v_new = _sgld_finish(k_nv, state.v[0], glv, v_ids, hyper_v,
                                 alpha, g_v, eps, temp, clip=cfg.clip)
            u_new = _sgld_finish(k_nu, state.u[0], glu, u_ids, hyper_u,
                                 alpha, g_u, eps, temp, clip=cfg.clip)
            return DistState(
                u=u_new[None], v=v_new[None],
                hyper_u=hyper_u, hyper_v=hyper_v,
                key=key, step=state.step + 1,
                v_eval=state.v,   # u_new's gradient read this v
            )

        if mode == "ring":
            glv = _sgld_phase_ring(k_sv, state.u[0], v_plans, state.v[0],
                                   n_shards, cfg.v_rows)
        else:
            glv = _sgld_phase_allgather(k_sv, state.u[0], v_plans,
                                        state.v[0], n_shards, cfg.v_rows)
        v_new = _sgld_finish(k_nv, state.v[0], glv, v_ids, hyper_v, alpha,
                             g_v, eps, temp, clip=cfg.clip)

        if mode == "ring":
            glu = _sgld_phase_ring(k_su, v_new, u_plans, state.u[0],
                                   n_shards, cfg.u_rows)
        else:
            glu = _sgld_phase_allgather(k_su, v_new, u_plans, state.u[0],
                                        n_shards, cfg.u_rows)
        u_new = _sgld_finish(k_nu, state.u[0], glu, u_ids, hyper_u, alpha,
                             g_u, eps, temp, clip=cfg.clip)

        return DistState(
            u=u_new[None], v=v_new[None], hyper_u=hyper_u, hyper_v=hyper_v,
            key=key, step=state.step + 1,
        )

    state_spec = DistState(
        u=P(AXIS), v=P(AXIS),
        hyper_u=HyperParams(P(), P()), hyper_v=HyperParams(P(), P()),
        key=P(), step=P(),
        v_eval=P(AXIS) if mode == "async" else None,
    )
    plans_in = tuple(P(AXIS) for _ in range(6))
    return _shard_map(
        sweep,
        mesh=mesh,
        in_specs=(state_spec, plans_in, plans_in, P(AXIS), P(AXIS),
                  P(AXIS), P(AXIS)),
        out_specs=state_spec,
        check_vma=False,
    )


class DistributedSGLD(DistributedBPMF):
    """Multi-device minibatch SGLD over the Gibbs grid partition.

    Rides the exact plans, LPT entity sharding, and exchange modes of
    DistributedBPMF — only the per-block work changes (a sampled gradient
    block instead of a full syrk) and the finish step is a preconditioned
    Langevin update instead of a Cholesky draw. `minibatch` is the padded
    lane budget per shard per half-step, split evenly across the P blocks
    a shard visits (ring/async) or drawn at once from the flattened plan
    (allgather).
    """

    verbose_every = 50

    def __init__(
        self,
        ratings: SparseRatings,
        test: SparseRatings | None = None,
        *,
        mesh: Mesh | None = None,
        k: int = 32,
        alpha: float = 1.5,
        width: int | str = 32,
        mode: str = "ring",
        minibatch: int = 4096,
        step_size: float = 0.3,
        step_decay: float = 0.33,
        step_t0: float = 100.0,
        temperature: float = 1.0,
        temp_warmup: int = 0,
        precondition: bool = True,
        clip: float | None = 3.0,
        seed: int = 0,
    ):
        self.minibatch = int(minibatch)
        self.step_size = float(step_size)
        self.step_decay = float(step_decay)
        self.step_t0 = float(step_t0)
        self.temperature = float(temperature)
        self.temp_warmup = int(temp_warmup)
        self.precondition = bool(precondition)
        self.clip = None if clip is None else float(clip)
        self._degrees = (
            np.asarray(ratings.degrees(0), np.float32),
            np.asarray(ratings.degrees(1), np.float32),
        )
        self.init_scale = data_init_scale(ratings.vals, k)
        super().__init__(
            ratings, test, mesh=mesh, k=k, alpha=alpha, width=width,
            mode=mode, engine="einsum", seed=seed,
        )

    def init(self, seed: int = 0):
        state = super().init(seed)
        s = self.init_scale / 0.1
        u, v = state.u * s, state.v * s
        return state._replace(
            u=u, v=v, v_eval=v if self.mode == "async" else None
        )

    def _shard_degrees(self, degrees, part):
        """Per-entity degrees in plan layout (P, n_loc); pad slots get 0,
        so their gain is the finite 1/lam_bar and the finish mask zeroes
        them regardless."""
        ids = part.ids
        d = np.where(ids >= 0, degrees[np.maximum(ids, 0)], 0.0)
        sh = NamedSharding(self.mesh, P(AXIS))
        return jax.device_put(jnp.asarray(d, jnp.float32), sh)

    def _build_sweep(self):
        self.u_ring, self.u_ids = self._device_plans(self.u_plan)
        self.v_ring, self.v_ids = self._device_plans(self.v_plan)
        if self.mode == "allgather":
            self.u_flat = self._flat_plans(self.u_plan)
            self.v_flat = self._flat_plans(self.v_plan)
        self.u_deg = self._shard_degrees(self._degrees[0], self.u_part)
        self.v_deg = self._shard_degrees(self._degrees[1], self.v_part)

        def rows_per_block(plan):
            _, _, r, w = plan.indices.shape
            return int(min(r, max(1, round(
                self.minibatch / (self.n_shards * w)
            ))))

        cfg = SGLDConfig(
            step_size=self.step_size, step_decay=self.step_decay,
            step_t0=self.step_t0, temperature=self.temperature,
            temp_warmup=self.temp_warmup,
            u_rows=rows_per_block(self.u_plan),
            v_rows=rows_per_block(self.v_plan),
            precondition=self.precondition, clip=self.clip,
        )
        mapped = make_sgld_sweep(self.mesh, self.mode, self.alpha,
                                 self.prior, cfg)
        u_plans = self.u_flat if self.mode == "allgather" else self.u_ring
        v_plans = self.v_flat if self.mode == "allgather" else self.v_ring

        @jax.jit
        def run(state):
            return mapped(state, u_plans, v_plans, self.u_ids, self.v_ids,
                          self.u_deg, self.v_deg)

        return run
