"""Degree-bucketed update plans — the TPU analogue of the paper's work stealing.

The paper (Sec 3.2, Fig 2-3) observes that item update cost is `fixed +
c * n_ratings` with a heavy power-law tail, and balances it with TBB work
stealing plus a per-degree algorithm switch (rank-one updates below 1000
ratings, parallel Cholesky above). TPUs are SPMD: balance must be *static*.

We bin items by degree into power-of-two-width padded buckets. Each bucket is
a dense (rows, width) block:

    indices (rows, width) int32   -- counterpart item ids, padded
    values  (rows, width) f32     -- ratings, padded with 0
    mask    (rows, width) f32     -- 1 for real ratings
    item_ids (rows,)      int32   -- which item each row contributes to
    seg_ids  (rows,)      int32   -- dense segment id within the bucket

Items whose degree exceeds the widest bucket are *split* across several rows
of that bucket and recombined with a segment-sum — the analogue of the paper
splitting one heavy item's Cholesky across cores. The per-bucket update is a
batched masked `syrk` (outer-product accumulation) that maps straight onto the
MXU; `padding_efficiency` reports how close the static plan gets to the
paper's stolen-work balance.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

DEFAULT_WIDTHS = (8, 32, 128, 512)


@dataclass(frozen=True)
class Bucket:
    width: int
    indices: np.ndarray  # (rows, width) int32
    values: np.ndarray   # (rows, width) f32
    mask: np.ndarray     # (rows, width) f32
    item_ids: np.ndarray  # (rows,) int32 — global item index this row feeds
    seg_ids: np.ndarray   # (rows,) int32 — dense segment id inside the bucket
    n_segments: int       # number of distinct items in the bucket
    seg_item_ids: np.ndarray  # (n_segments,) int32 — global item id per segment

    @property
    def rows(self) -> int:
        return int(self.indices.shape[0])


@dataclass(frozen=True)
class BucketPlan:
    n_items: int
    n_counterparts: int
    buckets: tuple[Bucket, ...]
    nnz: int
    padded: int
    empty_items: Optional[np.ndarray] = None  # items with no ratings

    @property
    def padding_efficiency(self) -> float:
        """Fraction of MXU lanes doing useful work (1.0 = perfect balance)."""
        return self.nnz / max(self.padded, 1)

    def stats(self) -> dict:
        return {
            "n_items": self.n_items,
            "nnz": self.nnz,
            "padded": self.padded,
            "padding_efficiency": round(self.padding_efficiency, 4),
            "buckets": [
                {"width": b.width, "rows": b.rows, "segments": b.n_segments}
                for b in self.buckets
            ],
        }


def plan_buckets(
    indptr: np.ndarray,
    indices: np.ndarray,
    values: np.ndarray,
    n_items: int,
    n_counterparts: int,
    widths: tuple[int, ...] = DEFAULT_WIDTHS,
) -> BucketPlan:
    """Build a bucketed plan from CSR (indptr over items)."""
    widths = tuple(sorted(widths))
    degrees = np.diff(indptr)
    assert len(degrees) == n_items

    buckets: list[Bucket] = []
    nnz_total = int(degrees.sum())
    padded_total = 0

    max_w = widths[-1]
    # Assign each item to the smallest width that fits; oversize items go to
    # the widest bucket, split into ceil(deg / max_w) rows.
    fits = np.searchsorted(np.asarray(widths), degrees, side="left")
    fits = np.clip(fits, 0, len(widths) - 1)

    for wi, w in enumerate(widths):
        if wi < len(widths) - 1:
            sel = np.where((fits == wi) & (degrees > 0))[0]
            n_rows_per_item = np.ones(len(sel), dtype=np.int64)
        else:
            sel = np.where((fits == wi) & (degrees > 0))[0]
            n_rows_per_item = np.maximum(1, -(-degrees[sel] // w))
        if len(sel) == 0:
            continue
        total_rows = int(n_rows_per_item.sum())
        idx = np.zeros((total_rows, w), dtype=np.int32)
        val = np.zeros((total_rows, w), dtype=np.float32)
        msk = np.zeros((total_rows, w), dtype=np.float32)
        row_item = np.zeros(total_rows, dtype=np.int32)
        row_seg = np.zeros(total_rows, dtype=np.int32)

        r = 0
        for seg, item in enumerate(sel):
            start, end = indptr[item], indptr[item + 1]
            deg = end - start
            for chunk0 in range(0, max(deg, 1), w):
                chunk = indices[start + chunk0 : min(start + chunk0 + w, end)]
                cvals = values[start + chunk0 : min(start + chunk0 + w, end)]
                idx[r, : len(chunk)] = chunk
                val[r, : len(chunk)] = cvals
                msk[r, : len(chunk)] = 1.0
                row_item[r] = item
                row_seg[r] = seg
                r += 1
        assert r == total_rows
        buckets.append(
            Bucket(
                width=w,
                indices=idx,
                values=val,
                mask=msk,
                item_ids=row_item,
                seg_ids=row_seg,
                n_segments=len(sel),
                seg_item_ids=sel.astype(np.int32),
            )
        )
        padded_total += total_rows * w

    empty = np.where(degrees == 0)[0].astype(np.int32)
    return BucketPlan(
        n_items=n_items,
        n_counterparts=n_counterparts,
        buckets=tuple(buckets),
        nnz=nnz_total,
        padded=padded_total,
        empty_items=empty,
    )


def pad_bucket(bucket: Bucket, rows: int, segments: int) -> Bucket:
    """Pad a bucket to (rows, segments) — mask-zero rows and zero-sum
    segments, so the padded plan computes identical statistics.

    Pad rows carry mask 0 (their gathered factors are zeroed before the
    syrk) and point at the LAST padded segment / item 0, contributing exact
    zeros while keeping `seg_ids` nondecreasing — the invariant the fused
    gather-syrk kernel's in-kernel segment reduction relies on. Pad
    segments receive only zero contributions and scatter them into item 0.
    This is how the fold-in plan cache maps every batch with a similar
    rating-count profile onto one quantized set of array shapes, so the
    compiled executables are reused across batches.
    """
    if rows < bucket.rows or segments < bucket.n_segments:
        raise ValueError(
            f"cannot pad bucket of ({bucket.rows} rows, {bucket.n_segments} "
            f"segments) down to ({rows}, {segments})"
        )
    pr = rows - bucket.rows
    ps = segments - bucket.n_segments
    if pr == 0 and ps == 0:
        return bucket
    w = bucket.width
    return Bucket(
        width=w,
        indices=np.concatenate([bucket.indices, np.zeros((pr, w), np.int32)]),
        values=np.concatenate([bucket.values, np.zeros((pr, w), np.float32)]),
        mask=np.concatenate([bucket.mask, np.zeros((pr, w), np.float32)]),
        item_ids=np.concatenate([bucket.item_ids, np.zeros(pr, np.int32)]),
        seg_ids=np.concatenate(
            [bucket.seg_ids, np.full(pr, segments - 1, np.int32)]
        ),
        n_segments=segments,
        seg_item_ids=np.concatenate(
            [bucket.seg_item_ids, np.zeros(ps, np.int32)]
        ),
    )


def workload_model(degrees: np.ndarray, fixed_cost: float = 1.0, per_rating: float = 0.02):
    """The paper's Sec 4.2 workload model: cost = fixed + c * n_ratings.

    Used by the LPT partitioner to balance shards. Constants follow the shape
    of Fig 3 (small items dominated by the K^3 Cholesky fixed cost, large
    items by the per-rating syrk cost).
    """
    return fixed_cost + per_rating * degrees.astype(np.float64)
