"""Degree-bucketed update plans — the TPU analogue of the paper's work stealing.

The paper (Sec 3.2, Fig 2-3) observes that item update cost is `fixed +
c * n_ratings` with a heavy power-law tail, and balances it with TBB work
stealing plus a per-degree algorithm switch (rank-one updates below 1000
ratings, parallel Cholesky above). TPUs are SPMD: balance must be *static*.

We bin items by degree into power-of-two-width padded buckets. Each bucket is
a dense (rows, width) block:

    indices (rows, width) int32   -- counterpart item ids, padded
    values  (rows, width) f32     -- ratings, padded with 0
    mask    (rows, width) f32     -- 1 for real ratings
    item_ids (rows,)      int32   -- which item each row contributes to
    seg_ids  (rows,)      int32   -- dense segment id within the bucket

Items whose degree exceeds the widest bucket are *split* across several rows
of that bucket and recombined with a segment-sum — the analogue of the paper
splitting one heavy item's Cholesky across cores. The per-bucket update is a
batched masked `syrk` (outer-product accumulation) that maps straight onto the
MXU; `padding_efficiency` reports how close the static plan gets to the
paper's stolen-work balance.

Two planners share the bucket schema:

* the fixed ladder (`widths=(8, 32, 128, 512)` or any explicit tuple) — the
  original pow2 plan, kept as the static baseline;
* the **balanced** planner (`widths="balanced"`) — the work-stealing
  equivalent. `balanced_widths` reads the actual degree histogram and picks
  the width ladder that minimizes the padded workload-model cost (the same
  `cost = fixed + c * n_ratings` model the paper's scheduler balances
  dynamically), via an exact interval-partition DP over distinct degrees.
  Item degrees in real rating data are heavily skewed toward the ladder's
  bottom, where a fixed pow2 ladder wastes most of its lanes; fitting the
  ladder to the histogram is what lifts `padding_efficiency` from ~0.3 to
  >0.7 on the ChEMBL-like benchmark profile (`benchmarks/fig4_multicore.py`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

DEFAULT_WIDTHS = (8, 32, 128, 512)

#: accepted by every `widths=` parameter that feeds `plan_buckets`
BALANCED = "balanced"

WidthsSpec = Union[str, Sequence[int]]


@dataclass(frozen=True)
class Bucket:
    width: int
    indices: np.ndarray  # (rows, width) int32
    values: np.ndarray   # (rows, width) f32
    mask: np.ndarray     # (rows, width) f32
    item_ids: np.ndarray  # (rows,) int32 — global item index this row feeds
    seg_ids: np.ndarray   # (rows,) int32 — dense segment id inside the bucket
    n_segments: int       # number of distinct items in the bucket
    seg_item_ids: np.ndarray  # (n_segments,) int32 — global item id per segment

    @property
    def rows(self) -> int:
        return int(self.indices.shape[0])


@dataclass(frozen=True)
class BucketPlan:
    n_items: int
    n_counterparts: int
    buckets: tuple[Bucket, ...]
    nnz: int
    padded: int
    empty_items: Optional[np.ndarray] = None  # items with no ratings
    widths: Optional[tuple[int, ...]] = None  # the resolved width ladder

    @property
    def padding_efficiency(self) -> float:
        """Fraction of MXU lanes doing useful work (1.0 = perfect balance)."""
        return self.nnz / max(self.padded, 1)

    def stats(self) -> dict:
        return {
            "n_items": self.n_items,
            "nnz": self.nnz,
            "padded": self.padded,
            "padding_efficiency": round(self.padding_efficiency, 4),
            "widths": list(self.widths) if self.widths else None,
            "buckets": [
                {"width": b.width, "rows": b.rows, "segments": b.n_segments}
                for b in self.buckets
            ],
        }


def balanced_widths(
    degrees: np.ndarray,
    *,
    max_buckets: int = 8,
    lane: int = 1,
    max_width: int = 512,
    fixed_cost: float = 1.0,
    per_rating: float = 0.02,
) -> tuple[int, ...]:
    """Degree-aware width ladder: the static equivalent of work stealing.

    The paper's scheduler balances `cost = fixed + c * n_ratings` across
    cores at run time; the static analogue is choosing bucket widths so the
    *padded* plan carries as little dead cost as possible. Every item of
    degree d placed in a width-w bucket costs one row of
    `workload_model(w)`, so for a candidate ladder the total padded cost is

        sum_items workload_model(width(item))  (+ split rows, see below)

    and the row count is fixed (one row per unsplit item) — minimizing the
    cost is exactly minimizing padded lanes, with `fixed_cost` only acting
    through the split items' chunk count. The optimal ladder under a bucket
    budget is an interval partition of the distinct-degree axis, found
    exactly by DP (O(D^2 * max_buckets) on D <= max_width distinct values —
    microseconds, done once at plan time).

    Items with degree > max_width are split across rows of a forced
    `max_width` bucket (chunking keeps their per-row fill near 1, and the
    DP's remaining buckets fit the small-degree mass). `lane` rounds widths
    up (lane=8 keeps every bucket MXU-lane aligned for the fused kernel;
    the default lane=1 maximizes lane efficiency for the einsum engines —
    `kernels/ops.py` re-pads to 8-lane tiles on the kernel path either way).
    """
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    degrees = np.asarray(degrees)
    d = degrees[(degrees > 0) & (degrees <= max_width)]
    oversize = degrees[degrees > max_width]

    def lane_up(w: int) -> int:
        return -(-int(w) // lane) * lane

    if d.size == 0:
        return (lane_up(max_width if oversize.size else lane),)

    ds, cs = np.unique(d, return_counts=True)
    m = len(ds)
    budget = max_buckets - (1 if oversize.size else 0)
    budget = max(budget, 1)
    row_cost = fixed_cost + per_rating * np.array(
        [lane_up(x) for x in ds], np.float64
    )
    csum = np.concatenate([[0], np.cumsum(cs)])      # csum[i] = count of ds[:i]

    if m <= budget:
        cuts = list(range(1, m + 1))
    else:
        # f[b, i] = min cost covering ds[:i] with b+1 buckets, the last
        # bucket ending exactly at ds[i-1] (its width); arg[b, i] = best j
        inf = np.inf
        f = np.full((budget, m + 1), inf)
        arg = np.zeros((budget, m + 1), np.int64)
        f[0, 1:] = csum[1:] * row_cost                # one bucket up to ds[i-1]
        for b in range(1, budget):
            for i in range(b + 1, m + 1):
                # last bucket spans ds[j..i-1]; vectorized over j
                j = np.arange(b, i)
                cand = f[b - 1, j] + (csum[i] - csum[j]) * row_cost[i - 1]
                best = int(np.argmin(cand))
                f[b, i] = cand[best]
                arg[b, i] = j[best]
        b_best = int(np.argmin(f[:, m]))
        cuts = [m]
        b, i = b_best, m
        while b > 0:
            i = int(arg[b, i])
            cuts.append(i)
            b -= 1
        cuts = sorted(cuts)
    widths = {lane_up(ds[i - 1]) for i in cuts}
    if oversize.size:
        widths.add(lane_up(max_width))
    return tuple(sorted(widths))


def resolve_widths(
    widths: WidthsSpec,
    degrees: np.ndarray,
    **balanced_kwargs,
) -> tuple[int, ...]:
    """An explicit ladder passes through sorted; `"balanced"` is resolved
    from the degree distribution via `balanced_widths`."""
    if isinstance(widths, str):
        if widths != BALANCED:
            raise ValueError(
                f"widths must be a tuple of ints or {BALANCED!r}, got {widths!r}"
            )
        return balanced_widths(degrees, **balanced_kwargs)
    return tuple(sorted(int(w) for w in widths))


def plan_buckets(
    indptr: np.ndarray,
    indices: np.ndarray,
    values: np.ndarray,
    n_items: int,
    n_counterparts: int,
    widths: WidthsSpec = DEFAULT_WIDTHS,
) -> BucketPlan:
    """Build a bucketed plan from CSR (indptr over items).

    widths: an explicit ladder, or `"balanced"` to fit the ladder to this
    CSR's degree histogram (`balanced_widths`).
    """
    degrees = np.diff(indptr)
    assert len(degrees) == n_items
    widths = resolve_widths(widths, degrees)

    buckets: list[Bucket] = []
    nnz_total = int(degrees.sum())
    padded_total = 0

    max_w = widths[-1]
    # Assign each item to the smallest width that fits; oversize items go to
    # the widest bucket, split into ceil(deg / max_w) rows.
    fits = np.searchsorted(np.asarray(widths), degrees, side="left")
    fits = np.clip(fits, 0, len(widths) - 1)

    for wi, w in enumerate(widths):
        if wi < len(widths) - 1:
            sel = np.where((fits == wi) & (degrees > 0))[0]
            n_rows_per_item = np.ones(len(sel), dtype=np.int64)
        else:
            sel = np.where((fits == wi) & (degrees > 0))[0]
            n_rows_per_item = np.maximum(1, -(-degrees[sel] // w))
        if len(sel) == 0:
            continue
        total_rows = int(n_rows_per_item.sum())
        idx = np.zeros((total_rows, w), dtype=np.int32)
        val = np.zeros((total_rows, w), dtype=np.float32)
        msk = np.zeros((total_rows, w), dtype=np.float32)
        row_item = np.zeros(total_rows, dtype=np.int32)
        row_seg = np.zeros(total_rows, dtype=np.int32)

        r = 0
        for seg, item in enumerate(sel):
            start, end = indptr[item], indptr[item + 1]
            deg = end - start
            for chunk0 in range(0, max(deg, 1), w):
                chunk = indices[start + chunk0 : min(start + chunk0 + w, end)]
                cvals = values[start + chunk0 : min(start + chunk0 + w, end)]
                idx[r, : len(chunk)] = chunk
                val[r, : len(chunk)] = cvals
                msk[r, : len(chunk)] = 1.0
                row_item[r] = item
                row_seg[r] = seg
                r += 1
        assert r == total_rows
        buckets.append(
            Bucket(
                width=w,
                indices=idx,
                values=val,
                mask=msk,
                item_ids=row_item,
                seg_ids=row_seg,
                n_segments=len(sel),
                seg_item_ids=sel.astype(np.int32),
            )
        )
        padded_total += total_rows * w

    empty = np.where(degrees == 0)[0].astype(np.int32)
    return BucketPlan(
        n_items=n_items,
        n_counterparts=n_counterparts,
        buckets=tuple(buckets),
        nnz=nnz_total,
        padded=padded_total,
        empty_items=empty,
        widths=widths,
    )


def pad_bucket(bucket: Bucket, rows: int, segments: int) -> Bucket:
    """Pad a bucket to (rows, segments) — mask-zero rows and zero-sum
    segments, so the padded plan computes identical statistics.

    Pad rows carry mask 0 (their gathered factors are zeroed before the
    syrk) and point at the LAST padded segment / item 0, contributing exact
    zeros while keeping `seg_ids` nondecreasing — the invariant the fused
    gather-syrk kernel's in-kernel segment reduction relies on. Pad
    segments receive only zero contributions and scatter them into item 0.
    This is how the fold-in plan cache maps every batch with a similar
    rating-count profile onto one quantized set of array shapes, so the
    compiled executables are reused across batches.
    """
    if rows < bucket.rows or segments < bucket.n_segments:
        raise ValueError(
            f"cannot pad bucket of ({bucket.rows} rows, {bucket.n_segments} "
            f"segments) down to ({rows}, {segments})"
        )
    pr = rows - bucket.rows
    ps = segments - bucket.n_segments
    if pr == 0 and ps == 0:
        return bucket
    w = bucket.width
    return Bucket(
        width=w,
        indices=np.concatenate([bucket.indices, np.zeros((pr, w), np.int32)]),
        values=np.concatenate([bucket.values, np.zeros((pr, w), np.float32)]),
        mask=np.concatenate([bucket.mask, np.zeros((pr, w), np.float32)]),
        item_ids=np.concatenate([bucket.item_ids, np.zeros(pr, np.int32)]),
        seg_ids=np.concatenate(
            [bucket.seg_ids, np.full(pr, segments - 1, np.int32)]
        ),
        n_segments=segments,
        seg_item_ids=np.concatenate(
            [bucket.seg_item_ids, np.zeros(ps, np.int32)]
        ),
    )


def workload_model(degrees: np.ndarray, fixed_cost: float = 1.0, per_rating: float = 0.02):
    """The paper's Sec 4.2 workload model: cost = fixed + c * n_ratings.

    Used by the LPT partitioner to balance shards. Constants follow the shape
    of Fig 3 (small items dominated by the K^3 Cholesky fixed cost, large
    items by the per-rating syrk cost).
    """
    return fixed_cost + per_rating * degrees.astype(np.float64)
