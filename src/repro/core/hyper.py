"""Normal-Wishart hyperprior sampling for BPMF (Salakhutdinov & Mnih 2008).

The conditional posterior of (mu, Lambda) given a factor matrix X (n x K)
with NW(mu0, beta0, W0, nu0) prior is Normal-Wishart with

    beta* = beta0 + n            nu* = nu0 + n
    mu*   = (beta0 mu0 + n xbar) / beta*
    W*^-1 = W0^-1 + n S + (beta0 n / beta*) (xbar - mu0)(xbar - mu0)^T

where xbar and S are the sample mean and covariance. Crucially — following
the paper's single-core optimization (Sec 3.1) — we take the *sufficient
statistics* (sum_x, sum_xxT, n) rather than X itself, so they can be fused
into the factor-update sweep (and psum-ed across shards) at negligible cost.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class NWPrior(NamedTuple):
    mu0: jax.Array     # (K,)
    beta0: jax.Array   # scalar
    w0_inv: jax.Array  # (K, K) — inverse scale matrix
    nu0: jax.Array     # scalar


class HyperParams(NamedTuple):
    mu: jax.Array    # (K,)
    lam: jax.Array   # (K, K) precision


def default_prior(k: int, dtype=jnp.float32) -> NWPrior:
    return NWPrior(
        mu0=jnp.zeros((k,), dtype),
        beta0=jnp.asarray(2.0, dtype),
        w0_inv=jnp.eye(k, dtype=dtype),
        nu0=jnp.asarray(float(k), dtype),
    )


def init_hyper(k: int, dtype=jnp.float32) -> HyperParams:
    return HyperParams(mu=jnp.zeros((k,), dtype), lam=jnp.eye(k, dtype=dtype))


def sample_wishart(key: jax.Array, df: jax.Array, scale_chol: jax.Array) -> jax.Array:
    """Wishart(df, S) sample via the Bartlett decomposition.

    scale_chol is chol(S) (lower). A is lower-triangular with
    A_ii ~ sqrt(chi2(df - i)) and A_ij ~ N(0,1) below the diagonal;
    the sample is (L A)(L A)^T.
    """
    k = scale_chol.shape[-1]
    kn, kc = jax.random.split(key)
    # chi2(nu) = 2 * Gamma(nu / 2)
    dfs = df - jnp.arange(k, dtype=scale_chol.dtype)
    chi2 = 2.0 * jax.random.gamma(kc, dfs / 2.0, dtype=scale_chol.dtype)
    normal = jax.random.normal(kn, (k, k), dtype=scale_chol.dtype)
    a = jnp.tril(normal, -1) + jnp.diag(jnp.sqrt(chi2))
    la = scale_chol @ a
    return la @ la.T


def sample_normal_wishart(
    key: jax.Array,
    sum_x: jax.Array,
    sum_xxt: jax.Array,
    n: jax.Array,
    prior: NWPrior,
) -> HyperParams:
    """Sample (mu, Lambda) ~ NW-posterior given sufficient statistics."""
    k = sum_x.shape[-1]
    dtype = sum_x.dtype
    n = jnp.asarray(n, dtype)
    xbar = sum_x / n
    # n * S = sum_xxT - n xbar xbarT
    n_s = sum_xxt - n * jnp.outer(xbar, xbar)

    beta_star = prior.beta0 + n
    nu_star = prior.nu0 + n
    mu_star = (prior.beta0 * prior.mu0 + n * xbar) / beta_star
    diff = xbar - prior.mu0
    w_star_inv = prior.w0_inv + n_s + (prior.beta0 * n / beta_star) * jnp.outer(diff, diff)
    # Symmetrize for numerical safety, then invert via Cholesky.
    w_star_inv = 0.5 * (w_star_inv + w_star_inv.T)
    l_inv = jnp.linalg.cholesky(w_star_inv)
    eye = jnp.eye(k, dtype=dtype)
    l_inv_sol = jax.scipy.linalg.solve_triangular(l_inv, eye, lower=True)
    w_star = l_inv_sol.T @ l_inv_sol  # = (L L^T)^-1

    kw, km = jax.random.split(key)
    scale_chol = jnp.linalg.cholesky(0.5 * (w_star + w_star.T))
    lam = sample_wishart(kw, nu_star, scale_chol)
    lam = 0.5 * (lam + lam.T)

    # mu ~ N(mu*, (beta* Lambda)^-1): mu = mu* + chol(beta* Lambda)^-T z
    lam_chol = jnp.linalg.cholesky(beta_star * lam + 1e-6 * eye)
    z = jax.random.normal(km, (k,), dtype)
    mu = mu_star + jax.scipy.linalg.solve_triangular(lam_chol.T, z, lower=False)
    return HyperParams(mu=mu, lam=lam)
