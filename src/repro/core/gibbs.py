"""Single-host BPMF Gibbs sampler over bucketed plans.

Algorithm 1 of the paper: per sweep, sample movie hyperparameters from V,
update every movie from (R, U); sample user hyperparameters from U, update
every user from (R, V); then predict the test points. The per-item update is

    Lambda_i = Lambda_hyper + alpha * sum_j v_j v_j^T     (j in ratings of i)
    b_i      = Lambda_hyper mu_hyper + alpha * sum_j r_ij v_j
    u_i      ~ N(Lambda_i^-1 b_i, Lambda_i^-1)

computed bucket-by-bucket as batched masked syrk (MXU) + batched Cholesky
sample — full inverses are never formed (paper Sec 3.1). The sufficient
statistics for the *next* hyperparameter draw are fused into the sweep.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import Bucket, BucketPlan, WidthsSpec, plan_buckets
from repro.core.hyper import (
    HyperParams,
    default_prior,
    init_hyper,
    sample_normal_wishart,
)
from repro.data.sparse import SparseRatings, csr_from_coo

# Sweep engines, selecting how per-segment rating statistics are computed
# and how the posterior systems are solved (docs/architecture.md §4):
#   reference  seed data flow kept verbatim: einsum row stats, per-bucket
#              segment_sum + full-size scatter-adds, LAPACK-style 3-solve
#              sampling. The equivalence oracle and benchmark baseline.
#   einsum     restructured flow (default): same einsum statistics, but
#              per-segment outputs written once into their seg_item_ids
#              slots and the batched substitution solver.
#   kernel     restructured flow through the two-step Pallas kernels
#              (masked_syrk + chol_solve_sample; interpret mode off-TPU).
#   fused      restructured flow through the fused gather→syrk→segment-
#              reduce kernel: V gathered in-kernel, no row-level
#              intermediate, optional bf16 gather.
ENGINES = ("reference", "einsum", "kernel", "fused")

# The full trainer family launch/train.py exposes: the four Gibbs sweep
# engines above plus the minibatch SGLD trainer (core.sgld.SGLDSampler /
# DistributedSGLD), which is a different sampler, not a sweep
# implementation — resolve_engine therefore rejects it with a pointer.
SGLD = "sgld"
TRAIN_ENGINES = ENGINES + (SGLD,)


def resolve_engine(engine: str | None, use_kernel: bool = False) -> str:
    """Map the (engine, legacy use_kernel flag) pair onto an ENGINES name."""
    if engine is None:
        return "kernel" if use_kernel else "einsum"
    if engine == SGLD:
        raise ValueError(
            f"engine must be one of {ENGINES}, got {engine!r}: 'sgld' is "
            "the minibatch SG-MCMC trainer, not a Gibbs sweep engine — use "
            "core.sgld.SGLDSampler / DistributedSGLD "
            "(launch.train --engine sgld)"
        )
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    return engine


class FactorStats(NamedTuple):
    """Sufficient statistics of a factor matrix, fused into the sweep."""

    sum_x: jax.Array    # (K,)
    sum_xxt: jax.Array  # (K, K)
    n: jax.Array        # scalar


class BPMFState(NamedTuple):
    u: jax.Array              # (M, K)
    v: jax.Array              # (N, K)
    hyper_u: HyperParams
    hyper_v: HyperParams
    key: jax.Array
    step: jax.Array
    # Posterior-predictive accumulators over test points (after burn-in).
    pred_sum: jax.Array       # (n_test,)
    pred_count: jax.Array     # scalar


class DeviceBucket(NamedTuple):
    """Device-resident copy of a host Bucket (jnp arrays)."""

    width: int
    indices: jax.Array
    values: jax.Array
    mask: jax.Array
    seg_ids: jax.Array
    n_segments: int
    seg_item_ids: jax.Array
    # host-verified: seg_ids == arange(rows), i.e. every row is its own
    # segment and the per-bucket reduction is the identity (all buckets
    # except the widest, which splits long-tail items across rows)
    identity_segments: bool = False


def device_plan(
    plan: BucketPlan | Sequence[Bucket],
) -> tuple[DeviceBucket, ...]:
    """Move a host plan (or a bare bucket sequence, e.g. one the fold-in
    cache padded) onto the device."""
    if isinstance(plan, BucketPlan):
        plan = plan.buckets
    return tuple(
        DeviceBucket(
            width=b.width,
            indices=jnp.asarray(b.indices),
            values=jnp.asarray(b.values),
            mask=jnp.asarray(b.mask),
            seg_ids=jnp.asarray(b.seg_ids),
            n_segments=b.n_segments,
            seg_item_ids=jnp.asarray(b.seg_item_ids),
            identity_segments=bool(
                b.indices.shape[0] == b.n_segments
                and np.array_equal(
                    np.asarray(b.seg_ids), np.arange(b.n_segments)
                )
            ),
        )
        for b in plan
    )


def segment_reduce_rows(
    rows: jax.Array, seg_ids: jax.Array, n_segments: int, *,
    stacked: bool = False, sorted_ids: bool = True, identity: bool = False,
) -> jax.Array:
    """Row-level statistics -> per-segment sums. The one definition of the
    bucket segment reduction, shared by every engine (`bucket_stats` here
    and the fused jnp path in `kernels.ops`): identity skips the reduction
    outright (every row its own segment), `stacked` rotates a leading draw
    axis out of the way (segment_sum reduces the leading axis), and
    `sorted_ids` asserts the planner's nondecreasing-rows invariant to XLA.
    """
    if identity:
        return rows
    if stacked:
        perm = (1, 0) + tuple(range(2, rows.ndim))
        return jax.ops.segment_sum(
            rows.transpose(perm), seg_ids, n_segments,
            indices_are_sorted=sorted_ids,
        ).transpose(perm)
    return jax.ops.segment_sum(
        rows, seg_ids, n_segments, indices_are_sorted=sorted_ids
    )


def bucket_stats(
    counterpart: jax.Array, bucket: DeviceBucket, *,
    use_kernel: bool = False, engine: str | None = None,
    bf16_gather: bool = False, interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Per-segment (sum v v^T, sum r v) for one bucket.

    counterpart is either one factor matrix (N, K) — the training sweep —
    or a stack of S retained draws (S, N, K) — the serving fold-in, where
    the same bucket plan (indices, ratings, mask are draw-independent) is
    applied against every draw's factors in one batched contraction.
    Returns (prec (..., n_segments, K, K), rhs (..., n_segments, K)) with
    the leading draw axis present iff counterpart carried one.

    `engine` selects the implementation (see ENGINES); the fused engine
    routes both forms through `kernels.ops.gather_syrk_seg`, so the
    stacked-draw fold-in rides the same kernel as the training sweep.
    """
    engine = resolve_engine(engine, use_kernel)

    if engine == "fused":
        from repro.kernels import ops as kops

        return kops.gather_syrk_seg(
            bucket.indices, bucket.values, bucket.mask,
            bucket.seg_ids, bucket.n_segments, counterpart,
            bf16_gather=bf16_gather,
            identity_segments=bucket.identity_segments,
            interpret=interpret,
        )

    # identity reduction is exact (a permutation-free relabeling), so the
    # restructured einsum engine skips it; the reference engine keeps the
    # seed computation verbatim
    skip_reduce = engine == "einsum" and bucket.identity_segments
    sorted_ids = engine != "reference"

    def reduce(rows, rotate):
        return segment_reduce_rows(
            rows, bucket.seg_ids, bucket.n_segments, stacked=rotate,
            sorted_ids=sorted_ids, identity=skip_reduce,
        )

    rv = bucket.values * bucket.mask
    if counterpart.ndim == 2:
        vg = counterpart[bucket.indices]                # (rows, w, K)
        vm = vg * bucket.mask[..., None]
        if engine == "kernel":
            from repro.kernels import ops as kops

            prec_rows, rhs_rows = kops.masked_syrk(vm, rv)
        else:
            prec_rows = jnp.einsum(
                "rwk,rwl->rkl", vm, vm, preferred_element_type=jnp.float32
            )
            rhs_rows = jnp.einsum("rwk,rw->rk", vm, rv)
        return reduce(prec_rows, False), reduce(rhs_rows, False)

    # stacked draws: one gather + one contraction covering all S draws
    vg = counterpart[:, bucket.indices]                 # (S, rows, w, K)
    vm = vg * bucket.mask[..., None]
    if engine == "kernel":
        from repro.kernels import ops as kops

        prec_rows, rhs_rows = kops.masked_syrk(
            vm, jnp.broadcast_to(rv, vm.shape[:-1])
        )
    else:
        prec_rows = jnp.einsum(
            "srwk,srwl->srkl", vm, vm, preferred_element_type=jnp.float32
        )
        rhs_rows = jnp.einsum("srwk,rw->srk", vm, rv)
    return reduce(prec_rows, True), reduce(rhs_rows, True)


def chol_subst_solve(chol: jax.Array, rhs: jax.Array, z: jax.Array) -> jax.Array:
    """x = L^-T (L^-1 rhs + z) via batch-vectorized substitution.

    XLA's batched `triangular_solve` dispatches per batch element on CPU
    and dominates the sweep (it is the seed path's real bottleneck, not the
    syrk). This runs the two substitutions as K fixed-shape steps over the
    whole batch — full-width dot products are exact because not-yet-solved
    entries are still zero — and merges the mean and noise solves into one
    backward pass. Works for any leading batch axes.
    """
    k = chol.shape[-1]

    def fwd(i, y):
        row = jax.lax.dynamic_slice_in_dim(chol, i, 1, axis=-2)[..., 0, :]
        d = jax.lax.dynamic_slice_in_dim(row, i, 1, axis=-1)[..., 0]
        yi = (
            jax.lax.dynamic_slice_in_dim(rhs, i, 1, axis=-1)[..., 0]
            - jnp.sum(row * y, -1)
        ) / d
        return jax.lax.dynamic_update_slice_in_dim(y, yi[..., None], i, axis=-1)

    c = jax.lax.fori_loop(0, k, fwd, jnp.zeros_like(rhs)) + z

    def bwd(j, x):
        i = k - 1 - j
        col = jax.lax.dynamic_slice_in_dim(chol, i, 1, axis=-1)[..., 0]
        d = jax.lax.dynamic_slice_in_dim(col, i, 1, axis=-1)[..., 0]
        xi = (
            jax.lax.dynamic_slice_in_dim(c, i, 1, axis=-1)[..., 0]
            - jnp.sum(col * x, -1)
        ) / d
        return jax.lax.dynamic_update_slice_in_dim(x, xi[..., None], i, axis=-1)

    return jax.lax.fori_loop(0, k, bwd, jnp.zeros_like(rhs))


def sample_mvn_precision(
    key: jax.Array | None, prec: jax.Array, rhs: jax.Array,
    *, z: jax.Array | None = None, use_kernel: bool = False,
    solver: str | None = None,
) -> jax.Array:
    """x ~ N(prec^-1 rhs, prec^-1), batched over any leading axes.

    Cholesky-only (no inverse): with prec = L L^T,
      mean = L^-T (L^-1 rhs),  x = mean + L^-T z.
    key=None returns the posterior mean (the z = 0 limb of the same solve)
    — the serving fold-in's deterministic mode. An explicit `z` (same shape
    as rhs) overrides the key: the batched fold-in pre-draws its noise with
    the per-draw key sequence of the original per-sample loop, so fused and
    looped sampling consume identical random bits.

    solver: "subst" (default) — batch-vectorized substitution, the fast
    path everywhere; "lapack" — the seed 3-triangular-solve formulation
    (retained for the reference engine); "kernel" — the Pallas
    chol_solve_sample kernel. All three agree to fp32 rounding.
    """
    if solver is None:
        solver = "kernel" if use_kernel else "subst"
    if z is None:
        z = (
            jnp.zeros_like(rhs)
            if key is None
            else jax.random.normal(key, rhs.shape, rhs.dtype)
        )
    if solver == "kernel":
        from repro.kernels import ops as kops

        return kops.chol_solve_sample(prec, rhs, z)
    chol = jnp.linalg.cholesky(prec)
    if solver == "subst":
        return chol_subst_solve(chol, rhs, z)
    y = jax.lax.linalg.triangular_solve(
        chol, rhs[..., None], left_side=True, lower=True
    )
    mean = jax.lax.linalg.triangular_solve(
        chol, y, left_side=True, lower=True, transpose_a=True
    )
    noise = jax.lax.linalg.triangular_solve(
        chol, z[..., None], left_side=True, lower=True, transpose_a=True
    )
    return (mean + noise)[..., 0]


def update_factors(
    key: jax.Array,
    counterpart: jax.Array,
    buckets: Sequence[DeviceBucket],
    n_items: int,
    hyper: HyperParams,
    alpha: float,
    *,
    use_kernel: bool = False,
    engine: str | None = None,
    bf16_gather: bool = False,
) -> tuple[jax.Array, FactorStats]:
    """One half-sweep: resample every item factor given the counterpart matrix.

    Also returns the sufficient statistics of the *new* factor matrix (fused
    aggregation, paper Sec 3.1).

    The restructured flow (every engine except "reference") writes each
    bucket's per-segment statistics straight into their seg_item_ids slots:
    the per-item buffers start as the broadcast hyper-prior and receive ONE
    scatter-add of the concatenated per-segment outputs — the bucket plan
    partitions items, so indices are unique and items with no ratings keep
    the prior, exactly as in the seed flow. The seed flow's per-bucket
    full-size zero buffers and double scatter passes are gone.
    """
    engine = resolve_engine(engine, use_kernel)
    k = counterpart.shape[-1]
    dtype = counterpart.dtype

    if engine == "reference":
        prec_all = jnp.zeros((n_items, k, k), dtype)
        rhs_all = jnp.zeros((n_items, k), dtype)
        for b in buckets:
            prec, rhs = bucket_stats(counterpart, b, engine="reference")
            prec_all = prec_all.at[b.seg_item_ids].add(prec)
            rhs_all = rhs_all.at[b.seg_item_ids].add(rhs)
        prec_all = hyper.lam[None] + alpha * prec_all
        rhs_all = (hyper.lam @ hyper.mu)[None] + alpha * rhs_all
        new = sample_mvn_precision(key, prec_all, rhs_all, solver="lapack")
    else:
        seg = [
            bucket_stats(counterpart, b, engine=engine, bf16_gather=bf16_gather)
            for b in buckets
        ]
        ids = jnp.concatenate([b.seg_item_ids for b in buckets])
        prec_cat = jnp.concatenate([p for p, _ in seg])
        rhs_cat = jnp.concatenate([r for _, r in seg])
        prec_all = jnp.broadcast_to(hyper.lam, (n_items, k, k)).astype(dtype)
        rhs_all = jnp.broadcast_to(hyper.lam @ hyper.mu, (n_items, k)).astype(dtype)
        prec_all = prec_all.at[ids].add(
            (alpha * prec_cat).astype(dtype), unique_indices=True
        )
        rhs_all = rhs_all.at[ids].add(
            (alpha * rhs_cat).astype(dtype), unique_indices=True
        )
        solver = "kernel" if engine == "kernel" else "subst"
        new = sample_mvn_precision(key, prec_all, rhs_all, solver=solver)

    stats = FactorStats(
        sum_x=new.sum(0),
        sum_xxt=jnp.einsum("nk,nl->kl", new, new, preferred_element_type=jnp.float32),
        n=jnp.asarray(n_items, dtype),
    )
    return new, stats


def factor_stats(x: jax.Array) -> FactorStats:
    return FactorStats(
        sum_x=x.sum(0),
        sum_xxt=jnp.einsum("nk,nl->kl", x, x, preferred_element_type=jnp.float32),
        n=jnp.asarray(x.shape[0], x.dtype),
    )


class GibbsSampler:
    """Single-host BPMF sampler. `jit`-compiled sweep over bucketed plans.

    `engine` selects the sweep implementation (see ENGINES): the
    restructured einsum flow by default, "fused" for the gather-syrk
    kernel path, "kernel" for the two-step Pallas path (the legacy
    `use_kernel=True`), "reference" for the seed flow. `bf16_gather`
    (fused engine) gathers counterpart factors at half width with fp32
    accumulation.

    `widths` picks the bucket planner: the default "balanced" fits a
    degree-aware width ladder to each plan's own degree histogram
    (`core.buckets.balanced_widths` — the static work-stealing analogue;
    the user and item plans resolve independently), or pass an explicit
    tuple for a fixed ladder. The sampled chain is plan-independent up to
    fp32 reduction order — every ladder draws the same per-item noise.
    """

    # verbose run() progress cadence; SGLD steps are ~100x cheaper than
    # Gibbs sweeps, so its subclass prints far less often
    verbose_every = 5

    def __init__(
        self,
        ratings: SparseRatings,
        test: SparseRatings | None = None,
        *,
        k: int = 64,
        alpha: float = 1.5,
        burn_in: int = 8,
        widths: WidthsSpec = "balanced",
        use_kernel: bool = False,
        engine: str | None = None,
        bf16_gather: bool = False,
        dtype=jnp.float32,
    ):
        self.m, self.n = ratings.shape
        self.k = k
        self.alpha = alpha
        self.burn_in = burn_in
        self.engine = resolve_engine(engine, use_kernel)
        self.use_kernel = self.engine == "kernel"
        self.bf16_gather = bf16_gather
        self.dtype = dtype
        self.global_mean = ratings.mean()
        centered = ratings.centered()

        # Movie-major and user-major plans.
        uptr, uidx, uval = csr_from_coo(
            centered.rows, centered.cols, centered.vals, self.m
        )
        self.user_plan_host = plan_buckets(uptr, uidx, uval, self.m, self.n, widths)
        t = centered.transpose()
        vptr, vidx, vval = csr_from_coo(t.rows, t.cols, t.vals, self.n)
        self.item_plan_host = plan_buckets(vptr, vidx, vval, self.n, self.m, widths)
        self.user_buckets = device_plan(self.user_plan_host)
        self.item_buckets = device_plan(self.item_plan_host)

        if test is not None:
            self.test_rows = jnp.asarray(test.rows.astype(np.int32))
            self.test_cols = jnp.asarray(test.cols.astype(np.int32))
            self.test_vals = jnp.asarray(test.vals.astype(np.float32))
        else:
            self.test_rows = jnp.zeros((0,), jnp.int32)
            self.test_cols = jnp.zeros((0,), jnp.int32)
            self.test_vals = jnp.zeros((0,), jnp.float32)

        self.prior = default_prior(k, dtype)
        self._sweep = jax.jit(self._sweep_impl)

    def init(self, seed: int = 0) -> BPMFState:
        key = jax.random.PRNGKey(seed)
        ku, kv, key = jax.random.split(key, 3)
        return BPMFState(
            u=0.1 * jax.random.normal(ku, (self.m, self.k), self.dtype),
            v=0.1 * jax.random.normal(kv, (self.n, self.k), self.dtype),
            hyper_u=init_hyper(self.k, self.dtype),
            hyper_v=init_hyper(self.k, self.dtype),
            key=key,
            step=jnp.asarray(0, jnp.int32),
            pred_sum=jnp.zeros_like(self.test_vals),
            pred_count=jnp.asarray(0, jnp.int32),
        )

    # --- one full Gibbs sweep (Algorithm 1 body) ---
    def _sweep_impl(self, state: BPMFState) -> BPMFState:
        key, k_hv, k_v, k_hu, k_u = jax.random.split(state.key, 5)

        # Movies phase: hyper from V stats, then update V given U.
        sv = factor_stats(state.v)
        hyper_v = sample_normal_wishart(k_hv, sv.sum_x, sv.sum_xxt, sv.n, self.prior)
        v_new, _ = update_factors(
            k_v, state.u, self.item_buckets, self.n, hyper_v, self.alpha,
            engine=self.engine, bf16_gather=self.bf16_gather,
        )

        # Users phase: hyper from U stats, then update U given new V.
        su = factor_stats(state.u)
        hyper_u = sample_normal_wishart(k_hu, su.sum_x, su.sum_xxt, su.n, self.prior)
        u_new, _ = update_factors(
            k_u, v_new, self.user_buckets, self.m, hyper_u, self.alpha,
            engine=self.engine, bf16_gather=self.bf16_gather,
        )

        # Posterior-predictive accumulation after burn-in.
        preds = (
            jnp.einsum("nk,nk->n", u_new[self.test_rows], v_new[self.test_cols])
            + self.global_mean
        )
        collect = state.step >= self.burn_in
        pred_sum = jnp.where(collect, state.pred_sum + preds, state.pred_sum)
        pred_count = state.pred_count + jnp.where(collect, 1, 0)

        return BPMFState(
            u=u_new,
            v=v_new,
            hyper_u=hyper_u,
            hyper_v=hyper_v,
            key=key,
            step=state.step + 1,
            pred_sum=pred_sum,
            pred_count=pred_count,
        )

    def sweep(self, state: BPMFState) -> BPMFState:
        return self._sweep(state)

    def rmse(self, state: BPMFState) -> float:
        """Posterior-mean RMSE over the test set (paper's accuracy metric)."""
        if self.test_vals.shape[0] == 0:
            return float("nan")
        count = jnp.maximum(state.pred_count, 1)
        pred = state.pred_sum / count
        return float(jnp.sqrt(jnp.mean((pred - self.test_vals) ** 2)))

    def sample_rmse(self, state: BPMFState) -> float:
        """RMSE of the current single sample (no posterior averaging)."""
        if self.test_vals.shape[0] == 0:
            return float("nan")
        preds = (
            jnp.einsum(
                "nk,nk->n", state.u[self.test_rows], state.v[self.test_cols]
            )
            + self.global_mean
        )
        return float(jnp.sqrt(jnp.mean((preds - self.test_vals) ** 2)))

    def sample_dict(self, state: BPMFState, *, host: bool = True) -> dict:
        """The current draw in the flat SAMPLE_KEYS schema both publication
        paths consume. host=True copies arrays off-device (the durable
        SampleStore write); host=False hands the device arrays through
        as-is (the in-memory PublicationChannel publish — the subscriber
        stacks them without a host round trip)."""
        conv = np.asarray if host else (lambda x: x)
        return {
            "u": conv(state.u),
            "v": conv(state.v),
            "hyper_u_mu": conv(state.hyper_u.mu),
            "hyper_u_lam": conv(state.hyper_u.lam),
            "hyper_v_mu": conv(state.hyper_v.mu),
            "hyper_v_lam": conv(state.hyper_v.lam),
            "global_mean": np.asarray(self.global_mean, np.float32),
            "alpha": np.asarray(self.alpha, np.float32),
        }

    def retain_sample(self, state: BPMFState, store) -> None:
        """Persist the current draw into a checkpoint.SampleStore."""
        store.retain(int(state.step), self.sample_dict(state))

    def run(
        self,
        n_sweeps: int,
        seed: int = 0,
        verbose: bool = False,
        *,
        store=None,
        publish=None,
        thin: int = 1,
    ) -> BPMFState:
        """Run the chain; every `thin`-th post-burn-in draw is handed off to
        serving on up to two paths:

        * `store` (a checkpoint.SampleStore): the durable write — survives
          restarts, feeds cold server starts.
        * `publish` (a serve.publish.PublicationChannel): the asynchronous
          in-memory push to a co-running server — the draw is live before
          (and regardless of whether) the store's async write hits disk.
          The channel is left open; callers close() it when the co-running
          server should see end-of-stream.

        Both writes overlap the next sweep (the store's executor thread, the
        channel's subscriber threads) — publication never stalls the chain,
        which is the paper's async-communication discipline applied to the
        train -> serve hand-off.
        """
        if thin < 1:
            raise ValueError(f"thin must be >= 1, got {thin}")
        state = self.init(seed)
        for i in range(n_sweeps):
            state = self.sweep(state)
            if i >= self.burn_in and (i - self.burn_in) % thin == 0:
                if store is not None:
                    self.retain_sample(state, store)
                if publish is not None:
                    publish.publish(
                        int(state.step), self.sample_dict(state, host=False)
                    )
            if verbose and (i % self.verbose_every == 0 or i == n_sweeps - 1):
                print(f"sweep {i:3d}  sample-rmse {self.sample_rmse(state):.4f}")
        if store is not None:
            store.wait()
        return state
