"""Single-host BPMF Gibbs sampler over bucketed plans.

Algorithm 1 of the paper: per sweep, sample movie hyperparameters from V,
update every movie from (R, U); sample user hyperparameters from U, update
every user from (R, V); then predict the test points. The per-item update is

    Lambda_i = Lambda_hyper + alpha * sum_j v_j v_j^T     (j in ratings of i)
    b_i      = Lambda_hyper mu_hyper + alpha * sum_j r_ij v_j
    u_i      ~ N(Lambda_i^-1 b_i, Lambda_i^-1)

computed bucket-by-bucket as batched masked syrk (MXU) + batched Cholesky
sample — full inverses are never formed (paper Sec 3.1). The sufficient
statistics for the *next* hyperparameter draw are fused into the sweep.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import Bucket, BucketPlan, plan_buckets
from repro.core.hyper import (
    HyperParams,
    NWPrior,
    default_prior,
    init_hyper,
    sample_normal_wishart,
)
from repro.data.sparse import SparseRatings, csr_from_coo


class FactorStats(NamedTuple):
    """Sufficient statistics of a factor matrix, fused into the sweep."""

    sum_x: jax.Array    # (K,)
    sum_xxt: jax.Array  # (K, K)
    n: jax.Array        # scalar


class BPMFState(NamedTuple):
    u: jax.Array              # (M, K)
    v: jax.Array              # (N, K)
    hyper_u: HyperParams
    hyper_v: HyperParams
    key: jax.Array
    step: jax.Array
    # Posterior-predictive accumulators over test points (after burn-in).
    pred_sum: jax.Array       # (n_test,)
    pred_count: jax.Array     # scalar


class DeviceBucket(NamedTuple):
    """Device-resident copy of a host Bucket (jnp arrays)."""

    width: int
    indices: jax.Array
    values: jax.Array
    mask: jax.Array
    seg_ids: jax.Array
    n_segments: int
    seg_item_ids: jax.Array


def device_plan(
    plan: BucketPlan | Sequence[Bucket],
) -> tuple[DeviceBucket, ...]:
    """Move a host plan (or a bare bucket sequence, e.g. one the fold-in
    cache padded) onto the device."""
    if isinstance(plan, BucketPlan):
        plan = plan.buckets
    return tuple(
        DeviceBucket(
            width=b.width,
            indices=jnp.asarray(b.indices),
            values=jnp.asarray(b.values),
            mask=jnp.asarray(b.mask),
            seg_ids=jnp.asarray(b.seg_ids),
            n_segments=b.n_segments,
            seg_item_ids=jnp.asarray(b.seg_item_ids),
        )
        for b in plan
    )


def bucket_stats(
    counterpart: jax.Array, bucket: DeviceBucket, *, use_kernel: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Per-segment (sum v v^T, sum r v) for one bucket.

    counterpart is either one factor matrix (N, K) — the training sweep —
    or a stack of S retained draws (S, N, K) — the serving fold-in, where
    the same bucket plan (indices, ratings, mask are draw-independent) is
    applied against every draw's factors in one batched contraction.
    Returns (prec (..., n_segments, K, K), rhs (..., n_segments, K)) with
    the leading draw axis present iff counterpart carried one.
    """
    if counterpart.ndim == 2:
        vg = counterpart[bucket.indices]                # (rows, w, K)
        vm = vg * bucket.mask[..., None]
        if use_kernel:
            from repro.kernels import ops as kops

            prec_rows, rhs_rows = kops.masked_syrk(vm, bucket.values * bucket.mask)
        else:
            prec_rows = jnp.einsum(
                "rwk,rwl->rkl", vm, vm, preferred_element_type=jnp.float32
            )
            rhs_rows = jnp.einsum("rwk,rw->rk", vm, bucket.values * bucket.mask)
        prec = jax.ops.segment_sum(prec_rows, bucket.seg_ids, bucket.n_segments)
        rhs = jax.ops.segment_sum(rhs_rows, bucket.seg_ids, bucket.n_segments)
        return prec, rhs

    # stacked draws: one gather + one contraction covering all S draws
    vg = counterpart[:, bucket.indices]                 # (S, rows, w, K)
    vm = vg * bucket.mask[..., None]
    rv = bucket.values * bucket.mask
    if use_kernel:
        from repro.kernels import ops as kops

        prec_rows, rhs_rows = kops.masked_syrk(
            vm, jnp.broadcast_to(rv, vm.shape[:-1])
        )
    else:
        prec_rows = jnp.einsum(
            "srwk,srwl->srkl", vm, vm, preferred_element_type=jnp.float32
        )
        rhs_rows = jnp.einsum("srwk,rw->srk", vm, rv)
    # segment_sum reduces the leading axis; rotate rows to the front and back
    prec = jax.ops.segment_sum(
        prec_rows.transpose(1, 0, 2, 3), bucket.seg_ids, bucket.n_segments
    ).transpose(1, 0, 2, 3)
    rhs = jax.ops.segment_sum(
        rhs_rows.transpose(1, 0, 2), bucket.seg_ids, bucket.n_segments
    ).transpose(1, 0, 2)
    return prec, rhs


def sample_mvn_precision(
    key: jax.Array | None, prec: jax.Array, rhs: jax.Array,
    *, z: jax.Array | None = None, use_kernel: bool = False
) -> jax.Array:
    """x ~ N(prec^-1 rhs, prec^-1), batched over any leading axes.

    Cholesky-only (no inverse): with prec = L L^T,
      mean = L^-T (L^-1 rhs),  x = mean + L^-T z.
    key=None returns the posterior mean (the z = 0 limb of the same solve)
    — the serving fold-in's deterministic mode. An explicit `z` (same shape
    as rhs) overrides the key: the batched fold-in pre-draws its noise with
    the per-draw key sequence of the original per-sample loop, so fused and
    looped sampling consume identical random bits.
    """
    if z is None:
        z = (
            jnp.zeros_like(rhs)
            if key is None
            else jax.random.normal(key, rhs.shape, rhs.dtype)
        )
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.chol_solve_sample(prec, rhs, z)
    chol = jnp.linalg.cholesky(prec)
    y = jax.lax.linalg.triangular_solve(
        chol, rhs[..., None], left_side=True, lower=True
    )
    mean = jax.lax.linalg.triangular_solve(
        chol, y, left_side=True, lower=True, transpose_a=True
    )
    noise = jax.lax.linalg.triangular_solve(
        chol, z[..., None], left_side=True, lower=True, transpose_a=True
    )
    return (mean + noise)[..., 0]


def update_factors(
    key: jax.Array,
    counterpart: jax.Array,
    buckets: Sequence[DeviceBucket],
    n_items: int,
    hyper: HyperParams,
    alpha: float,
    *,
    use_kernel: bool = False,
) -> tuple[jax.Array, FactorStats]:
    """One half-sweep: resample every item factor given the counterpart matrix.

    Also returns the sufficient statistics of the *new* factor matrix (fused
    aggregation, paper Sec 3.1).
    """
    k = counterpart.shape[-1]
    dtype = counterpart.dtype
    prec_all = jnp.zeros((n_items, k, k), dtype)
    rhs_all = jnp.zeros((n_items, k), dtype)
    for b in buckets:
        prec, rhs = bucket_stats(counterpart, b, use_kernel=use_kernel)
        prec_all = prec_all.at[b.seg_item_ids].add(prec)
        rhs_all = rhs_all.at[b.seg_item_ids].add(rhs)

    prec_all = hyper.lam[None] + alpha * prec_all
    rhs_all = (hyper.lam @ hyper.mu)[None] + alpha * rhs_all
    new = sample_mvn_precision(key, prec_all, rhs_all, use_kernel=use_kernel)
    stats = FactorStats(
        sum_x=new.sum(0),
        sum_xxt=jnp.einsum("nk,nl->kl", new, new, preferred_element_type=jnp.float32),
        n=jnp.asarray(n_items, dtype),
    )
    return new, stats


def factor_stats(x: jax.Array) -> FactorStats:
    return FactorStats(
        sum_x=x.sum(0),
        sum_xxt=jnp.einsum("nk,nl->kl", x, x, preferred_element_type=jnp.float32),
        n=jnp.asarray(x.shape[0], x.dtype),
    )


class GibbsSampler:
    """Single-host BPMF sampler. `jit`-compiled sweep over bucketed plans."""

    def __init__(
        self,
        ratings: SparseRatings,
        test: SparseRatings | None = None,
        *,
        k: int = 64,
        alpha: float = 1.5,
        burn_in: int = 8,
        widths: tuple[int, ...] = (8, 32, 128, 512),
        use_kernel: bool = False,
        dtype=jnp.float32,
    ):
        self.m, self.n = ratings.shape
        self.k = k
        self.alpha = alpha
        self.burn_in = burn_in
        self.use_kernel = use_kernel
        self.dtype = dtype
        self.global_mean = ratings.mean()
        centered = ratings.centered()

        # Movie-major and user-major plans.
        uptr, uidx, uval = csr_from_coo(
            centered.rows, centered.cols, centered.vals, self.m
        )
        self.user_plan_host = plan_buckets(uptr, uidx, uval, self.m, self.n, widths)
        t = centered.transpose()
        vptr, vidx, vval = csr_from_coo(t.rows, t.cols, t.vals, self.n)
        self.item_plan_host = plan_buckets(vptr, vidx, vval, self.n, self.m, widths)
        self.user_buckets = device_plan(self.user_plan_host)
        self.item_buckets = device_plan(self.item_plan_host)

        if test is not None:
            self.test_rows = jnp.asarray(test.rows.astype(np.int32))
            self.test_cols = jnp.asarray(test.cols.astype(np.int32))
            self.test_vals = jnp.asarray(test.vals.astype(np.float32))
        else:
            self.test_rows = jnp.zeros((0,), jnp.int32)
            self.test_cols = jnp.zeros((0,), jnp.int32)
            self.test_vals = jnp.zeros((0,), jnp.float32)

        self.prior = default_prior(k, dtype)
        self._sweep = jax.jit(functools.partial(self._sweep_impl))

    def init(self, seed: int = 0) -> BPMFState:
        key = jax.random.PRNGKey(seed)
        ku, kv, key = jax.random.split(key, 3)
        return BPMFState(
            u=0.1 * jax.random.normal(ku, (self.m, self.k), self.dtype),
            v=0.1 * jax.random.normal(kv, (self.n, self.k), self.dtype),
            hyper_u=init_hyper(self.k, self.dtype),
            hyper_v=init_hyper(self.k, self.dtype),
            key=key,
            step=jnp.asarray(0, jnp.int32),
            pred_sum=jnp.zeros_like(self.test_vals),
            pred_count=jnp.asarray(0, jnp.int32),
        )

    # --- one full Gibbs sweep (Algorithm 1 body) ---
    def _sweep_impl(self, state: BPMFState) -> BPMFState:
        key, k_hv, k_v, k_hu, k_u = jax.random.split(state.key, 5)

        # Movies phase: hyper from V stats, then update V given U.
        sv = factor_stats(state.v)
        hyper_v = sample_normal_wishart(k_hv, sv.sum_x, sv.sum_xxt, sv.n, self.prior)
        v_new, _ = update_factors(
            k_v, state.u, self.item_buckets, self.n, hyper_v, self.alpha,
            use_kernel=self.use_kernel,
        )

        # Users phase: hyper from U stats, then update U given new V.
        su = factor_stats(state.u)
        hyper_u = sample_normal_wishart(k_hu, su.sum_x, su.sum_xxt, su.n, self.prior)
        u_new, _ = update_factors(
            k_u, v_new, self.user_buckets, self.m, hyper_u, self.alpha,
            use_kernel=self.use_kernel,
        )

        # Posterior-predictive accumulation after burn-in.
        preds = (
            jnp.einsum("nk,nk->n", u_new[self.test_rows], v_new[self.test_cols])
            + self.global_mean
        )
        collect = state.step >= self.burn_in
        pred_sum = jnp.where(collect, state.pred_sum + preds, state.pred_sum)
        pred_count = state.pred_count + jnp.where(collect, 1, 0)

        return BPMFState(
            u=u_new,
            v=v_new,
            hyper_u=hyper_u,
            hyper_v=hyper_v,
            key=key,
            step=state.step + 1,
            pred_sum=pred_sum,
            pred_count=pred_count,
        )

    def sweep(self, state: BPMFState) -> BPMFState:
        return self._sweep(state)

    def rmse(self, state: BPMFState) -> float:
        """Posterior-mean RMSE over the test set (paper's accuracy metric)."""
        if self.test_vals.shape[0] == 0:
            return float("nan")
        count = jnp.maximum(state.pred_count, 1)
        pred = state.pred_sum / count
        return float(jnp.sqrt(jnp.mean((pred - self.test_vals) ** 2)))

    def sample_rmse(self, state: BPMFState) -> float:
        """RMSE of the current single sample (no posterior averaging)."""
        if self.test_vals.shape[0] == 0:
            return float("nan")
        preds = (
            jnp.einsum(
                "nk,nk->n", state.u[self.test_rows], state.v[self.test_cols]
            )
            + self.global_mean
        )
        return float(jnp.sqrt(jnp.mean((preds - self.test_vals) ** 2)))

    def sample_dict(self, state: BPMFState, *, host: bool = True) -> dict:
        """The current draw in the flat SAMPLE_KEYS schema both publication
        paths consume. host=True copies arrays off-device (the durable
        SampleStore write); host=False hands the device arrays through
        as-is (the in-memory PublicationChannel publish — the subscriber
        stacks them without a host round trip)."""
        conv = np.asarray if host else (lambda x: x)
        return {
            "u": conv(state.u),
            "v": conv(state.v),
            "hyper_u_mu": conv(state.hyper_u.mu),
            "hyper_u_lam": conv(state.hyper_u.lam),
            "hyper_v_mu": conv(state.hyper_v.mu),
            "hyper_v_lam": conv(state.hyper_v.lam),
            "global_mean": np.asarray(self.global_mean, np.float32),
            "alpha": np.asarray(self.alpha, np.float32),
        }

    def retain_sample(self, state: BPMFState, store) -> None:
        """Persist the current draw into a checkpoint.SampleStore."""
        store.retain(int(state.step), self.sample_dict(state))

    def run(
        self,
        n_sweeps: int,
        seed: int = 0,
        verbose: bool = False,
        *,
        store=None,
        publish=None,
        thin: int = 1,
    ) -> BPMFState:
        """Run the chain; every `thin`-th post-burn-in draw is handed off to
        serving on up to two paths:

        * `store` (a checkpoint.SampleStore): the durable write — survives
          restarts, feeds cold server starts.
        * `publish` (a serve.publish.PublicationChannel): the asynchronous
          in-memory push to a co-running server — the draw is live before
          (and regardless of whether) the store's async write hits disk.
          The channel is left open; callers close() it when the co-running
          server should see end-of-stream.

        Both writes overlap the next sweep (the store's executor thread, the
        channel's subscriber threads) — publication never stalls the chain,
        which is the paper's async-communication discipline applied to the
        train -> serve hand-off.
        """
        if thin < 1:
            raise ValueError(f"thin must be >= 1, got {thin}")
        state = self.init(seed)
        for i in range(n_sweeps):
            state = self.sweep(state)
            if i >= self.burn_in and (i - self.burn_in) % thin == 0:
                if store is not None:
                    self.retain_sample(state, store)
                if publish is not None:
                    publish.publish(
                        int(state.step), self.sample_dict(state, host=False)
                    )
            if verbose and (i % 5 == 0 or i == n_sweeps - 1):
                print(f"sweep {i:3d}  sample-rmse {self.sample_rmse(state):.4f}")
        if store is not None:
            store.wait()
        return state
