"""ALS baseline (Zhou et al. 2008) over the same bucketed plans.

The paper positions BPMF against ALS/SGD (Sec 6). ALS solves, per item,

    (lambda * n_i * I + sum_j v_j v_j^T) u_i = sum_j r_ij v_j

— the same sufficient statistics as the BPMF conditional, minus sampling.
Reusing `bucket_stats` means the baseline exercises the identical data path
(gather + masked syrk + segment sum + batched Cholesky solve), isolating the
algorithmic difference exactly as the paper's comparison intends.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import plan_buckets
from repro.core.gibbs import DeviceBucket, bucket_stats, device_plan
from repro.data.sparse import SparseRatings, csr_from_coo


class ALSState(NamedTuple):
    u: jax.Array
    v: jax.Array
    step: jax.Array


def _solve_factors(
    counterpart: jax.Array,
    buckets: Sequence[DeviceBucket],
    n_items: int,
    lam_reg: float,
) -> jax.Array:
    k = counterpart.shape[-1]
    dtype = counterpart.dtype
    prec_all = jnp.zeros((n_items, k, k), dtype)
    rhs_all = jnp.zeros((n_items, k), dtype)
    counts = jnp.zeros((n_items,), dtype)
    for b in buckets:
        prec, rhs = bucket_stats(counterpart, b)
        prec_all = prec_all.at[b.seg_item_ids].add(prec)
        rhs_all = rhs_all.at[b.seg_item_ids].add(rhs)
        counts = counts.at[b.seg_item_ids].add(
            jax.ops.segment_sum(b.mask.sum(-1), b.seg_ids, b.n_segments)
        )
    # Weighted-lambda regularization (ALS-WR): lambda * n_i * I.
    reg = lam_reg * jnp.maximum(counts, 1.0)
    prec_all = prec_all + reg[:, None, None] * jnp.eye(k, dtype=dtype)[None]
    chol = jnp.linalg.cholesky(prec_all)
    y = jax.lax.linalg.triangular_solve(chol, rhs_all[..., None], left_side=True, lower=True)
    x = jax.lax.linalg.triangular_solve(
        chol, y, left_side=True, lower=True, transpose_a=True
    )
    return x[..., 0]


class ALS:
    def __init__(
        self,
        ratings: SparseRatings,
        test: SparseRatings | None = None,
        *,
        k: int = 64,
        lam_reg: float = 0.05,
        widths: tuple[int, ...] = (8, 32, 128, 512),
        dtype=jnp.float32,
    ):
        self.m, self.n = ratings.shape
        self.k = k
        self.lam_reg = lam_reg
        self.dtype = dtype
        self.global_mean = ratings.mean()
        centered = ratings.centered()
        uptr, uidx, uval = csr_from_coo(centered.rows, centered.cols, centered.vals, self.m)
        self.user_buckets = device_plan(plan_buckets(uptr, uidx, uval, self.m, self.n, widths))
        t = centered.transpose()
        vptr, vidx, vval = csr_from_coo(t.rows, t.cols, t.vals, self.n)
        self.item_buckets = device_plan(plan_buckets(vptr, vidx, vval, self.n, self.m, widths))
        if test is not None:
            self.test_rows = jnp.asarray(test.rows.astype(np.int32))
            self.test_cols = jnp.asarray(test.cols.astype(np.int32))
            self.test_vals = jnp.asarray(test.vals.astype(np.float32))
        else:
            self.test_rows = jnp.zeros((0,), jnp.int32)
            self.test_cols = jnp.zeros((0,), jnp.int32)
            self.test_vals = jnp.zeros((0,), jnp.float32)
        self._sweep = jax.jit(self._sweep_impl)

    def init(self, seed: int = 0) -> ALSState:
        key = jax.random.PRNGKey(seed)
        ku, kv = jax.random.split(key)
        return ALSState(
            u=0.1 * jax.random.normal(ku, (self.m, self.k), self.dtype),
            v=0.1 * jax.random.normal(kv, (self.n, self.k), self.dtype),
            step=jnp.asarray(0, jnp.int32),
        )

    def _sweep_impl(self, state: ALSState) -> ALSState:
        v_new = _solve_factors(state.u, self.item_buckets, self.n, self.lam_reg)
        u_new = _solve_factors(v_new, self.user_buckets, self.m, self.lam_reg)
        return ALSState(u=u_new, v=v_new, step=state.step + 1)

    def sweep(self, state: ALSState) -> ALSState:
        return self._sweep(state)

    def rmse(self, state: ALSState) -> float:
        if self.test_vals.shape[0] == 0:
            return float("nan")
        preds = (
            jnp.einsum("nk,nk->n", state.u[self.test_rows], state.v[self.test_cols])
            + self.global_mean
        )
        return float(jnp.sqrt(jnp.mean((preds - self.test_vals) ** 2)))

    def run(self, n_sweeps: int, seed: int = 0) -> ALSState:
        state = self.init(seed)
        for _ in range(n_sweeps):
            state = self.sweep(state)
        return state
