"""granite-moe-3b-a800m [moe] — IBM Granite 3.0 MoE family.

32L d_model=1536 24H (GQA kv=8) expert d_ff=512, vocab 49155, 40 experts
top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. Experts are padded
40 -> 48 so the expert axis divides the 16-wide model mesh axis (the 8 pad
experts are never routed to; memory overhead 17% of expert weights).
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=0,
    moe_d_ff=512,
    n_experts=40,
    n_experts_pad=48,
    n_experts_active=8,
    vocab_size=49155,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
