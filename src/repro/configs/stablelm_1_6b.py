"""stablelm-1.6b [dense] — full MHA (kv=32), LayerNorm.

24L d_model=2048 32H (kv=32) d_ff=5632 vocab 100352.
[hf:stabilityai/stablelm-2-1_6b; unverified]. Partial-rotary detail of the
HF config is simplified to full rotary (noted deviation).
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    norm_type="layer",
    tie_embeddings=False,
    qkv_bias=False,
)
