"""whisper-medium [audio] — encoder-decoder backbone; conv frontend STUB.

24+24L d_model=1024 16H d_ff=4096 vocab 51865, encoder 1500 frames.
[arXiv:2212.04356; unverified]. Per the grading spec the mel/conv frontend
is a stub: input_specs() provides precomputed (B, 1500, d) frame embeddings.
LayerNorm + GELU + qkv bias per the original architecture.
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    norm_type="layer",
    mlp_act="gelu",
    qkv_bias=True,
    tie_embeddings=True,
)
