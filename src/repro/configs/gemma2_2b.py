"""gemma2-2b [dense] — local/global alternating attention + logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim 256) d_ff=9216 vocab 256000.
Sliding window 4096 on odd layers, full attention on even; attn softcap 50,
final logit softcap 30; sandwich (post) norms; embeddings scaled by
sqrt(d_model). [arXiv:2408.00118; hf].
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    sliding_window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    mlp_act="gelu",
)
