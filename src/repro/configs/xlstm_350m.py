"""xlstm-350m [ssm] — sLSTM + mLSTM blocks, 7:1 ratio.

24L d_model=1024 4H vocab 50304. [arXiv:2405.04517; unverified].
Grouped as 3 x (7 mLSTM + 1 sLSTM); matrix-memory mLSTM runs the
chunkwise-parallel form for training, the exact recurrence for decode.
Sub-quadratic: runs the long_500k cell.
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=8,
    ssm_conv=4,
    tie_embeddings=True,
)
