"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8, head_dim 112) expert d_ff=2048,
vocab 163840, 384 experts top-8. [arXiv:2501.kimi2; unverified].
Deviations noted: the real K2 uses MLA and one dense layer + shared expert;
the assigned spec pins GQA kv=8 and uniform MoE, which we follow.
HBM posture at 512 chips: bf16 moments + FSDP over (pod, data) on the
largest weight dim (see DESIGN.md §6).
"""
import jax.numpy as jnp
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=0,
    moe_d_ff=2048,
    n_experts=384,
    n_experts_active=8,
    vocab_size=163_840,
    tie_embeddings=False,
    rope_theta=50_000.0,
    moment_dtype=jnp.bfloat16,
    fsdp_pod=True,
)
