"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution; patch frontend STUB.

28L d_model=3584 28H (GQA kv=4, head_dim 128) d_ff=18944 vocab 152064.
[arXiv:2409.12191; hf]. Per the grading spec the vision tower is a stub:
input_specs() provides precomputed patch embeddings (1024 patches) that are
prepended to the text tokens; positions carry the (t, h, w) M-RoPE channels
with sections (16, 24, 24) over the 64 rotary frequency lanes.
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    n_patches=1024,
)
