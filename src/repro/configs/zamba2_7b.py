"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab 32000, ssm_state=64.
[arXiv:2411.15242; unverified]. Structured as 3 groups of 27 Mamba2 layers,
each followed by one application of a weight-tied attention+MLP block
(Zamba's shared-block design). Mamba2: expand 2 -> d_inner 7168, headdim 64
-> 112 SSD heads. Hybrid: runs the long_500k cell (attention KV cache is
sequence-sharded across the mesh).
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14_336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=27,
    tie_embeddings=True,
)
