"""granite-20b [dense] — GPT-BigCode-style code model, MQA.

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab 49152.
[arXiv:2405.04324; hf]. Classic (non-gated) GELU MLP per the GPT-BigCode
lineage — with a gated MLP the parameter count lands at 28B, not 20B.
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    tie_embeddings=False,
    mlp_act="gelu",
    mlp_gated=False,
)
