"""Perf variants (EXPERIMENTS.md §Perf): beyond-paper optimization overlays.

`optimized(cfg)` applies the winning changes from the hillclimb log:
  - chunked online-softmax attention for all training/prefill lengths with
    bf16 probability blocks (never materializes the f32 S x S tensor);
  - per-sequence MoE dispatch groups (routing/sort/capacity stay local to
    each data shard; cross-shard movement reduces to the EP buffer reshard);
  - SSD decay folding + tuned chunk (one intra-chunk score tensor instead
    of three; chunk length balances intra-chunk quadratic traffic vs
    inter-chunk state traffic).

Baselines use the plain configs; the dry-run's --variant flag applies this
overlay so both tables stay reproducible.
"""
from __future__ import annotations

import dataclasses

from repro.models.layers import ModelConfig


def optimized(cfg: ModelConfig) -> ModelConfig:
    # chunked attention stays at the 8192 threshold: at 4k the chunk scan
    # re-gathers KV per block and LOST to the direct path (§Perf iteration
    # log) — bf16 probability tensors win in both paths instead.
    # remat_policy stays "nothing": "dots" cut compute 27% but needs 315GB
    # of temp per device (8x HBM); the named-probs policy saved the tensor
    # without avoiding the recompute (§Perf iterations 3-4). The deployable
    # fix for attention traffic is the Pallas flash kernel.
    upd: dict = dict(
        attn_probs_bf16=True,
    )
    if cfg.is_moe:
        upd["moe_group_dispatch"] = True     # grouped dispatch (no mesh needed)
        upd["moe_ep_shard_map"] = True       # explicit EP when a mesh is active
    if cfg.family in ("hybrid",):
        upd.update(ssm_chunk=64, ssd_fold_decay=True)
    # xlstm: slstm_reshard / bf16 gates measured neutral-to-negative at the
    # HLO level (§Perf) — the sLSTM needs a fused recurrent kernel instead;
    # the knobs exist but stay off in the shipped variant.
    return dataclasses.replace(cfg, **upd)


VARIANTS = {
    "base": lambda c: c,
    "opt": optimized,
}


def apply_variant(cfg: ModelConfig, name: str) -> ModelConfig:
    return VARIANTS[name](cfg)
