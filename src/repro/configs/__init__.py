"""Architecture registry: one module per assigned architecture.

`get_config(name)` returns the full published config; `reduced(cfg)` shrinks
it to a CPU-runnable smoke size of the same family (fewer/smaller layers,
fewer experts, tiny vocab) for tests. Full configs are only ever lowered via
ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.models.layers import ModelConfig

from repro.configs.granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from repro.configs.kimi_k2_1t_a32b import CONFIG as kimi_k2_1t_a32b
from repro.configs.granite_20b import CONFIG as granite_20b
from repro.configs.gemma2_2b import CONFIG as gemma2_2b
from repro.configs.stablelm_1_6b import CONFIG as stablelm_1_6b
from repro.configs.smollm_360m import CONFIG as smollm_360m
from repro.configs.xlstm_350m import CONFIG as xlstm_350m
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.qwen2_vl_7b import CONFIG as qwen2_vl_7b

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        granite_moe_3b_a800m,
        kimi_k2_1t_a32b,
        granite_20b,
        gemma2_2b,
        stablelm_1_6b,
        smollm_360m,
        xlstm_350m,
        whisper_medium,
        zamba2_7b,
        qwen2_vl_7b,
    )
}


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {name}; have {sorted(REGISTRY)}")
    return REGISTRY[key]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family miniature for CPU smoke tests."""
    upd: dict = dict(
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        head_dim=32,
        vocab_size=512,
        remat=False,
        chunked_attn_min_len=64,
        attn_chunk=32,
    )
    if cfg.family == "ssm":
        upd.update(n_layers=4, slstm_every=2, d_ff=0)
    elif cfg.family == "hybrid":
        upd.update(n_layers=4, attn_every=2, d_ff=256, ssm_state=16, ssm_headdim=32)
    elif cfg.family == "audio":
        upd.update(n_layers=2, encoder_layers=2, encoder_seq=24, d_ff=256)
    else:
        upd.update(n_layers=2, d_ff=256)
    if cfg.is_moe:
        # capacity_factor 8 = effectively dropless, so cache-consistency
        # invariants hold exactly in smoke tests
        upd.update(n_experts=8, n_experts_pad=8, n_experts_active=2, moe_d_ff=64,
                   d_ff=0, capacity_factor=8.0)
    if cfg.family == "vlm":
        upd.update(n_patches=8)
    if cfg.mrope_sections:
        upd.update(mrope_sections=(4, 6, 6))  # sums to head_dim/2 = 16
    if cfg.sliding_window:
        upd.update(sliding_window=16, local_global_period=cfg.local_global_period)
    return dataclasses.replace(cfg, **upd)


__all__ = ["REGISTRY", "get_config", "reduced", "ModelConfig"]
