"""Sweep-engine equivalence: the restructured/fused engines must produce
the same samples as the reference engine from a shared key — single-host,
distributed ring (subprocess: jax pins the device count at first init),
and the stacked-draw serving fold-in."""
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import GibbsSampler
from repro.core.gibbs import (
    chol_subst_solve,
    resolve_engine,
    sample_mvn_precision,
    update_factors,
)
from repro.data import synthetic_lowrank, train_test_split

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(scope="module")
def small_data():
    ratings, _, _ = synthetic_lowrank(200, 150, k_true=6, nnz=6000, noise=0.3, seed=2)
    return train_test_split(ratings, 0.1, seed=3)


# ---------------------------------------------------------------------------
# engine flag resolution
# ---------------------------------------------------------------------------
def test_resolve_engine():
    assert resolve_engine(None) == "einsum"
    assert resolve_engine(None, use_kernel=True) == "kernel"
    assert resolve_engine("fused") == "fused"
    with pytest.raises(ValueError):
        resolve_engine("warp")
    # 'sgld' is a valid --engine choice but not a sweep implementation:
    # the error must list the sweep engines AND point at the SGLD samplers
    with pytest.raises(ValueError, match="SGLDSampler"):
        resolve_engine("sgld")


# ---------------------------------------------------------------------------
# solver equivalence
# ---------------------------------------------------------------------------
def test_subst_solver_matches_lapack():
    rng = np.random.default_rng(0)
    b, k = 37, 24
    a = rng.normal(size=(b, k, k)).astype(np.float32)
    prec = jnp.asarray(a @ a.transpose(0, 2, 1) + 3 * np.eye(k, dtype=np.float32))
    rhs = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    x_l = sample_mvn_precision(None, prec, rhs, z=z, solver="lapack")
    x_s = sample_mvn_precision(None, prec, rhs, z=z, solver="subst")
    np.testing.assert_allclose(x_s, x_l, rtol=1e-4, atol=1e-4)
    # leading batch axes flatten-free (the fold-in's (S, B) stack)
    x2 = chol_subst_solve(
        jnp.linalg.cholesky(prec.reshape(1, b, k, k)),
        rhs.reshape(1, b, k), z.reshape(1, b, k),
    )
    np.testing.assert_allclose(x2[0], x_s, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# single-host: update_factors and full sweeps agree across engines
# ---------------------------------------------------------------------------
def test_update_factors_engines_match(small_data):
    train, _ = small_data
    s = GibbsSampler(train, None, k=16, alpha=8.0, widths=(8, 32, 128))
    state = s.init(0)
    key = jax.random.PRNGKey(42)
    out = {}
    for engine in ("reference", "einsum", "fused"):
        new, stats = update_factors(
            key, state.u, s.item_buckets, s.n, state.hyper_v, 8.0,
            engine=engine,
        )
        out[engine] = np.asarray(new)
        assert np.isfinite(out[engine]).all()
    np.testing.assert_allclose(out["einsum"], out["reference"], atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(out["fused"], out["reference"], atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("engine", ["einsum", "fused", "kernel"])
def test_gibbs_sweeps_identical_across_engines(small_data, engine):
    """Two full sweeps from one seed: every engine draws the same samples
    (shared z bits; only solve rounding differs)."""
    train, test = small_data
    ref = GibbsSampler(train, test, k=16, alpha=10.0, widths=(8, 32, 128),
                       engine="reference")
    alt = GibbsSampler(train, test, k=16, alpha=10.0, widths=(8, 32, 128),
                       engine=engine)
    st_r, st_a = ref.init(0), alt.init(0)
    for _ in range(2):
        st_r, st_a = ref.sweep(st_r), alt.sweep(st_a)
    np.testing.assert_allclose(np.asarray(st_a.u), np.asarray(st_r.u),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st_a.v), np.asarray(st_r.v),
                               atol=2e-3, rtol=2e-3)


def test_bf16_gather_engine_close_but_looser(small_data):
    train, _ = small_data
    f32 = GibbsSampler(train, None, k=16, alpha=10.0, widths=(8, 32),
                       engine="fused")
    bf16 = GibbsSampler(train, None, k=16, alpha=10.0, widths=(8, 32),
                        engine="fused", bf16_gather=True)
    st_f, st_b = f32.init(0), bf16.init(0)
    st_f, st_b = f32.sweep(st_f), bf16.sweep(st_b)
    # same chain to bf16-rounding tolerance (documented accuracy contract)
    np.testing.assert_allclose(np.asarray(st_b.u), np.asarray(st_f.u),
                               atol=0.05, rtol=0.05)
    assert np.abs(np.asarray(st_b.u) - np.asarray(st_f.u)).max() > 0


# ---------------------------------------------------------------------------
# distributed ring: fused engine matches einsum bit-for-bit per mode
# ---------------------------------------------------------------------------
def test_distributed_ring_engines_match():
    """Ring-mode fused vs einsum parity on 4 simulated devices. Kept small
    enough for tier-1 (two configs, tiny data); the full ring-vs-allgather
    cross-product lives in tests/test_distributed.py's slow suite."""
    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        f"import sys\nsys.path.insert(0, {SRC!r})\n"
        + textwrap.dedent("""
        import numpy as np
        from repro.core.distributed import DistributedBPMF
        from repro.data import synthetic_lowrank, train_test_split

        ratings, _, _ = synthetic_lowrank(100, 60, k_true=4, nnz=1500,
                                          noise=0.3, seed=3)
        train, test = train_test_split(ratings, 0.1, seed=4)
        outs = {}
        for engine in ('einsum', 'fused'):
            s = DistributedBPMF(train, test, k=8, alpha=10.0,
                                mode='ring', engine=engine)
            outs[engine] = s.gather_factors(s.run(2, seed=7))
        u1, v1 = outs['einsum']
        u2, v2 = outs['fused']
        np.testing.assert_allclose(u2, u1, atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(v2, v1, atol=2e-4, rtol=2e-4)
        print('dist engines ok')
        """)
    )
    res = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "dist engines ok" in res.stdout


@pytest.mark.slow
def test_distributed_allgather_engines_match():
    """Allgather-mode fused vs einsum parity + cross-mode agreement (the
    heavier cross-product, slow-marked per the distributed-test convention)."""
    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'\n"
        f"import sys\nsys.path.insert(0, {SRC!r})\n"
        + textwrap.dedent("""
        import numpy as np
        from repro.core.distributed import DistributedBPMF
        from repro.data import synthetic_lowrank, train_test_split

        ratings, _, _ = synthetic_lowrank(150, 100, k_true=4, nnz=3000,
                                          noise=0.3, seed=3)
        train, test = train_test_split(ratings, 0.1, seed=4)
        outs = {}
        for mode in ('ring', 'allgather'):
            for engine in ('einsum', 'fused'):
                s = DistributedBPMF(train, test, k=8, alpha=10.0,
                                    mode=mode, engine=engine)
                outs[(mode, engine)] = s.gather_factors(s.run(3, seed=7))
        for mode in ('ring', 'allgather'):
            u1, v1 = outs[(mode, 'einsum')]
            u2, v2 = outs[(mode, 'fused')]
            np.testing.assert_allclose(u2, u1, atol=2e-4, rtol=2e-4)
            np.testing.assert_allclose(v2, v1, atol=2e-4, rtol=2e-4)
        # and the ring still matches the sync baseline across engines
        np.testing.assert_allclose(outs[('ring', 'fused')][0],
                                   outs[('allgather', 'einsum')][0],
                                   atol=2e-3, rtol=2e-3)
        print('dist engines ok')
        """)
    )
    res = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "dist engines ok" in res.stdout


def test_per_item_noise_batched_bits_pinned():
    """Regression: the batched fold-in of the id vector produces the exact
    bits of folding each id separately (layout-independent determinism)."""
    from repro.core.distributed import _per_item_noise

    key = jax.random.PRNGKey(11)
    ids = jnp.asarray([5, 0, -1, 17, 3, 3], jnp.int32)
    got = np.asarray(_per_item_noise(key, ids, 8))
    want = np.stack([
        np.asarray(jax.random.normal(
            jax.random.fold_in(key, int(max(i, 0))), (8,), jnp.float32))
        for i in np.asarray(ids)
    ])
    assert np.array_equal(got, want)  # bit-exact, not allclose


# ---------------------------------------------------------------------------
# stacked-draw fold-in rides the fused kernel
# ---------------------------------------------------------------------------
def _toy_ensemble(rng, s=3, m=40, n=60, k=8):
    from repro.serve import PosteriorEnsemble

    def spd():
        a = rng.normal(size=(k, k)).astype(np.float32) / np.sqrt(k)
        return a @ a.T + 2.0 * np.eye(k, dtype=np.float32)

    return PosteriorEnsemble.from_arrays(
        rng.normal(size=(s, m, k)).astype(np.float32),
        rng.normal(size=(s, n, k)).astype(np.float32),
        hyper_u_mu=rng.normal(size=(s, k)).astype(np.float32) * 0.1,
        hyper_u_lam=np.stack([spd() for _ in range(s)]),
        hyper_v_mu=np.zeros((s, k), np.float32),
        hyper_v_lam=np.stack([np.eye(k, dtype=np.float32)] * s),
        global_mean=3.5,
        alpha=2.0,
        steps=list(range(s)),
    )


def _toy_batch(rng, n_new, n_items):
    from repro.data.sparse import SparseRatings

    rows, cols, vals = [], [], []
    for u in range(n_new):
        d = int(rng.integers(1, 9))
        rows.extend([u] * d)
        cols.extend(rng.choice(n_items, d, replace=False).tolist())
        vals.extend(rng.normal(3.5, 1.0, d).tolist())
    return SparseRatings(
        rows=np.asarray(rows, np.int32), cols=np.asarray(cols, np.int32),
        vals=np.asarray(vals, np.float32), shape=(n_new, n_items),
    )


@pytest.mark.parametrize("sample", [False, True])
def test_fold_in_fused_engine_matches_loop(sample):
    from repro.serve import fold_in, fold_in_loop

    rng = np.random.default_rng(0)
    ens = _toy_ensemble(rng)
    ratings = _toy_batch(rng, 7, ens.n_items)
    key = jax.random.PRNGKey(5) if sample else None
    out_loop = fold_in_loop(key, ratings, ens, sample=sample)
    out_ein = fold_in(key, ratings, ens, sample=sample, engine="einsum")
    out_fus = fold_in(key, ratings, ens, sample=sample, engine="fused")
    assert out_fus.shape == (ens.n_samples, 7, ens.k)
    np.testing.assert_allclose(np.asarray(out_ein), np.asarray(out_loop),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(out_fus), np.asarray(out_loop),
                               atol=2e-4, rtol=2e-4)


def test_fold_in_fused_engine_with_plan_cache_padding():
    """pad_bucket keeps seg_ids nondecreasing (pad rows -> last segment), so
    the fused engine accepts quantized/padded plans unchanged."""
    from repro.core.buckets import pad_bucket, plan_buckets
    from repro.data.sparse import csr_from_coo
    from repro.serve import FoldInPlanCache, fold_in

    rng = np.random.default_rng(1)
    ens = _toy_ensemble(rng)
    ratings = _toy_batch(rng, 5, ens.n_items)
    cache = FoldInPlanCache()
    out_exact = fold_in(None, ratings, ens, sample=False, engine="fused")
    out_padded = fold_in(None, ratings, ens, sample=False, engine="fused",
                         plan_cache=cache)
    np.testing.assert_allclose(np.asarray(out_padded), np.asarray(out_exact),
                               atol=1e-5, rtol=1e-5)

    # padding invariant directly
    indptr, idx, vals = csr_from_coo(ratings.rows, ratings.cols,
                                     ratings.vals, 5)
    plan = plan_buckets(indptr, idx, vals, 5, ens.n_items, (4, 16))
    for b in plan.buckets:
        pb = pad_bucket(b, b.rows + 3, b.n_segments + 2)
        assert (np.diff(pb.seg_ids) >= 0).all()
        assert pb.seg_ids[-1] == pb.n_segments - 1


def test_plan_cache_trace_flat_across_identity_flip():
    """Regression: two batches sharing a quantized schema must reuse one
    compiled executable even when padding makes one batch's seg_ids exactly
    arange (identity) and not the other's — the static plan key is derived
    from the schema, never from padded array contents."""
    from repro.serve import FoldInPlanCache, fold_in
    from repro.serve import foldin as foldin_mod

    rng = np.random.default_rng(4)
    ens = _toy_ensemble(rng)
    cache = FoldInPlanCache(widths=(4,), quantum=8)

    def one_rating_batch(n_new, seed):
        r = np.random.default_rng(seed)
        from repro.data.sparse import SparseRatings
        return SparseRatings(
            rows=np.arange(n_new, dtype=np.int32),
            cols=r.choice(ens.n_items, n_new, replace=False).astype(np.int32),
            vals=np.full(n_new, 3.0, np.float32),
            shape=(n_new, ens.n_items),
        )

    # 6 users -> pads 2 rows onto segment 7 (seg_ids != arange);
    # 7 users -> pads 1 row onto segment 7 (seg_ids == arange). Same schema.
    out6 = fold_in(None, one_rating_batch(6, 0), ens, sample=False,
                   plan_cache=cache)
    traces = foldin_mod.trace_count()
    out7 = fold_in(None, one_rating_batch(7, 1), ens, sample=False,
                   plan_cache=cache)
    assert foldin_mod.trace_count() == traces, "schema hit must not retrace"
    assert cache.hits == 1 and cache.misses == 1
    assert out6.shape[1] == 6 and out7.shape[1] == 7
