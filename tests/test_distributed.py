"""Distributed BPMF + grad compression. Multi-device tests run in
subprocesses (jax pins the device count at first init)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str, devices: int = 8) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        f"import sys\nsys.path.insert(0, {SRC!r})\n" + textwrap.dedent(code)
    )
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, timeout=600
    )
    assert res.returncode == 0, res.stderr[-4000:]
    return res.stdout


@pytest.mark.slow
def test_ring_equals_allgather_and_converges():
    out = run_sub("""
    import numpy as np, json
    from repro.data import synthetic_lowrank, train_test_split
    from repro.core.distributed import DistributedBPMF

    ratings, _, _ = synthetic_lowrank(300, 200, k_true=8, nnz=9000, noise=0.3, seed=3)
    train, test = train_test_split(ratings, 0.1, seed=4)
    ring = DistributedBPMF(train, test, k=16, alpha=11.0, mode="ring")
    s1 = ring.run(10, seed=7)
    sync = DistributedBPMF(train, test, k=16, alpha=11.0, mode="allgather")
    s2 = sync.run(10, seed=7)
    u1, v1 = ring.gather_factors(s1)
    u2, v2 = sync.gather_factors(s2)
    np.testing.assert_allclose(u1, u2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(v1, v2, rtol=2e-3, atol=2e-3)
    print(json.dumps({"ring": ring.rmse(s1), "sync": sync.rmse(s2)}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["ring"] - res["sync"]) < 1e-4
    assert res["ring"] < 0.7


@pytest.mark.slow
def test_distributed_matches_partition_invariants():
    out = run_sub("""
    import numpy as np, json
    from repro.data import synthetic_lowrank
    from repro.core.partition import partition_entities, build_grid_plan

    ratings, _, _ = synthetic_lowrank(200, 150, k_true=4, nnz=4000, noise=0.3, seed=5)
    up = partition_entities(ratings.degrees(0), 8)
    vp = partition_entities(ratings.degrees(1), 8)
    # every entity appears exactly once
    ids = up.ids[up.ids >= 0]
    assert sorted(ids.tolist()) == list(range(200))
    plan = build_grid_plan(ratings, up, vp, width=16)
    assert plan.mask.sum() == ratings.nnz
    # balance: LPT keeps per-shard cost within 30% of the mean
    from repro.core.buckets import workload_model
    cost = workload_model(ratings.degrees(0))
    loads = np.zeros(8)
    np.add.at(loads, up.shard, cost)
    assert loads.max() / loads.mean() < 1.3
    print(json.dumps(plan.stats()))
    """)
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["lane_efficiency"] > 0.03


def test_partition_heap_lpt_matches_argmin_reference():
    """Bit-equality regression for the O(N log P) heap rewrite of
    partition_entities: the heap pops the same (load, shard) minimum the
    old O(N*P) np.argmin scan found (argmin breaks load ties by lowest
    shard id; the (load, p) tuple order does the same), so assignments —
    not just balance — must be identical."""
    from repro.core.buckets import workload_model
    from repro.core.partition import partition_entities

    def argmin_reference(degrees, n_shards):
        cost = workload_model(degrees)
        order = np.argsort(-cost, kind="stable")
        loads = np.zeros(n_shards)
        shard = np.zeros(len(degrees), np.int32)
        for e in order:
            p = int(np.argmin(loads))
            shard[e] = p
            loads[p] += cost[e]
        return shard

    rng = np.random.default_rng(11)
    for n, p in [(1, 1), (7, 8), (200, 3), (500, 8), (333, 5)]:
        degrees = rng.zipf(1.7, size=n).astype(np.int64)
        degrees[rng.random(n) < 0.2] = 0  # ties: zero-degree entities
        got = partition_entities(degrees, p)
        np.testing.assert_array_equal(got.shard, argmin_reference(degrees, p))


def test_grid_plan_auto_width_no_worse_than_fixed():
    """width="auto" must keep the plan lossless and never pick a lane
    layout worse than the fixed default on a skewed profile."""
    from repro.data import chembl_like
    from repro.core.partition import partition_entities, build_grid_plan

    ratings, _, _ = chembl_like(scale=0.002, seed=0)
    up = partition_entities(ratings.degrees(0), 4)
    vp = partition_entities(ratings.degrees(1), 4)
    auto = build_grid_plan(ratings, up, vp, width="auto")
    fixed = build_grid_plan(ratings, up, vp, width=32)
    assert auto.mask.sum() == ratings.nnz
    assert auto.stats()["lane_efficiency"] >= fixed.stats()["lane_efficiency"]


def test_distributed_rejects_unknown_mode():
    from repro.data import synthetic_lowrank, train_test_split
    from repro.core.distributed import DistributedBPMF

    ratings, _, _ = synthetic_lowrank(40, 30, k_true=2, nnz=300, noise=0.3, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)
    with pytest.raises(ValueError, match="async"):
        DistributedBPMF(train, test, k=4, mode="gossip")


@pytest.mark.slow
def test_async_first_sweep_v_bitwise_and_rmse_parity():
    """The stale-by-one async sweep is bit-comparable at burn-in: sweep 1
    consumes fresh u for the v-phase (staleness only enters via u reading
    last sweep's v), so from equal init states async and ring must produce
    the SAME v draw bit-for-bit — and after burn-in both chains land on
    the same RMSE plateau."""
    out = run_sub("""
    import numpy as np, json
    from repro.data import synthetic_lowrank, train_test_split
    from repro.core.distributed import DistributedBPMF

    ratings, _, _ = synthetic_lowrank(300, 200, k_true=8, nnz=9000, noise=0.3, seed=3)
    train, test = train_test_split(ratings, 0.1, seed=4)
    ring = DistributedBPMF(train, test, k=16, alpha=11.0, mode="ring")
    asyn = DistributedBPMF(train, test, k=16, alpha=11.0, mode="async")
    s1 = ring.sweep(ring.init(7))
    s2 = asyn.sweep(asyn.init(7))
    _, v1 = ring.gather_factors(s1)
    _, v2 = asyn.gather_factors(s2, coupled=False)   # fresh v, not the eval pair
    assert np.array_equal(np.asarray(v1), np.asarray(v2)), "first-sweep v diverged"
    # rmse() pairs u with v_eval (the v it conditioned on): the
    # same-index (u, v) pair mixes the two interleaved chains and
    # plateaus visibly high — pin that the coupled pair does not
    for _ in range(19):
        s1 = ring.sweep(s1)
        s2 = asyn.sweep(s2)
    print(json.dumps({"ring": ring.rmse(s1), "async": asyn.rmse(s2)}))
    """, devices=4)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["ring"] - res["async"]) < 0.05
    assert res["async"] < 0.7


@pytest.mark.slow
def test_int8_compressed_psum_error_feedback():
    out = run_sub("""
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.optim.compress import compress_init, compressed_psum, CompressState

    mesh = jax.make_mesh((8,), ("pod",))
    g_global = np.random.default_rng(0).normal(size=(8, 64, 32)).astype(np.float32)

    def f(g, err):
        out, st = compressed_psum({"w": g[0]}, CompressState(error={"w": err[0]}), "pod")
        return out["w"][None], st.error["w"][None]

    m = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                  out_specs=(P("pod"), P("pod")), check_vma=False)
    errs = np.zeros_like(g_global)
    # accumulate over rounds: error feedback keeps the running sum unbiased
    total_true = g_global.sum(0)
    out, errs2 = jax.jit(m)(jnp.asarray(g_global), jnp.asarray(errs))
    got = np.asarray(out)[0]
    rel = np.abs(got - total_true).max() / np.abs(total_true).max()
    assert rel < 0.05, rel
    # second round with carried error: residual shrinks the bias
    out2, _ = jax.jit(m)(jnp.asarray(g_global), errs2)
    print("ok", rel)
    """)
    assert "ok" in out


def test_compress_roundtrip_single_device():
    import jax.numpy as jnp
    from repro.optim.compress import int8_compress, int8_decompress

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    e = jnp.zeros_like(g)
    q, scale, new_e = int8_compress(g, e)
    deq = int8_decompress(q, scale)
    np.testing.assert_allclose(np.asarray(deq + new_e), np.asarray(g), atol=1e-5)
    assert np.abs(np.asarray(new_e)).max() <= float(scale) / 2 + 1e-6


@pytest.mark.slow
def test_moe_ep_shard_map_matches_grouped():
    """The shard_map EP dispatch (§Perf iteration 5) must be numerically
    faithful to the single-device grouped dispatch."""
    out = run_sub("""
    import numpy as np, dataclasses
    import jax, jax.numpy as jnp
    from repro.models.layers import ModelConfig, init_moe, moe_block, active_mesh

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=0, vocab_size=64, n_experts=8,
                      n_experts_active=2, moe_d_ff=16, capacity_factor=8.0,
                      dtype=jnp.float32, param_dtype=jnp.float32)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32), jnp.float32)
    o_ref, a_ref = moe_block(params, x, dataclasses.replace(cfg, moe_group_dispatch=True))
    cfg_ep = dataclasses.replace(cfg, moe_ep_shard_map=True)
    with mesh, active_mesh(mesh):
        o_ep, a_ep = jax.jit(lambda p, xx: moe_block(p, xx, cfg_ep))(params, x)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_ep), rtol=3e-3, atol=3e-3)
    # aux: local-mean estimator vs global — close but not identical
    np.testing.assert_allclose(float(a_ref), float(a_ep), rtol=5e-2)
    print("ep ok")
    """)
    assert "ep ok" in out
