"""End-to-end behaviour: training reduces loss; optimizer; schedules; specs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, get_config, reduced
from repro.data.tokens import TokenStream
from repro.launch.train import init_train_state, make_train_step
from repro.models import input_specs, supported_shapes
from repro.models.api import LM_SHAPES
from repro.optim import AdamWConfig, cosine_schedule


def test_train_loop_reduces_loss():
    cfg = reduced(get_config("smollm-360m"))
    opt = AdamWConfig(lr=2e-3)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt, total_steps=60))
    data = TokenStream(cfg, batch=4, seq=64)
    losses = []
    for i in range(50):
        state, metrics = step(state, {k: jnp.asarray(v) for k, v in data(i).items()})
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, (
        losses[:5], losses[-5:])
    assert int(state.step) == 50


def test_train_loop_moe_reduces_loss():
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    opt = AdamWConfig(lr=2e-3)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt, total_steps=40))
    data = TokenStream(cfg, batch=4, seq=64)
    losses = []
    for i in range(30):
        state, metrics = step(state, {k: jnp.asarray(v) for k, v in data(i).items()})
        losses.append(float(metrics["loss"]))
    # MoE routing stabilizes slower than dense at tiny scale; require a
    # clear monotone improvement rather than a large drop
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15


def test_adamw_converges_quadratic():
    from repro.optim import adamw_init, adamw_update

    opt = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(params, opt)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, st, _ = adamw_update(grads, st, params, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_norm():
    from repro.optim import clip_by_global_norm

    g = {"a": jnp.full((10,), 100.0), "b": jnp.full((5,), -100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4
    assert float(norm) > 100


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10, total_steps=100))
    lr_peak = float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10, total_steps=100))
    lr_end = float(cosine_schedule(100, peak_lr=1.0, warmup_steps=10, total_steps=100))
    assert lr0 == 0.0 and abs(lr_peak - 1.0) < 1e-6 and abs(lr_end - 0.1) < 1e-6


def test_input_specs_cover_all_cells():
    """Every (arch x supported shape) has well-formed ShapeDtypeStruct specs."""
    n_cells = 0
    for arch, cfg in REGISTRY.items():
        shapes = supported_shapes(cfg)
        if cfg.family in ("ssm", "hybrid"):
            assert any(s.name == "long_500k" for s in shapes), arch
        else:
            assert not any(s.name == "long_500k" for s in shapes), arch
        for shape in shapes:
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            for k, sd in specs.items():
                assert all(d > 0 for d in sd.shape), (arch, shape.name, k)
            n_cells += 1
    assert n_cells == 32  # 10 train + 10 prefill + 10 decode + 2 long_500k


def test_assigned_shape_table():
    names = [(s.name, s.seq_len, s.global_batch) for s in LM_SHAPES]
    assert names == [
        ("train_4k", 4096, 256),
        ("prefill_32k", 32768, 32),
        ("decode_32k", 32768, 128),
        ("long_500k", 524288, 1),
    ]
