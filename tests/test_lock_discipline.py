"""Lock-discipline regressions for the serving tier, plus the
`serve.faults.assert_holds` debug helper — the runtime half of the
``*_locked`` convention repro-lint (`python -m repro.analysis`) checks
statically. See docs/concurrency.md."""
import threading

import numpy as np
import pytest

from repro.checkpoint import as_retained_sample
from repro.serve import ClusterCoordinator, PosteriorEnsemble
from repro.serve.faults import HostHealth, assert_holds, debug_locks_enabled

M, N, K = 16, 23, 4


def _ensemble(steps) -> PosteriorEnsemble:
    samples = []
    for step in steps:
        rng = np.random.default_rng(step)
        samples.append(as_retained_sample(step, {
            "u": rng.normal(size=(M, K)).astype(np.float32),
            "v": rng.normal(size=(N, K)).astype(np.float32),
            "hyper_u_mu": np.zeros(K, np.float32),
            "hyper_u_lam": np.eye(K, dtype=np.float32),
            "hyper_v_mu": np.zeros(K, np.float32),
            "hyper_v_lam": np.eye(K, dtype=np.float32),
            "global_mean": np.float32(0.0),
            "alpha": np.float32(2.0),
        }))
    return PosteriorEnsemble(tuple(samples))


# ---------------------------------------------------------------------------
# assert_holds: the REPRO_DEBUG_LOCKS runtime check
# ---------------------------------------------------------------------------
def test_assert_holds_disabled_is_noop(monkeypatch):
    monkeypatch.delenv("REPRO_DEBUG_LOCKS", raising=False)
    assert not debug_locks_enabled()
    assert_holds(threading.Lock())  # unheld, but checks are off

    monkeypatch.setenv("REPRO_DEBUG_LOCKS", "0")
    assert not debug_locks_enabled()
    assert_holds(threading.Lock())


def test_assert_holds_plain_lock(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_LOCKS", "1")
    lock = threading.Lock()
    with pytest.raises(AssertionError, match="convention violation"):
        assert_holds(lock)
    with lock:
        assert_holds(lock)  # held: passes
    # the probe must not leave the lock held behind our back
    assert lock.acquire(blocking=False)
    lock.release()


def test_assert_holds_condition_ownership_is_exact(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG_LOCKS", "1")
    cond = threading.Condition()
    with pytest.raises(AssertionError):
        assert_holds(cond)
    with cond:
        assert_holds(cond)
    # Condition tracks the owning thread: held by ANOTHER thread must
    # still fail here (exact, unlike the plain-Lock probe)
    entered = threading.Event()
    release = threading.Event()

    def holder():
        with cond:
            entered.set()
            release.wait(5.0)

    t = threading.Thread(target=holder, daemon=True)
    t.start()
    assert entered.wait(5.0)
    try:
        with pytest.raises(AssertionError):
            assert_holds(cond)
    finally:
        release.set()
        t.join(timeout=5.0)


def test_locked_convention_enforced_on_hosthealth(monkeypatch):
    """_state_locked is the convention's runtime canary: unlocked entry
    raises under REPRO_DEBUG_LOCKS=1, the public locked path still works."""
    monkeypatch.setenv("REPRO_DEBUG_LOCKS", "1")
    health = HostHealth()
    health.register(0)
    assert health.state(0) == "healthy"  # acquires the lock, then delegates
    with pytest.raises(AssertionError):
        health._state_locked(0)


# ---------------------------------------------------------------------------
# fixed guarded-field findings: regressions
# ---------------------------------------------------------------------------
def test_freshness_percentiles_concurrent_with_commits():
    """freshness_percentiles() used to iterate the publish_to_fresh_s deque
    unlocked — a commit appending mid-iteration raised 'deque mutated
    during iteration'. Hammer the read path against a writer thread doing
    exactly what _commit_locked does."""
    coord = ClusterCoordinator(_ensemble([1]), n_hosts=2)
    stop = threading.Event()
    errors: list[Exception] = []

    def writer():
        i = 0
        while not stop.is_set():
            with coord._lock:
                coord.publish_to_fresh_s.append(float(i))
            i += 1

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    try:
        for _ in range(400):
            try:
                out = coord.freshness_percentiles()
            except RuntimeError as e:  # pragma: no cover - the regression
                errors.append(e)
                break
            assert set(out) == {"p50", "max"}
    finally:
        stop.set()
        t.join(timeout=5.0)
    assert not errors, f"deque mutated during unlocked iteration: {errors[0]}"


def test_epoch_and_layout_reads_are_locked():
    """The n_hosts/epoch properties and stats() must agree under the same
    lock the commit path takes — and never deadlock against it."""
    coord = ClusterCoordinator(_ensemble([3]), n_hosts=3)
    assert coord.n_hosts == 3
    assert coord.epoch == 3
    stats = coord.stats()
    assert stats["epoch"] == coord.epoch
    assert stats["n_hosts"] == coord.n_hosts


def test_rebind_shape_check_reads_committed_ensemble():
    """rebind() now snapshots the live ensemble under the lock before the
    shape comparison; same-shape rebinds still succeed and shape changes
    still raise."""
    coord = ClusterCoordinator(_ensemble([1]), n_hosts=2)
    rebound = coord.rebind(_ensemble([2]))
    assert rebound.epoch == 2
    grown = PosteriorEnsemble((
        as_retained_sample(5, {
            "u": np.zeros((M, K), np.float32),
            "v": np.zeros((N + 7, K), np.float32),
            "hyper_u_mu": np.zeros(K, np.float32),
            "hyper_u_lam": np.eye(K, dtype=np.float32),
            "hyper_v_mu": np.zeros(K, np.float32),
            "hyper_v_lam": np.eye(K, dtype=np.float32),
            "global_mean": np.float32(0.0),
            "alpha": np.float32(2.0),
        }),
    ))
    with pytest.raises(ValueError, match="rebuild"):
        coord.rebind(grown)
