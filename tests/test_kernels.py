"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# masked syrk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,w,k", [
    (8, 16, 8), (16, 32, 16), (8, 256, 64), (5, 33, 24), (1, 8, 64), (24, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_syrk_shapes(r, w, k, dtype):
    rng = np.random.default_rng(r * 1000 + w + k)
    vm = jnp.asarray(rng.normal(size=(r, w, k)), dtype)
    rv = jnp.asarray(rng.normal(size=(r, w)), dtype)
    p1, b1 = ops.masked_syrk(vm, rv)
    p2, b2 = ref.masked_syrk_ref(vm, rv)
    np.testing.assert_allclose(p1, p2, rtol=2e-5, atol=3e-4)
    np.testing.assert_allclose(b1, b2, rtol=2e-5, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(
    r=st.integers(1, 20), w=st.integers(1, 80), k=st.integers(1, 48),
    seed=st.integers(0, 1000),
)
def test_syrk_property(r, w, k, seed):
    rng = np.random.default_rng(seed)
    vm = jnp.asarray(rng.normal(size=(r, w, k)), jnp.float32)
    rv = jnp.asarray(rng.normal(size=(r, w)), jnp.float32)
    p1, b1 = ops.masked_syrk(vm, rv)
    p2, b2 = ref.masked_syrk_ref(vm, rv)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
    # precision matrices are symmetric PSD by construction
    np.testing.assert_allclose(p1, np.swapaxes(np.asarray(p1), 1, 2), atol=1e-5)


# ---------------------------------------------------------------------------
# fused cholesky-solve-sample
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,k", [(16, 16), (32, 64), (7, 24), (1, 8), (64, 32)])
def test_chol_solve_shapes(b, k):
    rng = np.random.default_rng(b + k)
    a = rng.normal(size=(b, k, k))
    prec = jnp.asarray(a @ np.transpose(a, (0, 2, 1)) + (k * 0.1 + 0.5) * np.eye(k), jnp.float32)
    rhs = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    x1 = ops.chol_solve_sample(prec, rhs, z)
    x2 = ref.chol_solve_sample_ref(prec, rhs, z)
    np.testing.assert_allclose(x1, x2, rtol=2e-3, atol=2e-3)


def test_chol_solve_zero_noise_solves_system():
    """With z = 0 the kernel output solves Lambda x = rhs exactly."""
    rng = np.random.default_rng(5)
    b, k = 8, 32
    a = rng.normal(size=(b, k, k))
    prec = jnp.asarray(a @ np.transpose(a, (0, 2, 1)) + 4.0 * np.eye(k), jnp.float32)
    rhs = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    x = ops.chol_solve_sample(prec, rhs, jnp.zeros_like(rhs))
    recon = jnp.einsum("bij,bj->bi", prec, x)
    np.testing.assert_allclose(recon, rhs, rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bh,s,d,window,cap", [
    (4, 128, 32, 0, 0.0),
    (2, 256, 64, 64, 0.0),
    (3, 128, 32, 0, 30.0),
    (1, 384, 64, 128, 50.0),
    (2, 200, 32, 0, 0.0),          # non-multiple S -> padding path
])
def test_flash_vs_ref(bh, s, d, window, cap):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=True, window=window, softcap=cap)
    o2 = ref.flash_attention_ref(q, k, v, causal=True, window=window, softcap=cap)
    np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4), (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, tol):
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), dtype)
    o1 = ops.flash_attention(q, k, v, causal=True)
    o2 = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), rtol=tol, atol=tol
    )


def test_flash_matches_model_chunked_attention():
    """The jnp chunked attention in models/layers.py is the second oracle."""
    from repro.models.layers import multi_head_attention

    rng = np.random.default_rng(3)
    b, s, h, hd = 2, 256, 4, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    o_model = multi_head_attention(q, k, v, causal=True, chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    o_kernel = ops.flash_attention(qf, kf, vf, causal=True)
    o_kernel = o_kernel.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(o_model, o_kernel, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# fused gather+syrk+segment-reduce (V stays in HBM; rows gathered in-kernel)
# ---------------------------------------------------------------------------
def _sorted_segments(rng, r, n_seg):
    """Nondecreasing dense segment ids with ragged boundaries: every segment
    gets at least one row, the rest are assigned at random."""
    assert r >= n_seg
    extra = np.sort(rng.integers(0, n_seg, r - n_seg))
    return np.sort(np.concatenate([np.arange(n_seg), extra])).astype(np.int32)


def _seg_ref(idx, val, msk, seg, n_seg, v):
    """numpy oracle: einsum row stats + segment scatter-add."""
    vm = np.asarray(v)[..., np.asarray(idx), :] * np.asarray(msk)[..., None]
    prec_rows = np.einsum("...rwk,...rwl->...rkl", vm, vm)
    rhs_rows = np.einsum("...rwk,...rw->...rk", vm, np.asarray(val * msk))
    shape = vm.shape[:-3] + (n_seg,)
    p = np.zeros(shape + vm.shape[-1:] * 2, np.float32)
    b = np.zeros(shape + vm.shape[-1:], np.float32)
    if vm.ndim == 3:
        np.add.at(p, seg, prec_rows)
        np.add.at(b, seg, rhs_rows)
    else:
        for s in range(vm.shape[0]):
            np.add.at(p[s], seg, prec_rows[s])
            np.add.at(b[s], seg, rhs_rows[s])
    return p, b


@pytest.mark.parametrize("r,w,n,k,n_seg", [
    (8, 16, 40, 8, 5),       # aligned rows, ragged segments
    (16, 32, 100, 16, 16),   # identity segments
    (13, 8, 20, 24, 9),      # rows need padding
    (24, 256, 60, 16, 11),   # multiple W tiles (double-buffered DMA path)
])
@pytest.mark.parametrize("interpret", [True, None])
def test_gather_syrk_seg_matches_reference(r, w, n, k, n_seg, interpret):
    """interpret=True runs the real Pallas kernel; None the jnp fused path."""
    rng = np.random.default_rng(r * 100 + w + n_seg)
    idx = jnp.asarray(rng.integers(0, n, (r, w)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(r, w)), jnp.float32)
    msk = jnp.asarray((rng.random((r, w)) > 0.3).astype(np.float32))
    seg = _sorted_segments(rng, r, n_seg)
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    p1, b1 = ops.gather_syrk_seg(
        idx, val, msk, jnp.asarray(seg), n_seg, v, interpret=interpret
    )
    p2, b2 = _seg_ref(idx, val, msk, seg, n_seg, v)
    assert p1.shape == (n_seg, k, k) and b1.shape == (n_seg, k)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("interpret", [True, None])
def test_gather_syrk_seg_stacked_draws(interpret):
    """The leading stacked-draw axis (serving fold-in) rides the same kernel."""
    rng = np.random.default_rng(7)
    s, r, w, n, k, n_seg = 3, 11, 16, 30, 8, 6
    idx = jnp.asarray(rng.integers(0, n, (r, w)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(r, w)), jnp.float32)
    msk = jnp.asarray((rng.random((r, w)) > 0.4).astype(np.float32))
    seg = _sorted_segments(rng, r, n_seg)
    v = jnp.asarray(rng.normal(size=(s, n, k)), jnp.float32)
    p1, b1 = ops.gather_syrk_seg(
        idx, val, msk, jnp.asarray(seg), n_seg, v, interpret=interpret
    )
    p2, b2 = _seg_ref(idx, val, msk, seg, n_seg, v)
    assert p1.shape == (s, n_seg, k, k)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("interpret", [True, None])
def test_gather_syrk_seg_bf16_gather_tolerance(interpret):
    """bf16 gather keeps fp32 accumulation: ~1e-2 relative, not 1e-4."""
    rng = np.random.default_rng(3)
    r, w, n, k, n_seg = 16, 32, 50, 16, 10
    idx = jnp.asarray(rng.integers(0, n, (r, w)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(r, w)), jnp.float32)
    msk = jnp.asarray((rng.random((r, w)) > 0.3).astype(np.float32))
    seg = _sorted_segments(rng, r, n_seg)
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    p1, b1 = ops.gather_syrk_seg(
        idx, val, msk, jnp.asarray(seg), n_seg, v,
        bf16_gather=True, interpret=interpret,
    )
    p2, b2 = _seg_ref(idx, val, msk, seg, n_seg, v)
    np.testing.assert_allclose(p1, p2, rtol=3e-2, atol=3e-1)
    np.testing.assert_allclose(b1, b2, rtol=3e-2, atol=3e-1)
    # and the fp32 path is strictly tighter on the same inputs
    p3, _ = ops.gather_syrk_seg(
        idx, val, msk, jnp.asarray(seg), n_seg, v, interpret=interpret
    )
    assert np.abs(np.asarray(p3) - p2).max() < np.abs(np.asarray(p1) - p2).max()


@pytest.mark.parametrize("r,w,n,k", [(8, 16, 40, 8), (16, 32, 100, 16), (5, 8, 20, 24)])
def test_gather_syrk_fused_matches_two_step(r, w, n, k):
    rng = np.random.default_rng(r + w + n)
    idx = jnp.asarray(rng.integers(0, n, (r, w)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(r, w)), jnp.float32)
    msk = jnp.asarray((rng.random((r, w)) > 0.3).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    p1, b1 = ops.gather_syrk(idx, val, msk, v, interpret=True)
    vm = v[idx] * msk[..., None]
    p2, b2 = ref.masked_syrk_ref(vm, val * msk)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
