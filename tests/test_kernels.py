"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# masked syrk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,w,k", [
    (8, 16, 8), (16, 32, 16), (8, 256, 64), (5, 33, 24), (1, 8, 64), (24, 128, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_syrk_shapes(r, w, k, dtype):
    rng = np.random.default_rng(r * 1000 + w + k)
    vm = jnp.asarray(rng.normal(size=(r, w, k)), dtype)
    rv = jnp.asarray(rng.normal(size=(r, w)), dtype)
    p1, b1 = ops.masked_syrk(vm, rv)
    p2, b2 = ref.masked_syrk_ref(vm, rv)
    np.testing.assert_allclose(p1, p2, rtol=2e-5, atol=3e-4)
    np.testing.assert_allclose(b1, b2, rtol=2e-5, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(
    r=st.integers(1, 20), w=st.integers(1, 80), k=st.integers(1, 48),
    seed=st.integers(0, 1000),
)
def test_syrk_property(r, w, k, seed):
    rng = np.random.default_rng(seed)
    vm = jnp.asarray(rng.normal(size=(r, w, k)), jnp.float32)
    rv = jnp.asarray(rng.normal(size=(r, w)), jnp.float32)
    p1, b1 = ops.masked_syrk(vm, rv)
    p2, b2 = ref.masked_syrk_ref(vm, rv)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
    # precision matrices are symmetric PSD by construction
    np.testing.assert_allclose(p1, np.swapaxes(np.asarray(p1), 1, 2), atol=1e-5)


# ---------------------------------------------------------------------------
# fused cholesky-solve-sample
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,k", [(16, 16), (32, 64), (7, 24), (1, 8), (64, 32)])
def test_chol_solve_shapes(b, k):
    rng = np.random.default_rng(b + k)
    a = rng.normal(size=(b, k, k))
    prec = jnp.asarray(a @ np.transpose(a, (0, 2, 1)) + (k * 0.1 + 0.5) * np.eye(k), jnp.float32)
    rhs = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    x1 = ops.chol_solve_sample(prec, rhs, z)
    x2 = ref.chol_solve_sample_ref(prec, rhs, z)
    np.testing.assert_allclose(x1, x2, rtol=2e-3, atol=2e-3)


def test_chol_solve_zero_noise_solves_system():
    """With z = 0 the kernel output solves Lambda x = rhs exactly."""
    rng = np.random.default_rng(5)
    b, k = 8, 32
    a = rng.normal(size=(b, k, k))
    prec = jnp.asarray(a @ np.transpose(a, (0, 2, 1)) + 4.0 * np.eye(k), jnp.float32)
    rhs = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    x = ops.chol_solve_sample(prec, rhs, jnp.zeros_like(rhs))
    recon = jnp.einsum("bij,bj->bi", prec, x)
    np.testing.assert_allclose(recon, rhs, rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bh,s,d,window,cap", [
    (4, 128, 32, 0, 0.0),
    (2, 256, 64, 64, 0.0),
    (3, 128, 32, 0, 30.0),
    (1, 384, 64, 128, 50.0),
    (2, 200, 32, 0, 0.0),          # non-multiple S -> padding path
])
def test_flash_vs_ref(bh, s, d, window, cap):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, d)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=True, window=window, softcap=cap)
    o2 = ref.flash_attention_ref(q, k, v, causal=True, window=window, softcap=cap)
    np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4), (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, tol):
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), dtype)
    o1 = ops.flash_attention(q, k, v, causal=True)
    o2 = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32), rtol=tol, atol=tol
    )


def test_flash_matches_model_chunked_attention():
    """The jnp chunked attention in models/layers.py is the second oracle."""
    from repro.models.layers import multi_head_attention

    rng = np.random.default_rng(3)
    b, s, h, hd = 2, 256, 4, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    o_model = multi_head_attention(q, k, v, causal=True, chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    o_kernel = ops.flash_attention(qf, kf, vf, causal=True)
    o_kernel = o_kernel.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(o_model, o_kernel, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# fused gather+syrk (V stays in HBM; rows gathered in-kernel)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,w,n,k", [(8, 16, 40, 8), (16, 32, 100, 16), (5, 8, 20, 24)])
def test_gather_syrk_fused_matches_two_step(r, w, n, k):
    rng = np.random.default_rng(r + w + n)
    idx = jnp.asarray(rng.integers(0, n, (r, w)), jnp.int32)
    val = jnp.asarray(rng.normal(size=(r, w)), jnp.float32)
    msk = jnp.asarray((rng.random((r, w)) > 0.3).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    p1, b1 = ops.gather_syrk(idx, val, msk, v)
    vm = v[idx] * msk[..., None]
    p2, b2 = ref.masked_syrk_ref(vm, val * msk)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-3)
