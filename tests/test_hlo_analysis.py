"""The HLO cost model: trip-count multiplication, collectives, shapes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import HloCostModel, roofline_terms


def test_scan_flops_multiplied_by_trip_count():
    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        return jax.lax.scan(body, h, ws)[0]

    h = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((17, 128, 128), jnp.float32)
    txt = jax.jit(f).lower(h, ws).compile().as_text()
    res = HloCostModel(txt).analyze()
    expect = 17 * 2 * 128**3
    assert abs(res["flops"] - expect) / expect < 0.01, res["flops"]


def test_nested_scan_flops():
    def inner(h, w):
        return h @ w, None

    def outer(h, ws):
        def step(carry, _):
            return jax.lax.scan(inner, carry, ws)[0], None
        return jax.lax.scan(step, h, None, length=3)[0]

    h = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    txt = jax.jit(outer).lower(h, ws).compile().as_text()
    res = HloCostModel(txt).analyze()
    expect = 3 * 5 * 2 * 64**3
    assert abs(res["flops"] - expect) / expect < 0.02, res["flops"]


def test_tuple_result_comment_shapes_parse():
    """Tuple types with /*index=N*/ comments must not break the parser."""
    hlo = """
HloModule m
ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  %t = (f32[4,4]{1,0}, /*index=1*/f32[8]{0}) tuple(%p, %p)
  ROOT %g = f32[4,4]{1,0} get-tuple-element(%t), index=0
}
"""
    res = HloCostModel(hlo).analyze()
    assert res["flops"] == 0


def test_collective_bytes_with_loop_multiplier():
    hlo = """
HloModule m
%body (arg: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %arg = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%arg), index=1
  %ag = f32[64,64]{1,0} all-reduce(%x), replica_groups={}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[64,64]) tuple(%ip, %ag)
}
%cond (arg: (s32[], f32[64,64])) -> pred[] {
  %arg = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t = (s32[], f32[64,64]) tuple(%zero, %p)
  %w = (s32[], f32[64,64]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %g = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""
    res = HloCostModel(hlo).analyze()
    assert res["collective_bytes"]["all-reduce"] == 10 * 64 * 64 * 4
    assert res["collective_counts"]["all-reduce"] == 10


def test_roofline_terms_dominance():
    t = roofline_terms(
        flops=197e12, hbm_bytes=819e9 / 2, collective_bytes_per_device=0,
        n_devices=4, peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
    )
    assert t["dominant"] == "compute_s"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["roofline_fraction"] - 1.0) < 1e-9
