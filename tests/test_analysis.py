"""repro-lint (`python -m repro.analysis`): per-rule fixture snippets
(positive, negative, suppression), baseline round-trip, CLI exit codes,
and the meta-test that the analyzer runs clean on this repo's live tree
against the checked-in baseline."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES, RULE_DOCS, analyze_source, main
from repro.analysis import baseline as baseline_mod

ROOT = Path(__file__).resolve().parents[1]


def src(code: str) -> str:
    return textwrap.dedent(code).lstrip("\n")


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# pass 1: lock discipline
# ---------------------------------------------------------------------------
GUARDED = src("""
    import threading

    class Coord:
        def __init__(self):
            self._lock = threading.Lock()
            self.epoch = 0

        def commit(self):
            with self._lock:
                self.epoch += 1

        def peek(self):
            return self.epoch
""")


def test_guarded_field_positive():
    (f,) = analyze_source(GUARDED, rules=["guarded-field"])
    assert f.rule == "guarded-field"
    assert f.scope == "Coord.peek"
    assert "'self.epoch'" in f.message and "_lock" in f.message


def test_guarded_field_locked_read_is_clean():
    ok = GUARDED.replace(
        "    def peek(self):\n        return self.epoch",
        "    def peek(self):\n        with self._lock:\n"
        "            return self.epoch",
    )
    assert ok != GUARDED
    assert analyze_source(ok, rules=["guarded-field"]) == []


def test_guarded_field_constructor_exempt():
    # the unlocked write in __init__ must neither flag nor poison inference
    code = GUARDED + src("""
        class Boot:
            def __init__(self):
                self._lock = threading.Lock()
                self.x = 0
                self.x = 1
    """)
    (f,) = analyze_source(code, rules=["guarded-field"])
    assert f.scope == "Coord.peek"


def test_guarded_field_mutator_call_counts_as_write():
    code = src("""
        import threading

        class Q:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def push(self, v):
                with self._lock:
                    self.items.append(v)

            def drain(self):
                return list(self.items)
    """)
    (f,) = analyze_source(code, rules=["guarded-field"])
    assert f.scope == "Q.drain"


def test_guarded_field_condition_alias_holds_the_lock():
    code = src("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._cond = threading.Condition(self._lock)
                self.n = 0

            def bump(self):
                with self._lock:
                    self.n += 1

            def wait_n(self):
                with self._cond:
                    return self.n
    """)
    assert analyze_source(code, rules=["guarded-field"]) == []


def test_guarded_field_nested_def_resets_held():
    # a thread target defined under `with lock` runs later, without it
    code = src("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0

            def go(self):
                with self._lock:
                    self.n = 1
                    def worker():
                        return self.n
                    return worker
    """)
    (f,) = analyze_source(code, rules=["guarded-field"])
    # findings are keyed to the defining method's scope
    assert f.scope == "C.go"
    assert "read of 'self.n'" in f.message


LOCKED_CALL = src("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def _pick_locked(self):
            return 1

        def good(self):
            with self._lock:
                return self._pick_locked()

        def also_good_locked(self):
            return self._pick_locked()

        def bad(self):
            return self._pick_locked()
""")


def test_locked_call_positive_and_convention_negative():
    (f,) = analyze_source(LOCKED_CALL, rules=["locked-call"])
    assert f.scope == "C.bad"
    assert "_pick_locked" in f.message


def test_lock_reacquire_flags_plain_lock_only():
    code = src("""
        import threading

        class A:
            def __init__(self):
                self._lock = threading.Lock()

            def _step_locked(self):
                with self._lock:
                    return 1

        class B:
            def __init__(self):
                self._lock = threading.RLock()

            def _step_locked(self):
                with self._lock:
                    return 1
    """)
    (f,) = analyze_source(code, rules=["lock-reacquire"])
    assert f.scope == "A._step_locked"
    assert "deadlock" in f.message


# ---------------------------------------------------------------------------
# pass 2: retrace hazards
# ---------------------------------------------------------------------------
def test_traced_branch_positive_decorator_form():
    code = src("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    (f,) = analyze_source(code, rules=["traced-branch"])
    assert "branches in Python" in f.message and "'x'" in f.message


def test_traced_branch_static_and_shape_exemptions():
    code = src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, mode, y=None):
            if mode == "fast":            # static: exempt
                return x
            if x.shape[0] > 2:            # shape projection: exempt
                pass
            if y is None:                 # trace-time None check: exempt
                return x
            for _ in range(len(x)):       # len(): exempt
                pass
            return x + y
    """)
    assert analyze_source(code, rules=["traced-branch"]) == []


def test_traced_branch_container_annotation_exempt():
    # pytree STRUCTURE is part of the jit cache key (serve/foldin.py)
    code = src("""
        import jax

        @jax.jit
        def f(arrays: tuple, x):
            for a in arrays:
                x = x + a
            for b in x:
                pass
            return x
    """)
    (f,) = analyze_source(code, rules=["traced-branch"])
    assert "'x'" in f.message


def test_shape_leak_positive_and_fstring():
    code = src("""
        import jax

        @jax.jit
        def f(x):
            n = int(x)
            name = f"val={x}"
            safe = int(x.shape[0])
            return n, name, safe
    """)
    found = analyze_source(code, rules=["shape-leak"])
    assert rules_of(found) == ["shape-leak", "shape-leak"]
    assert "int(...)" in found[0].message
    assert "f-string" in found[1].message


def test_static_args_typo_and_unhashable_call_site():
    code = src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("shap",))
        def f(x, shape):
            return x

        def caller(x):
            return f(x, shape=[1, 2])
    """)
    found = analyze_source(code, rules=["static-args"])
    # the typo'd name is reported; the call site is not (the typo'd name
    # is what got pinned, right or wrong)
    assert any("'shap' is not a parameter" in f.message for f in found)


def test_static_args_unhashable_value():
    code = src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("widths",))
        def f(x, widths):
            return x

        def caller(x):
            return f(x, widths=[8, 16])
    """)
    found = analyze_source(code, rules=["static-args"])
    assert len(found) == 1 and "unhashable" in found[0].message


def test_static_args_non_literal_argnums():
    code = src("""
        import jax

        NUMS = (1,)

        @jax.jit(static_argnums=NUMS)
        def f(x, n):
            return x
    """)
    (f,) = analyze_source(code, rules=["static-args"])
    assert "literal" in f.message


def test_bound_method_jit_assignment_is_recognized():
    code = src("""
        import jax

        class Sweeper:
            def __init__(self):
                self._sweep = jax.jit(self._sweep_impl)

            def _sweep_impl(self, state):
                if state:
                    return state
                return state
    """)
    (f,) = analyze_source(code, rules=["traced-branch"])
    assert "'state'" in f.message


# ---------------------------------------------------------------------------
# pass 3: device sync under a coordinator lock
# ---------------------------------------------------------------------------
def test_sync_under_lock_positive_and_negative():
    code = src("""
        import threading
        import jax.numpy as jnp

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def bad(self, x):
                with self._lock:
                    return jnp.asarray(x)

            def good(self, x):
                y = jnp.asarray(x)
                with self._lock:
                    return y
    """)
    (f,) = analyze_source(code, rules=["sync-under-lock"])
    assert f.scope == "C.bad"


def test_sync_under_lock_tree_util_allowlisted():
    code = src("""
        import threading
        import jax

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def ok(self, x):
                with self._lock:
                    return jax.tree_util.tree_map(lambda a: a, x)
    """)
    assert analyze_source(code, rules=["sync-under-lock"]) == []


# ---------------------------------------------------------------------------
# pass 4: PRNG key discipline
# ---------------------------------------------------------------------------
def test_prng_reuse_positive():
    code = src("""
        import jax

        def draw(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert "'key'" in f.message and f.line == 5


def test_prng_split_between_uses_is_clean():
    code = src("""
        import jax

        def draw(key):
            key, sub = jax.random.split(key)
            a = jax.random.normal(sub, (3,))
            key, sub = jax.random.split(key)
            b = jax.random.normal(sub, (3,))
            return a + b
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_sibling_branches_do_not_taint_each_other():
    code = src("""
        import jax

        def draw(key, fast):
            if fast:
                return jax.random.normal(key, (3,))
            else:
                return jax.random.uniform(key, (3,))
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_early_return_arm_excluded_from_merge():
    # the core/distributed.py sweep shape: the async arm consumes the
    # keys and returns; the sync path below is mutually exclusive with it
    code = src("""
        import jax

        def sweep(key, mode):
            k1, k2 = jax.random.split(key)
            if mode == "async":
                return jax.random.normal(k1, (3,)) + jax.random.normal(k2, (3,))
            a = jax.random.normal(k1, (3,))
            return a + jax.random.normal(k2, (3,))
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_fallthrough_arm_still_taints():
    code = src("""
        import jax

        def sweep(key, warm):
            if warm:
                a = jax.random.normal(key, (3,))
            return jax.random.normal(key, (3,))
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert f.line == 6


def test_prng_loop_carried_reuse():
    code = src("""
        import jax

        def draws(key, n):
            out = []
            for _ in range(n):
                out.append(jax.random.normal(key, (3,)))
            return out
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert f.line == 6


def test_prng_per_iteration_split_ledger_is_clean():
    code = src("""
        import jax

        def draws(key, n):
            out = []
            for _ in range(n):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, (3,)))
            return out
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_fold_in_and_validators_do_not_consume():
    code = src("""
        import jax

        def fan_out(key, ids):
            _check_args(key, ids)
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)
            return jax.random.normal(key, (3,)), keys
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_randint_selection_counts_as_consumption():
    # the SGLD minibatch pattern: row selection via jax.random.randint is
    # a draw like any other — reusing its key for the noise must flag
    code = src("""
        import jax

        def step(key, factors):
            rows = jax.random.randint(key, (4,), 0, 10)
            return rows, jax.random.normal(key, factors.shape)
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert "'key'" in f.message and f.line == 5


def test_prng_per_bucket_fold_in_chain_is_clean():
    # core/sgld.py's bucket loop: fold_in derives an independent stream
    # per bucket without consuming the parent key
    code = src("""
        import jax

        def minibatch(key, buckets):
            out = []
            for b in range(len(buckets)):
                kb = jax.random.fold_in(key, b)
                out.append(jax.random.randint(kb, (4,), 0, 10))
            return out
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_stateful_numpy_generator_not_tracked():
    code = src("""
        import numpy as np

        def fixture():
            rng = np.random.default_rng(0)
            a = make(rng)
            b = make(rng)
            return a, b
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------
def test_suppression_comment_silences_one_rule():
    flagged = GUARDED.replace(
        "        return self.epoch",
        "        return self.epoch  # repro-lint: disable=guarded-field (snapshot read)",
    )
    assert flagged != GUARDED
    assert analyze_source(flagged) == []
    # a different rule on the same line is NOT silenced
    wrong = GUARDED.replace(
        "        return self.epoch",
        "        return self.epoch  # repro-lint: disable=prng-reuse",
    )
    assert rules_of(analyze_source(wrong)) == ["guarded-field"]


# ---------------------------------------------------------------------------
# baseline round-trip + CLI
# ---------------------------------------------------------------------------
def test_baseline_round_trip(tmp_path):
    target = tmp_path / "mod.py"
    target.write_text(GUARDED)
    base_file = tmp_path / "base.json"

    args = [str(target), "--root", str(tmp_path), "--baseline", str(base_file)]
    assert main(args) == 1                      # finding, no baseline yet
    assert main([*args, "--write-baseline"]) == 0
    assert main(args) == 0                      # grandfathered

    data = json.loads(base_file.read_text())
    assert data["version"] == baseline_mod.BASELINE_VERSION
    (key,) = data["findings"]
    assert key.startswith("mod.py::guarded-field::Coord.peek::")

    # baseline keys survive line churn but not edits to the flagged line
    target.write_text("# a new leading comment\n" + GUARDED)
    assert main(args) == 0
    target.write_text(GUARDED.replace("return self.epoch",
                                      "return self.epoch + 1"))
    assert main(args) == 1


def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean), "--root", str(tmp_path)]) == 0
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ALL_RULES:
        assert rule in out
    assert main([str(clean), "--rules", "no-such-rule"]) == 2
    assert main([str(tmp_path / "missing.py")]) == 2

    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n")
    assert main([str(bad), "--root", str(tmp_path)]) == 2


def test_cli_json_format(tmp_path, capsys):
    target = tmp_path / "mod.py"
    target.write_text(GUARDED)
    rc = main([str(target), "--root", str(tmp_path), "--format", "json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["summary"] == {"guarded-field": 1}
    (finding,) = payload["findings"]
    assert finding["path"] == "mod.py"
    assert finding["rule"] == "guarded-field"


def test_rule_docs_cover_every_rule():
    assert set(RULE_DOCS) == set(ALL_RULES)


# ---------------------------------------------------------------------------
# meta: the live tree is clean modulo the checked-in baseline
# ---------------------------------------------------------------------------
def test_analyzer_clean_on_live_tree():
    """`python -m repro.analysis src tests` must exit 0 against the
    checked-in baseline — the same invocation the CI lint job gates on.
    A failure here means a new finding: fix it, suppress it in-line with a
    justification, or (last resort) regenerate the baseline."""
    rc = main([
        str(ROOT / "src"), str(ROOT / "tests"),
        "--root", str(ROOT),
        "--baseline", str(ROOT / baseline_mod.DEFAULT_BASELINE),
    ])
    assert rc == 0
