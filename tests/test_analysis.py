"""repro-lint (`python -m repro.analysis`): per-rule fixture snippets
(positive, negative, suppression), baseline round-trip, CLI exit codes,
and the meta-test that the analyzer runs clean on this repo's live tree
against the checked-in baseline."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import ALL_RULES, RULE_DOCS, analyze_source, main
from repro.analysis import baseline as baseline_mod

ROOT = Path(__file__).resolve().parents[1]


def src(code: str) -> str:
    return textwrap.dedent(code).lstrip("\n")


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# pass 1: lock discipline
# ---------------------------------------------------------------------------
GUARDED = src("""
    import threading

    class Coord:
        def __init__(self):
            self._lock = threading.Lock()
            self.epoch = 0

        def commit(self):
            with self._lock:
                self.epoch += 1

        def peek(self):
            return self.epoch
""")


def test_guarded_field_positive():
    (f,) = analyze_source(GUARDED, rules=["guarded-field"])
    assert f.rule == "guarded-field"
    assert f.scope == "Coord.peek"
    assert "'self.epoch'" in f.message and "_lock" in f.message


def test_guarded_field_locked_read_is_clean():
    ok = GUARDED.replace(
        "    def peek(self):\n        return self.epoch",
        "    def peek(self):\n        with self._lock:\n"
        "            return self.epoch",
    )
    assert ok != GUARDED
    assert analyze_source(ok, rules=["guarded-field"]) == []


def test_guarded_field_constructor_exempt():
    # the unlocked write in __init__ must neither flag nor poison inference
    code = GUARDED + src("""
        class Boot:
            def __init__(self):
                self._lock = threading.Lock()
                self.x = 0
                self.x = 1
    """)
    (f,) = analyze_source(code, rules=["guarded-field"])
    assert f.scope == "Coord.peek"


def test_guarded_field_mutator_call_counts_as_write():
    code = src("""
        import threading

        class Q:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def push(self, v):
                with self._lock:
                    self.items.append(v)

            def drain(self):
                return list(self.items)
    """)
    (f,) = analyze_source(code, rules=["guarded-field"])
    assert f.scope == "Q.drain"


def test_guarded_field_condition_alias_holds_the_lock():
    code = src("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._cond = threading.Condition(self._lock)
                self.n = 0

            def bump(self):
                with self._lock:
                    self.n += 1

            def wait_n(self):
                with self._cond:
                    return self.n
    """)
    assert analyze_source(code, rules=["guarded-field"]) == []


def test_guarded_field_nested_def_resets_held():
    # a thread target defined under `with lock` runs later, without it
    code = src("""
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0

            def go(self):
                with self._lock:
                    self.n = 1
                    def worker():
                        return self.n
                    return worker
    """)
    (f,) = analyze_source(code, rules=["guarded-field"])
    # findings are keyed to the defining method's scope
    assert f.scope == "C.go"
    assert "read of 'self.n'" in f.message


LOCKED_CALL = src("""
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def _pick_locked(self):
            return 1

        def good(self):
            with self._lock:
                return self._pick_locked()

        def also_good_locked(self):
            return self._pick_locked()

        def bad(self):
            return self._pick_locked()
""")


def test_locked_call_positive_and_convention_negative():
    (f,) = analyze_source(LOCKED_CALL, rules=["locked-call"])
    assert f.scope == "C.bad"
    assert "_pick_locked" in f.message


def test_lock_reacquire_flags_plain_lock_only():
    code = src("""
        import threading

        class A:
            def __init__(self):
                self._lock = threading.Lock()

            def _step_locked(self):
                with self._lock:
                    return 1

        class B:
            def __init__(self):
                self._lock = threading.RLock()

            def _step_locked(self):
                with self._lock:
                    return 1
    """)
    (f,) = analyze_source(code, rules=["lock-reacquire"])
    assert f.scope == "A._step_locked"
    assert "deadlock" in f.message


# ---------------------------------------------------------------------------
# pass 2: retrace hazards
# ---------------------------------------------------------------------------
def test_traced_branch_positive_decorator_form():
    code = src("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    (f,) = analyze_source(code, rules=["traced-branch"])
    assert "branches in Python" in f.message and "'x'" in f.message


def test_traced_branch_static_and_shape_exemptions():
    code = src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, mode, y=None):
            if mode == "fast":            # static: exempt
                return x
            if x.shape[0] > 2:            # shape projection: exempt
                pass
            if y is None:                 # trace-time None check: exempt
                return x
            for _ in range(len(x)):       # len(): exempt
                pass
            return x + y
    """)
    assert analyze_source(code, rules=["traced-branch"]) == []


def test_traced_branch_container_annotation_exempt():
    # pytree STRUCTURE is part of the jit cache key (serve/foldin.py)
    code = src("""
        import jax

        @jax.jit
        def f(arrays: tuple, x):
            for a in arrays:
                x = x + a
            for b in x:
                pass
            return x
    """)
    (f,) = analyze_source(code, rules=["traced-branch"])
    assert "'x'" in f.message


def test_shape_leak_positive_and_fstring():
    code = src("""
        import jax

        @jax.jit
        def f(x):
            n = int(x)
            name = f"val={x}"
            safe = int(x.shape[0])
            return n, name, safe
    """)
    found = analyze_source(code, rules=["shape-leak"])
    assert rules_of(found) == ["shape-leak", "shape-leak"]
    assert "int(...)" in found[0].message
    assert "f-string" in found[1].message


def test_static_args_typo_and_unhashable_call_site():
    code = src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("shap",))
        def f(x, shape):
            return x

        def caller(x):
            return f(x, shape=[1, 2])
    """)
    found = analyze_source(code, rules=["static-args"])
    # the typo'd name is reported; the call site is not (the typo'd name
    # is what got pinned, right or wrong)
    assert any("'shap' is not a parameter" in f.message for f in found)


def test_static_args_unhashable_value():
    code = src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("widths",))
        def f(x, widths):
            return x

        def caller(x):
            return f(x, widths=[8, 16])
    """)
    found = analyze_source(code, rules=["static-args"])
    assert len(found) == 1 and "unhashable" in found[0].message


def test_static_args_non_literal_argnums():
    code = src("""
        import jax

        NUMS = (1,)

        @jax.jit(static_argnums=NUMS)
        def f(x, n):
            return x
    """)
    (f,) = analyze_source(code, rules=["static-args"])
    assert "literal" in f.message


def test_bound_method_jit_assignment_is_recognized():
    code = src("""
        import jax

        class Sweeper:
            def __init__(self):
                self._sweep = jax.jit(self._sweep_impl)

            def _sweep_impl(self, state):
                if state:
                    return state
                return state
    """)
    (f,) = analyze_source(code, rules=["traced-branch"])
    assert "'state'" in f.message


# ---------------------------------------------------------------------------
# pass 3: device sync under a coordinator lock
# ---------------------------------------------------------------------------
def test_sync_under_lock_positive_and_negative():
    code = src("""
        import threading
        import jax.numpy as jnp

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def bad(self, x):
                with self._lock:
                    return jnp.asarray(x)

            def good(self, x):
                y = jnp.asarray(x)
                with self._lock:
                    return y
    """)
    (f,) = analyze_source(code, rules=["sync-under-lock"])
    assert f.scope == "C.bad"


def test_sync_under_lock_tree_util_allowlisted():
    code = src("""
        import threading
        import jax

        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def ok(self, x):
                with self._lock:
                    return jax.tree_util.tree_map(lambda a: a, x)
    """)
    assert analyze_source(code, rules=["sync-under-lock"]) == []


# ---------------------------------------------------------------------------
# pass 4: PRNG key discipline
# ---------------------------------------------------------------------------
def test_prng_reuse_positive():
    code = src("""
        import jax

        def draw(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert "'key'" in f.message and f.line == 5


def test_prng_split_between_uses_is_clean():
    code = src("""
        import jax

        def draw(key):
            key, sub = jax.random.split(key)
            a = jax.random.normal(sub, (3,))
            key, sub = jax.random.split(key)
            b = jax.random.normal(sub, (3,))
            return a + b
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_sibling_branches_do_not_taint_each_other():
    code = src("""
        import jax

        def draw(key, fast):
            if fast:
                return jax.random.normal(key, (3,))
            else:
                return jax.random.uniform(key, (3,))
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_early_return_arm_excluded_from_merge():
    # the core/distributed.py sweep shape: the async arm consumes the
    # keys and returns; the sync path below is mutually exclusive with it
    code = src("""
        import jax

        def sweep(key, mode):
            k1, k2 = jax.random.split(key)
            if mode == "async":
                return jax.random.normal(k1, (3,)) + jax.random.normal(k2, (3,))
            a = jax.random.normal(k1, (3,))
            return a + jax.random.normal(k2, (3,))
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_fallthrough_arm_still_taints():
    code = src("""
        import jax

        def sweep(key, warm):
            if warm:
                a = jax.random.normal(key, (3,))
            return jax.random.normal(key, (3,))
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert f.line == 6


def test_prng_loop_carried_reuse():
    code = src("""
        import jax

        def draws(key, n):
            out = []
            for _ in range(n):
                out.append(jax.random.normal(key, (3,)))
            return out
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert f.line == 6


def test_prng_per_iteration_split_ledger_is_clean():
    code = src("""
        import jax

        def draws(key, n):
            out = []
            for _ in range(n):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, (3,)))
            return out
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_fold_in_and_validators_do_not_consume():
    code = src("""
        import jax

        def fan_out(key, ids):
            _check_args(key, ids)
            keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)
            return jax.random.normal(key, (3,)), keys
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_randint_selection_counts_as_consumption():
    # the SGLD minibatch pattern: row selection via jax.random.randint is
    # a draw like any other — reusing its key for the noise must flag
    code = src("""
        import jax

        def step(key, factors):
            rows = jax.random.randint(key, (4,), 0, 10)
            return rows, jax.random.normal(key, factors.shape)
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert "'key'" in f.message and f.line == 5


def test_prng_per_bucket_fold_in_chain_is_clean():
    # core/sgld.py's bucket loop: fold_in derives an independent stream
    # per bucket without consuming the parent key
    code = src("""
        import jax

        def minibatch(key, buckets):
            out = []
            for b in range(len(buckets)):
                kb = jax.random.fold_in(key, b)
                out.append(jax.random.randint(kb, (4,), 0, 10))
            return out
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


def test_prng_stateful_numpy_generator_not_tracked():
    code = src("""
        import numpy as np

        def fixture():
            rng = np.random.default_rng(0)
            a = make(rng)
            b = make(rng)
            return a, b
    """)
    assert analyze_source(code, rules=["prng-reuse"]) == []


# ---------------------------------------------------------------------------
# suppression comments
# ---------------------------------------------------------------------------
def test_suppression_comment_silences_one_rule():
    flagged = GUARDED.replace(
        "        return self.epoch",
        "        return self.epoch  # repro-lint: disable=guarded-field (snapshot read)",
    )
    assert flagged != GUARDED
    assert analyze_source(flagged) == []
    # a different rule on the same line is NOT silenced
    wrong = GUARDED.replace(
        "        return self.epoch",
        "        return self.epoch  # repro-lint: disable=prng-reuse",
    )
    assert rules_of(analyze_source(wrong)) == ["guarded-field"]


# ---------------------------------------------------------------------------
# baseline round-trip + CLI
# ---------------------------------------------------------------------------
def test_baseline_round_trip(tmp_path):
    target = tmp_path / "mod.py"
    target.write_text(GUARDED)
    base_file = tmp_path / "base.json"

    args = [str(target), "--root", str(tmp_path), "--baseline", str(base_file)]
    assert main(args) == 1                      # finding, no baseline yet
    assert main([*args, "--write-baseline"]) == 0
    assert main(args) == 0                      # grandfathered

    data = json.loads(base_file.read_text())
    assert data["version"] == baseline_mod.BASELINE_VERSION
    (key,) = data["findings"]
    assert key.startswith("mod.py::guarded-field::Coord.peek::")

    # baseline keys survive line churn but not edits to the flagged line
    target.write_text("# a new leading comment\n" + GUARDED)
    assert main(args) == 0
    target.write_text(GUARDED.replace("return self.epoch",
                                      "return self.epoch + 1"))
    assert main(args) == 1


def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean), "--root", str(tmp_path)]) == 0
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ALL_RULES:
        assert rule in out
    assert main([str(clean), "--rules", "no-such-rule"]) == 2
    assert main([str(tmp_path / "missing.py")]) == 2

    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n")
    assert main([str(bad), "--root", str(tmp_path)]) == 2


def test_cli_json_format(tmp_path, capsys):
    target = tmp_path / "mod.py"
    target.write_text(GUARDED)
    rc = main([str(target), "--root", str(tmp_path), "--format", "json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["summary"] == {"guarded-field": 1}
    (finding,) = payload["findings"]
    assert finding["path"] == "mod.py"
    assert finding["rule"] == "guarded-field"


def test_rule_docs_cover_every_rule():
    assert set(RULE_DOCS) == set(ALL_RULES)


# ---------------------------------------------------------------------------
# meta: the live tree is clean modulo the checked-in baseline
# ---------------------------------------------------------------------------
def test_analyzer_clean_on_live_tree():
    """`python -m repro.analysis src tests` must exit 0 against the
    checked-in baseline — the same invocation the CI lint job gates on.
    A failure here means a new finding: fix it, suppress it in-line with a
    justification, or (last resort) regenerate the baseline."""
    rc = main([
        str(ROOT / "src"), str(ROOT / "tests"),
        "--root", str(ROOT),
        "--baseline", str(ROOT / baseline_mod.DEFAULT_BASELINE),
    ])
    assert rc == 0


# ---------------------------------------------------------------------------
# pass 5: collective discipline (SPMD)
# ---------------------------------------------------------------------------
RING = src("""
    import jax

    AXIS = "items"

    def exchange(blk, n_shards):
        fwd = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        return jax.lax.ppermute(blk, AXIS, fwd)
""")


def test_ppermute_ring_comprehension_is_clean():
    assert analyze_source(RING, rules=["ppermute-perm"]) == []


def test_ppermute_missing_wraparound_flagged():
    bad = RING.replace("(i + 1) % n_shards", "i + 1")
    assert bad != RING
    (f,) = analyze_source(bad, rules=["ppermute-perm"])
    assert f.rule == "ppermute-perm" and "wraparound" in f.message


def test_ppermute_wrong_ring_modulus_flagged():
    bad = RING.replace("% n_shards", "% (n_shards - 1)")
    assert bad != RING
    (f,) = analyze_source(bad, rules=["ppermute-perm"])
    assert "not a bijection" in f.message


def test_ppermute_literal_duplicate_dest_flagged():
    code = src("""
        import jax

        def exchange(blk):
            return jax.lax.ppermute(blk, "x", [(0, 1), (1, 1)])
    """)
    (f,) = analyze_source(code, rules=["ppermute-perm"])
    assert "destination" in f.message


def test_ppermute_dynamic_perm_is_skipped():
    code = src("""
        import jax

        def exchange(blk, perm):
            return jax.lax.ppermute(blk, "x", perm)
    """)
    assert analyze_source(code, rules=["ppermute-perm"]) == []


def test_collective_branch_one_armed_psum_flagged():
    code = src("""
        import jax

        def step(pred, x):
            return jax.lax.cond(
                pred,
                lambda v: jax.lax.psum(v, "items"),
                lambda v: v,
                x,
            )
    """)
    (f,) = analyze_source(code, rules=["collective-branch"])
    assert f.rule == "collective-branch" and "deadlock" in f.message


def test_collective_branch_balanced_arms_clean():
    code = src("""
        import jax

        def step(pred, x):
            return jax.lax.cond(
                pred,
                lambda v: jax.lax.psum(v * 2, "items"),
                lambda v: jax.lax.psum(v, "items"),
                x,
            )
    """)
    assert analyze_source(code, rules=["collective-branch"]) == []


def test_collective_branch_expands_same_file_helpers():
    # the collective hides two calls deep in a named arm: _stats -> psum
    code = src("""
        import jax

        def _stats(v):
            return jax.lax.psum(v, "items")

        def _draw(v):
            return _stats(v) + 1.0

        def step(pred, x):
            return jax.lax.cond(pred, _draw, lambda v: v, x)
    """)
    (f,) = analyze_source(code, rules=["collective-branch"])
    assert "psum" in f.message


def test_collective_branch_unresolvable_arm_skipped():
    code = src("""
        import jax
        from elsewhere import mystery_fn

        def step(pred, x):
            return jax.lax.cond(
                pred, mystery_fn, lambda v: jax.lax.psum(v, "i"), x)
    """)
    assert analyze_source(code, rules=["collective-branch"]) == []


def test_collective_axis_undeclared_flagged():
    code = src("""
        import jax

        AXIS = "items"

        def make(n):
            mesh = jax.make_mesh((n,), (AXIS,))
            return mesh

        def stats(x):
            return jax.lax.psum(x, "rows")
    """)
    (f,) = analyze_source(code, rules=["collective-axis"])
    assert "'rows'" in f.message and "items" in f.message


def test_collective_axis_resolves_module_constants():
    code = src("""
        import jax
        from jax.sharding import PartitionSpec as P

        AXIS = "items"
        SPEC = P(AXIS)

        def stats(x):
            return jax.lax.psum(x, AXIS)
    """)
    assert analyze_source(code, rules=["collective-axis"]) == []


def test_collective_axis_silent_without_declarations():
    # a helper module that takes axis_name from callers declares nothing:
    # the contract lives at the call sites, not here
    code = src("""
        import jax

        def compressed_psum(x, axis_name):
            return jax.lax.psum(x, axis_name)

        def hardcoded(x):
            return jax.lax.psum(x, "pod")
    """)
    assert analyze_source(code, rules=["collective-axis"]) == []


# ---------------------------------------------------------------------------
# pass 6: sharding layout
# ---------------------------------------------------------------------------
STATE_INIT = src("""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import shard_map

    AXIS = "items"

    class DistState(tuple):
        pass

    def make_sweep(mesh):
        def sweep(state, plans):
            return DistState(u=state.u, key=state.key)
        state_spec = DistState(u=P(AXIS), key=P())
        return shard_map(sweep, mesh=mesh, in_specs=(state_spec, P(AXIS)),
                         out_specs=state_spec)

    def init(mesh, key):
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        u = 0.1 * jax.random.normal(key, (8, 4))
        u_dev = jax.device_put(u, sh)
        return DistState(u=u_dev, key=jax.device_put(key, rep))
""")


def test_state_sharding_pinned_init_is_clean():
    assert analyze_source(STATE_INIT, rules=["state-sharding"]) == []


def test_state_sharding_bare_field_flagged():
    bad = STATE_INIT.replace("u=u_dev,", "u=u,")
    assert bad != STATE_INIT
    (f,) = analyze_source(bad, rules=["state-sharding"])
    assert f.rule == "state-sharding"
    assert "'u'" in f.message and "recompile" in f.message


def test_state_sharding_direct_call_field_flagged():
    bad = STATE_INIT.replace(
        "key=jax.device_put(key, rep)", "key=jax.random.split(key)")
    assert bad != STATE_INIT
    (f,) = analyze_source(bad, rules=["state-sharding"])
    assert "'key'" in f.message


def test_state_sharding_spec_tree_outside_init_exempt():
    # `state_spec = DistState(u=P(AXIS), ...)` in make_sweep stays silent:
    # only init* functions assemble device state
    found = analyze_source(STATE_INIT, rules=["state-sharding"])
    assert found == []
    optional = STATE_INIT.replace(
        "key=jax.device_put(key, rep))",
        "key=jax.device_put(key, rep) if mesh else None)")
    assert analyze_source(optional, rules=["state-sharding"]) == []


def test_state_sharding_catches_pr6_mutant_in_live_init():
    """Seeded mutant: delete the explicit shardings in DistributedBPMF.init()
    (the PR 6 silent-recompile bug) and the pass must catch it."""
    live = (ROOT / "src" / "repro" / "core" / "distributed.py").read_text()
    assert "u=jax.device_put(u, sh)," in live
    assert analyze_source(live, rules=["state-sharding"]) == []
    mutant = live.replace("u=jax.device_put(u, sh),", "u=u,")
    found = analyze_source(mutant, rules=["state-sharding"])
    assert [f.rule for f in found] == ["state-sharding"]
    assert "'u'" in found[0].message


def test_donated_reuse_flagged():
    code = src("""
        import jax
        import jax.numpy as jnp

        def run(f, state):
            step = jax.jit(f, donate_argnums=(0,))
            out = step(state)
            return out, jnp.sum(state)
    """)
    (f,) = analyze_source(code, rules=["donated-reuse"])
    assert f.rule == "donated-reuse" and "'state'" in f.message


def test_donated_reuse_rebind_idiom_clean():
    code = src("""
        import jax

        def run(f, state, n):
            step = jax.jit(f, donate_argnums=(0,))
            for _ in range(n):
                state = step(state)
            return state
    """)
    assert analyze_source(code, rules=["donated-reuse"]) == []


def test_donated_reuse_argnames_and_undonated_clean():
    code = src("""
        import jax
        import jax.numpy as jnp

        def run(f, state, other):
            step = jax.jit(f, donate_argnames=("state",))
            out = step(state=state, other=other)
            return out, jnp.sum(other)
    """)
    assert analyze_source(code, rules=["donated-reuse"]) == []
    bad = code.replace("jnp.sum(other)", "jnp.sum(state)")
    (f,) = analyze_source(bad, rules=["donated-reuse"])
    assert "'state'" in f.message


# ---------------------------------------------------------------------------
# pass 7: Pallas lowerability / kernel structure
# ---------------------------------------------------------------------------
PALLAS = src("""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _kernel(x_ref, o_ref):
        x = x_ref[...]
        o_ref[...] = jnp.maximum(x, 0.0)

    def relu(x, block):
        n, k = x.shape
        assert n % block == 0, (n, block)
        grid = (n // block,)
        return pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((block, k), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((block, k), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
        )(x)
""")


def test_pallas_clean_kernel_is_clean():
    assert analyze_source(PALLAS) == []


def test_pallas_lowering_top_k_flagged():
    bad = PALLAS.replace("jnp.maximum(x, 0.0)",
                         "jax.lax.top_k(x, 4)[0]")
    assert bad != PALLAS
    (f,) = analyze_source(bad, rules=["pallas-lowering"])
    assert f.rule == "pallas-lowering" and "top_k" in f.message


def test_pallas_lowering_sort_flagged_only_inside_kernel():
    bad = PALLAS.replace("jnp.maximum(x, 0.0)", "jnp.sort(x, axis=-1)")
    (f,) = analyze_source(bad, rules=["pallas-lowering"])
    assert "sort" in f.message
    # the same op in the host-side wrapper is fine
    host = PALLAS.replace("return pl.pallas_call(",
                          "x = jnp.sort(x, axis=-1)\n    return pl.pallas_call(")
    assert analyze_source(host, rules=["pallas-lowering"]) == []


def test_pallas_lowering_catches_mutant_in_live_topn_kernel():
    """Seeded mutant: drop the sanctioned suppressions in bpmf_topn.py and
    the interpret-only top_k/take_along_axis sites must all surface."""
    live = (ROOT / "src" / "repro" / "kernels" / "bpmf_topn.py").read_text()
    assert analyze_source(live, rules=["pallas-lowering"]) == []
    mutant = live.replace("  # repro-lint: disable=pallas-lowering", "")
    assert mutant != live
    found = analyze_source(mutant, rules=["pallas-lowering"])
    assert len(found) == 4
    assert {f.rule for f in found} == {"pallas-lowering"}


def test_pallas_anyspace_direct_access_flagged():
    code = src("""
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def _kernel(v_ref, o_ref):
            o_ref[...] = v_ref[0] * 2.0

        def scale(v, n, k):
            return pl.pallas_call(
                _kernel,
                grid=(1,),
                in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
                out_specs=pl.BlockSpec((n, k), lambda i: (0, 0)),
                out_shape=jax.ShapeDtypeStruct((n, k), v.dtype),
            )(v)
    """)
    (f,) = analyze_source(code, rules=["pallas-anyspace"])
    assert f.rule == "pallas-anyspace" and "'v_ref'" in f.message
    # .at[...] DMA slicing of the same ref is the sanctioned access path
    dma = code.replace("v_ref[0] * 2.0", "v_ref.at[0].shape[0] * 2.0")
    assert analyze_source(dma, rules=["pallas-anyspace"]) == []


def test_pallas_anyspace_vmem_refs_untouched():
    assert analyze_source(PALLAS, rules=["pallas-anyspace"]) == []


def test_pallas_anyspace_catches_mutant_in_live_gather_syrk():
    live = (ROOT / "src" / "repro" / "kernels"
            / "bpmf_gather_syrk.py").read_text()
    assert analyze_source(live, rules=["pallas-anyspace"]) == []
    mutant = live.replace("  # repro-lint: disable=pallas-anyspace", "")
    assert mutant != live
    found = analyze_source(mutant, rules=["pallas-anyspace"])
    assert len(found) == 2
    assert {f.rule for f in found} == {"pallas-anyspace"}


def test_pallas_out_init_accumulate_into_garbage_flagged():
    bad = PALLAS.replace("o_ref[...] = jnp.maximum(x, 0.0)",
                         "o_ref[...] += x")
    assert bad != PALLAS
    (f,) = analyze_source(bad, rules=["pallas-out-init"])
    assert f.rule == "pallas-out-init" and "read before" in f.message


def test_pallas_out_init_store_before_read_clean():
    ok = PALLAS.replace(
        "o_ref[...] = jnp.maximum(x, 0.0)",
        "o_ref[...] = jnp.zeros_like(x)\n    o_ref[...] += x")
    assert analyze_source(ok, rules=["pallas-out-init"]) == []


def test_pallas_out_init_when_guarded_init_clean():
    code = src("""
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            j = pl.program_id(0)

            @pl.when(j == 0)
            def _first():
                o_ref[...] = jnp.zeros_like(x_ref)

            @pl.when(j > 0)
            def _rest():
                o_ref[...] += x_ref[...]

        def accum(x, block, n, k):
            assert n % block == 0
            return pl.pallas_call(
                _kernel,
                grid=(n // block,),
                in_specs=[pl.BlockSpec((block, k), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((block, k), lambda i: (0, 0)),
                out_shape=jax.ShapeDtypeStruct((block, k), x.dtype),
            )(x)
    """)
    assert analyze_source(code, rules=["pallas-out-init"]) == []


def test_pallas_out_init_aliased_output_clean():
    code = src("""
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _kernel(x_ref, z_ref, o_ref):
            o_ref[...] += x_ref[...]

        def accum(x, z, n, k):
            return pl.pallas_call(
                _kernel,
                grid=(1,),
                in_specs=[pl.BlockSpec((n, k), lambda i: (0, 0)),
                          pl.BlockSpec((n, k), lambda i: (0, 0))],
                out_specs=pl.BlockSpec((n, k), lambda i: (0, 0)),
                out_shape=jax.ShapeDtypeStruct((n, k), x.dtype),
                input_output_aliases={1: 0},
            )(x, z)
    """)
    assert analyze_source(code, rules=["pallas-out-init"]) == []


def test_pallas_blockspec_arity_mismatch_flagged():
    bad = PALLAS.replace("grid = (n // block,)", "grid = (n // block, 1)")
    found = analyze_source(bad, rules=["pallas-blockspec"])
    assert len(found) == 2  # both index_maps take 1 arg against a rank-2 grid
    assert all("rank" in f.message for f in found)


def test_pallas_blockspec_element_offset_flagged():
    bad = PALLAS.replace("lambda i: (i, 0)", "lambda i: (i * block, 0)")
    found = analyze_source(bad, rules=["pallas-blockspec"])
    assert len(found) == 2
    assert all("block units" in f.message for f in found)


def test_pallas_blockspec_missing_divisibility_check_flagged():
    bad = PALLAS.replace("assert n % block == 0, (n, block)\n    ", "")
    assert bad != PALLAS
    (f,) = analyze_source(bad, rules=["pallas-blockspec"])
    assert "divisibility" in f.message and "n // block" in f.message


# ---------------------------------------------------------------------------
# suppression anchoring: statement spans, not physical lines
# ---------------------------------------------------------------------------
def test_suppression_on_first_line_of_multiline_call():
    code = src("""
        import jax

        AXIS = "items"

        def make(n):
            return jax.make_mesh((n,), (AXIS,))

        def stats(x):
            return jax.lax.psum(  # repro-lint: disable=collective-axis (cross-mesh)
                x,
                "rows",
            )
    """)
    assert analyze_source(code, rules=["collective-axis"]) == []
    # the undirected comment does not leak onto the next statement
    two = code + src("""
        def more(x):
            return jax.lax.psum(x, "cols")
    """)
    (f,) = analyze_source(two, rules=["collective-axis"])
    assert "'cols'" in f.message


def test_suppression_on_decorator_line_covers_header():
    code = src("""
        import jax

        NUMS = (1,)

        @jax.jit(
            static_argnums=NUMS,
        )
        def f(x, n):
            return x
    """)
    (f,) = analyze_source(code, rules=["static-args"])
    assert "literal" in f.message
    quiet = code.replace("@jax.jit(",
                         "@jax.jit(  # repro-lint: disable=static-args")
    assert analyze_source(quiet, rules=["static-args"]) == []


def test_suppression_on_def_line_does_not_cover_body():
    code = src("""
        import jax

        def draw(key):  # repro-lint: disable=prng-reuse
            a = jax.random.normal(key)
            b = jax.random.normal(key)
            return a + b
    """)
    (f,) = analyze_source(code, rules=["prng-reuse"])
    assert f.rule == "prng-reuse"


# ---------------------------------------------------------------------------
# CLI: --changed-only and --out
# ---------------------------------------------------------------------------
def _git(cwd, *args):
    import subprocess
    subprocess.run(
        ["git", "-c", "user.email=lint@test", "-c", "user.name=lint", *args],
        cwd=cwd, check=True, capture_output=True)


def test_cli_changed_only_scopes_to_git_diff(tmp_path):
    import shutil
    if shutil.which("git") is None:
        pytest.skip("git unavailable")
    _git(tmp_path, "init", "-q")
    committed = tmp_path / "committed.py"
    committed.write_text(GUARDED)           # has a finding, but is committed
    _git(tmp_path, "add", "committed.py")
    _git(tmp_path, "commit", "-q", "-m", "seed")

    fresh = tmp_path / "fresh.py"
    fresh.write_text("x = 1\n")             # untracked, clean

    args = [str(tmp_path), "--root", str(tmp_path)]
    assert main(args) == 1                  # full run still sees committed.py
    assert main([*args, "--changed-only"]) == 0   # diff scope skips it

    fresh.write_text(GUARDED)               # untracked file gains a finding
    assert main([*args, "--changed-only"]) == 1


def test_cli_changed_only_outside_git_is_usage_error(tmp_path, monkeypatch):
    target = tmp_path / "mod.py"
    target.write_text("x = 1\n")
    monkeypatch.setenv("GIT_CEILING_DIRECTORIES", str(tmp_path.parent))
    monkeypatch.setenv("GIT_DIR", str(tmp_path / "no-such-gitdir"))
    rc = main([str(target), "--root", str(tmp_path), "--changed-only"])
    assert rc == 2


def test_cli_out_writes_json_artifact(tmp_path, capsys):
    target = tmp_path / "mod.py"
    target.write_text(GUARDED)
    report = tmp_path / "lint-report.json"
    rc = main([str(target), "--root", str(tmp_path), "--out", str(report)])
    assert rc == 1
    payload = json.loads(report.read_text())
    assert payload["summary"] == {"guarded-field": 1}
    (finding,) = payload["findings"]
    assert finding["rule"] == "guarded-field"


def test_every_pass_rule_is_documented_and_reachable():
    """RULE_DOCS, ALL_RULES, and the pass modules' RULES tuples must agree —
    an undocumented rule (or a documented rule no pass implements) is a
    registry bug."""
    from repro.analysis.cli import PASSES

    implemented = set()
    for mod in PASSES:
        implemented.update(mod.RULES)
    assert implemented == set(RULE_DOCS) == set(ALL_RULES)
