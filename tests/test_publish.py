"""Async sample publication: channel ordering, atomic frontend swaps, and
compiled-executable reuse across same-shape publishes."""
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import SampleStore
from repro.core import GibbsSampler
from repro.data import synthetic_lowrank, train_test_split
from repro.kernels import bpmf_topn
from repro.serve import (
    PosteriorEnsemble,
    PublicationChannel,
    RecommendFrontend,
    TopNRecommender,
)

M, N, K = 24, 16, 4


def make_sample(step: int, *, u=None, v=None) -> dict:
    """A schema-complete draw; u/v default to deterministic per-step values."""
    rng = np.random.default_rng(step)
    return {
        "u": (rng.normal(size=(M, K)).astype(np.float32) if u is None else u),
        "v": (rng.normal(size=(N, K)).astype(np.float32) if v is None else v),
        "hyper_u_mu": np.zeros(K, np.float32),
        "hyper_u_lam": np.eye(K, dtype=np.float32),
        "hyper_v_mu": np.zeros(K, np.float32),
        "hyper_v_lam": np.eye(K, dtype=np.float32),
        "global_mean": np.float32(0.0),
        "alpha": np.float32(2.0),
    }


def epoch_coded_sample(step: int) -> dict:
    """A draw whose top-1 score *is* its step: u rows are all-ones/K, v is
    zero except item (step % N) which scores exactly `step`. Any mix of u
    and v from different epochs (a torn swap) would score a wrong value."""
    u = np.full((M, K), 1.0 / K, np.float32)
    v = np.zeros((N, K), np.float32)
    v[step % N] = float(step)
    return make_sample(step, u=u, v=v)


# ---------------------------------------------------------------------------
# channel semantics
# ---------------------------------------------------------------------------
def test_channel_windows_and_orders_draws():
    ch = PublicationChannel(window=3)
    assert ch.snapshot() is None and ch.epoch is None and ch.seq == 0
    for step in (10, 12, 11, 14):
        assert ch.publish(step, make_sample(step))
    snap = ch.snapshot()
    assert snap.epoch == 14 and snap.seq == 4
    assert [d.step for d in snap.draws] == [11, 12, 14]  # windowed, sorted


def test_channel_epoch_monotone_under_out_of_order_publishes():
    ch = PublicationChannel(window=4)
    ch.publish(9, make_sample(9))
    assert ch.epoch == 9
    # a straggler draw lands in the window but cannot move the epoch back
    assert ch.publish(7, make_sample(7)) is True
    assert ch.epoch == 9
    assert [d.step for d in ch.snapshot().draws] == [7, 9]
    # duplicates and draws older than a full window are dropped
    assert ch.publish(9, make_sample(9)) is False
    ch.publish(10, make_sample(10))
    ch.publish(11, make_sample(11))
    assert ch.publish(3, make_sample(3)) is False
    assert ch.epoch == 11 and ch.seq == 4


def test_channel_wait_and_close():
    ch = PublicationChannel(window=2)
    assert ch.wait(timeout=0.01) is None
    got = []
    t = threading.Thread(target=lambda: got.append(ch.wait(timeout=5.0)))
    t.start()
    ch.publish(1, make_sample(1))
    t.join(timeout=5.0)
    assert got and got[0].epoch == 1
    assert ch.wait(newer_than=1, timeout=0.01) is None  # nothing newer yet
    ch.close()
    assert ch.wait(newer_than=1, timeout=5.0) is None   # closed: no block
    with pytest.raises(RuntimeError):
        ch.publish(2, make_sample(2))


def test_channel_push_callback_fires_per_publish():
    ch = PublicationChannel(window=2)
    seen = []
    unsubscribe = ch.subscribe(lambda snap: seen.append(snap.epoch))
    ch.publish(1, make_sample(1))
    ch.publish(2, make_sample(2))
    unsubscribe()
    ch.publish(3, make_sample(3))
    assert seen == [1, 2]


def test_channel_rejects_incomplete_sample():
    ch = PublicationChannel()
    bad = make_sample(1)
    del bad["alpha"]
    with pytest.raises(ValueError, match="alpha"):
        ch.publish(1, bad)


# ---------------------------------------------------------------------------
# trainer integration: publish alongside the durable store
# ---------------------------------------------------------------------------
def test_gibbs_run_publishes_alongside_store(tmp_path):
    ratings, _, _ = synthetic_lowrank(40, 24, k_true=3, nnz=600, noise=0.3, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)
    store = SampleStore(tmp_path / "samples", keep=8)
    ch = PublicationChannel(window=8)
    sampler = GibbsSampler(train, test, k=4, alpha=2.0, burn_in=3, widths=(8, 32))
    sampler.run(8, seed=0, store=store, publish=ch)

    assert ch.epoch == store.epoch()
    snap = ch.snapshot()
    assert [d.step for d in snap.draws] == store.steps()
    durable = store.load(store.epoch())
    published = snap.draws[-1]
    np.testing.assert_array_equal(np.asarray(published.u), durable.u)
    np.testing.assert_array_equal(np.asarray(published.v), durable.v)
    assert published.alpha == pytest.approx(durable.alpha)


def test_sgld_run_publishes_alongside_store(tmp_path):
    """SGLD parity with the Gibbs publish test: the minibatch trainer emits
    draws through the identical store/channel hand-off, at its much higher
    step rate (thin keeps the traffic bounded), and the channel's epoch
    tracks the store's."""
    from repro.core import SGLDSampler

    ratings, _, _ = synthetic_lowrank(40, 24, k_true=3, nnz=600, noise=0.3, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)
    store = SampleStore(tmp_path / "samples", keep=8)
    ch = PublicationChannel(window=8)
    sampler = SGLDSampler(train, test, k=4, alpha=2.0, burn_in=20,
                          minibatch=256, step_size=0.3, widths=(8, 32))
    sampler.run(60, seed=0, store=store, publish=ch, thin=10)

    assert ch.epoch == store.epoch()
    snap = ch.snapshot()
    assert [d.step for d in snap.draws] == store.steps()
    durable = store.load(store.epoch())
    published = snap.draws[-1]
    np.testing.assert_array_equal(np.asarray(published.u), durable.u)
    np.testing.assert_array_equal(np.asarray(published.v), durable.v)


def test_store_retention_under_high_rate_publishes(tmp_path):
    """SGLD-rate retention: hundreds of retains against a small keep window
    must leave exactly the last `keep` epochs on disk, in order, with the
    newest loadable — the async writer can't tear or leak under burst."""
    store = SampleStore(tmp_path / "samples", keep=4)
    for step in range(1, 201):
        store.retain(step, epoch_coded_sample(step))
    store.wait()
    assert store.epoch() == 200
    assert store.steps() == list(range(197, 201))
    got = store.load(200)
    assert float(got.v[200 % N].max()) == pytest.approx(200.0)


def test_frontend_stays_consistent_under_publish_burst():
    """A tight synchronous burst of publishes (the SGLD cadence, no sleeps)
    with refresh interleaved: served epochs stay monotone and every result
    is internally consistent (no torn u/v mix), even though most publishes
    are superseded before the frontend ever sees them."""
    ch = PublicationChannel(window=1)  # S pinned at 1: exact-score checks
    ch.publish(1, epoch_coded_sample(1))
    fe = RecommendFrontend(channel=ch, subscribe=False, max_batch=4)
    served = []
    step = 2
    for burst in range(30):
        for _ in range(7):  # frontend refreshes once per 7 publishes
            ch.publish(step, epoch_coded_sample(step))
            step += 1
        fe.refresh()
        fe.submit(0, topk=1)
        (res,) = fe.flush()
        served.append(res.epoch)
        assert res.items[0] == res.epoch % N, res
        assert res.scores[0] == pytest.approx(float(res.epoch)), res
    assert served == sorted(served)
    assert served[-1] == ch.epoch == step - 1  # every refresh caught up


# ---------------------------------------------------------------------------
# frontend adoption: epochs, monotonicity, no disk required
# ---------------------------------------------------------------------------
def test_frontend_serves_from_channel_without_disk():
    ch = PublicationChannel(window=2)
    ch.publish(5, epoch_coded_sample(5))
    fe = RecommendFrontend(channel=ch, subscribe=False, max_batch=4)
    assert fe.store is None and fe.epoch == 5
    fe.submit(0, topk=1)
    (res,) = fe.flush()
    assert res.epoch == 5
    assert res.items[0] == 5 % N and res.scores[0] == pytest.approx(5.0)


def test_frontend_requires_some_sample_source():
    with pytest.raises(ValueError, match="sample_root"):
        RecommendFrontend()
    ch = PublicationChannel()
    with pytest.raises(TimeoutError):
        RecommendFrontend(channel=ch, subscribe=False, wait_first_publish_s=0.05)
    # a closed-before-first-publish channel means the trainer died/finished
    # early — reported distinctly, not as a phantom timeout
    ch.close()
    with pytest.raises(RuntimeError, match="closed before the first publish"):
        RecommendFrontend(channel=ch, subscribe=False, wait_first_publish_s=5.0)


def test_frontend_epoch_monotone_and_stale_publish_ignored():
    ch = PublicationChannel(window=4)
    ch.publish(10, epoch_coded_sample(10))
    fe = RecommendFrontend(channel=ch, subscribe=False, max_batch=4,
                           max_samples=1)
    assert fe.epoch == 10
    # a straggler publish must not move the served epoch backwards
    ch.publish(8, epoch_coded_sample(8))
    assert fe.refresh() is False and fe.epoch == 10
    ch.publish(12, epoch_coded_sample(12))
    assert fe.refresh() is True and fe.epoch == 12
    fe.submit(1, topk=1)
    (res,) = fe.flush()
    assert res.epoch == 12 and res.items[0] == 12 % N


def test_frontend_prefers_channel_over_store(tmp_path):
    root = tmp_path / "samples"
    store = SampleStore(root, keep=4)
    store.retain(1, epoch_coded_sample(1))
    store.wait()
    ch = PublicationChannel(window=1)
    fe = RecommendFrontend(root, channel=ch, subscribe=False, max_batch=4)
    assert fe.epoch == 1  # cold start from disk
    ch.publish(6, epoch_coded_sample(6))
    assert fe.refresh() is True and fe.epoch == 6  # push wins over the poll
    fe.submit(2, topk=1)
    (res,) = fe.flush()
    assert res.items[0] == 6 % N and res.scores[0] == pytest.approx(6.0)


def _draws(steps):
    from repro.checkpoint import as_retained_sample

    return tuple(as_retained_sample(s, epoch_coded_sample(s)) for s in steps)


# ---------------------------------------------------------------------------
# executable reuse: same-shape publish must not retrace the top-N kernel
# ---------------------------------------------------------------------------
def test_same_shape_publish_zero_topn_recompiles():
    ch = PublicationChannel(window=2)
    ch.publish(1, epoch_coded_sample(1))
    ch.publish(2, epoch_coded_sample(2))  # window full: S pinned at 2
    fe = RecommendFrontend(channel=ch, subscribe=False, max_batch=4)
    fe.submit(0, topk=3)
    fe.flush()  # compile at the serving shape

    traces_before = bpmf_topn.trace_count()
    for step in (3, 4, 5):
        ch.publish(step, epoch_coded_sample(step))
        assert fe.refresh() is True
        fe.submit(0, topk=3)
        (res,) = fe.flush()
        assert res.epoch == step and res.items[0] == step % N
    assert bpmf_topn.trace_count() == traces_before  # swaps, no retraces
    assert fe.swaps >= 4 and fe.rebinds >= 3


def test_rebind_rejects_shape_change_and_rebuild_still_works():
    rec = TopNRecommender(PosteriorEnsemble(_draws((1, 2))))
    e3 = PosteriorEnsemble(_draws((1, 2, 3)))  # S changed: 2 -> 3
    with pytest.raises(ValueError, match="shape changed"):
        rec.rebind(e3)
    # the frontend path falls back to a full rebuild on shape change
    ch = PublicationChannel(window=3)
    for s in (1, 2):
        ch.publish(s, epoch_coded_sample(s))
    fe = RecommendFrontend(channel=ch, subscribe=False, max_batch=4)
    ch.publish(3, epoch_coded_sample(3))  # window grows: S 2 -> 3
    assert fe.refresh() is True
    assert fe.swaps == 2 and fe.rebinds == 0
    fe.submit(0, topk=1)
    (res,) = fe.flush()
    assert res.epoch == 3


def test_ensemble_from_arrays_matches_draw_construction():
    """from_arrays (stacked device arrays, the embedding API) must build the
    same servable ensemble as stacking RetainedSamples."""
    import jax.numpy as jnp

    draws = _draws((3, 5))
    want = PosteriorEnsemble(draws)
    got = PosteriorEnsemble.from_arrays(
        jnp.stack([jnp.asarray(d.u) for d in draws]),
        jnp.stack([jnp.asarray(d.v) for d in draws]),
        hyper_u_mu=jnp.stack([jnp.asarray(d.hyper_u_mu) for d in draws]),
        hyper_u_lam=jnp.stack([jnp.asarray(d.hyper_u_lam) for d in draws]),
        hyper_v_mu=jnp.stack([jnp.asarray(d.hyper_v_mu) for d in draws]),
        hyper_v_lam=jnp.stack([jnp.asarray(d.hyper_v_lam) for d in draws]),
        global_mean=want.global_mean, alpha=want.alpha, steps=(3, 5),
    )
    assert got.epoch == want.epoch == 5
    assert got.shape_key() == want.shape_key()
    users = np.asarray([0, 1], np.int32)
    items = np.asarray([3 % N, 5 % N], np.int32)
    np.testing.assert_allclose(
        np.asarray(got.score(users, items)[0]),
        np.asarray(want.score(users, items)[0]),
    )
    assert [s.step for s in got.samples] == [3, 5]  # fold_in metadata intact

    with pytest.raises(ValueError, match="ascending"):
        PosteriorEnsemble.from_arrays(
            got.u, got.v,
            hyper_u_mu=jnp.zeros((2, K)), hyper_u_lam=jnp.stack([jnp.eye(K)] * 2),
            hyper_v_mu=jnp.zeros((2, K)), hyper_v_lam=jnp.stack([jnp.eye(K)] * 2),
            global_mean=0.0, alpha=2.0, steps=(5, 3),
        )


def test_frontend_channel_with_empty_store_waits_for_first_publish(tmp_path):
    """Co-train first boot: the durable sample dir exists but is still empty
    (trainer in burn-in); a channel-attached frontend must block for the
    first publish, not crash on the empty directory."""
    ch = PublicationChannel(window=2)
    t = threading.Thread(
        target=lambda: (time.sleep(0.05), ch.publish(4, epoch_coded_sample(4)))
    )
    t.start()
    fe = RecommendFrontend(tmp_path / "empty", channel=ch, subscribe=False,
                           wait_first_publish_s=10.0)
    t.join()
    assert fe.epoch == 4
    # store-only with an empty dir still fails fast, as before
    with pytest.raises(FileNotFoundError):
        RecommendFrontend(tmp_path / "empty2")


def test_rebind_scores_new_factors_through_old_layout():
    one = TopNRecommender(PosteriorEnsemble(_draws((4,))))
    rebound = one.rebind(PosteriorEnsemble(_draws((7,))))
    vals, idx = rebound.recommend(np.asarray([0], np.int32), 1)
    assert idx[0][0] == 7 % N and vals[0][0] == pytest.approx(7.0)
    # the original recommender still serves its own epoch untouched
    vals, idx = one.recommend(np.asarray([0], np.int32), 1)
    assert idx[0][0] == 4 % N and vals[0][0] == pytest.approx(4.0)


def test_subscriber_hammer_publishes_while_draining():
    """Two publisher threads hammer the channel while the frontend's
    subscriber thread drains it — the locked `_epoch` read in the loop and
    the locked write in `_swap` must agree: served epochs stay monotone and
    the final publish is always adopted (no lost-wakeup on a stale read)."""
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    fe = RecommendFrontend(channel=ch, subscribe=True, max_batch=4)
    barrier = threading.Barrier(2)

    def publisher(steps):
        barrier.wait()
        for step in steps:
            ch.publish(step, epoch_coded_sample(step))
            time.sleep(0.0005)

    threads = [
        threading.Thread(target=publisher, args=(range(2, 120, 2),)),
        threading.Thread(target=publisher, args=(range(3, 120, 2),)),
    ]
    for t in threads:
        t.start()
    epochs = []
    try:
        while any(t.is_alive() for t in threads):
            fe.submit(0, topk=1)
            for res in fe.flush():
                epochs.append(res.epoch)
                assert res.items[0] == res.epoch % N, res
    finally:
        for t in threads:
            t.join(timeout=20.0)
        ch.close()
    # the drain path must catch the last published epoch: condition-wait on
    # the swap (woken by every adoption), no sleep/poll race
    assert fe.wait_epoch(ch.epoch, timeout=20.0)
    fe.close()
    assert fe.epoch == ch.epoch == 119
    assert epochs == sorted(epochs)
    assert fe.swaps >= 2


# ---------------------------------------------------------------------------
# the seen-item index across shape-changing swaps
# ---------------------------------------------------------------------------
def _sized_sample(step: int, m: int, n: int) -> dict:
    rng = np.random.default_rng(step)
    k = K
    return {
        "u": rng.normal(size=(m, k)).astype(np.float32),
        "v": rng.normal(size=(n, k)).astype(np.float32),
        "hyper_u_mu": np.zeros(k, np.float32),
        "hyper_u_lam": np.eye(k, dtype=np.float32),
        "hyper_v_mu": np.zeros(k, np.float32),
        "hyper_v_lam": np.eye(k, dtype=np.float32),
        "global_mean": np.float32(0.0),
        "alpha": np.float32(2.0),
    }


def _boot_ratings():
    from repro.data.sparse import SparseRatings

    rows = np.repeat(np.arange(M, dtype=np.int32), 3)
    rng = np.random.default_rng(7)
    cols = rng.integers(0, N, rows.size).astype(np.int32)
    return SparseRatings(rows=rows, cols=cols,
                         vals=np.ones(rows.size, np.float32), shape=(M, N))


def test_seen_index_follows_grown_axes_on_swap():
    """The exclusion index is built against boot-time ratings; a swap that
    grows the user/item axes must rebuild it padded to the new shape (new
    users get empty exclusion rows) instead of silently under-excluding
    (or crashing the seen lookup for users past the boot axis)."""
    train = _boot_ratings()
    ch = PublicationChannel(window=1)
    ch.publish(1, _sized_sample(1, M, N))
    fe = RecommendFrontend(channel=ch, subscribe=False, seen=train,
                           max_batch=4)
    assert fe.seen.shape == (M, N)

    ch.publish(2, _sized_sample(2, M + 6, N + 3))  # trainer grew both axes
    assert fe.refresh() is True
    assert fe.seen.shape == (M + 6, N + 3)
    # an existing user still gets their boot-time exclusions
    fe.submit(0, topk=5)
    # a user beyond the boot axis is servable with an empty exclusion row
    fe.submit(M + 2, topk=5)
    results = fe.flush()
    assert len(results) == 2
    seen0 = set(train.cols[train.rows == 0].tolist())
    assert not seen0.intersection(results[0].items.tolist())


def test_seen_index_rejects_shrunk_ensemble():
    """An ensemble smaller than the ratings matrix cannot be served with
    exclusions intact — adopting it must fail loudly, not under-exclude."""
    train = _boot_ratings()
    ch = PublicationChannel(window=1)
    ch.publish(1, _sized_sample(1, M, N))
    fe = RecommendFrontend(channel=ch, subscribe=False, seen=train,
                           max_batch=4)
    ch.publish(2, _sized_sample(2, M - 4, N))
    with pytest.raises(ValueError, match="under-exclude"):
        fe.refresh()


def test_subscriber_survives_rejected_publish():
    """A rejected adoption (shrunk ensemble vs the seen index) must not
    kill the subscriber thread: the bad epoch is recorded and skipped, and
    the next acceptable publish is still adopted."""
    train = _boot_ratings()
    ch = PublicationChannel(window=1)
    ch.publish(1, _sized_sample(1, M, N))
    fe = RecommendFrontend(channel=ch, subscribe=True, seen=train,
                           max_batch=4)
    try:
        ch.publish(2, _sized_sample(2, M - 4, N))   # rejected: shrunk axes
        # the subscriber notifies the swap condition on a rejection too —
        # wait on it rather than polling the deque
        with fe._lock:
            assert fe._swap_cond.wait_for(lambda: len(fe.adopt_errors) > 0,
                                          timeout=20.0)
        assert fe.epoch == 1
        ch.publish(3, _sized_sample(3, M, N))        # good again
        assert fe.wait_epoch(3, timeout=20.0)  # the loop lived on
    finally:
        ch.close()
        fe.close()


# ---------------------------------------------------------------------------
# no torn ensemble: concurrent recommend() during a stream of publishes
# ---------------------------------------------------------------------------
def test_no_torn_ensemble_during_concurrent_publishes():
    """Each epoch-coded draw scores exactly its own step for every user; a
    torn swap (u from one epoch, v from another, or epoch label mismatching
    the factors) would surface as a score != the result's reported epoch."""
    ch = PublicationChannel(window=1)  # S pinned at 1: every swap rebinds
    ch.publish(1, epoch_coded_sample(1))
    fe = RecommendFrontend(channel=ch, subscribe=True, max_batch=4)

    stop = threading.Event()

    def publisher():
        step = 2
        while not stop.is_set() and step < 200:
            ch.publish(step, epoch_coded_sample(step))
            step += 1
            time.sleep(0.002)
        ch.close()

    pub = threading.Thread(target=publisher)
    pub.start()
    served = []
    try:
        t_end = time.monotonic() + 3.0
        while time.monotonic() < t_end and not ch.closed:
            for u in range(3):
                fe.submit(u, topk=1)
            for res in fe.flush():
                served.append(res)
                # consistency: reported epoch, item, and score all agree
                assert res.items[0] == res.epoch % N, res
                assert res.scores[0] == pytest.approx(float(res.epoch)), res
    finally:
        stop.set()
        pub.join(timeout=10.0)
        fe.close()

    epochs = [r.epoch for r in served]
    assert len(served) >= 10
    assert epochs == sorted(epochs)      # served freshness never regressed
    assert len(set(epochs)) >= 2         # and at least one live swap happened
