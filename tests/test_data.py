"""Data pipeline: dataset generators + seekable token stream."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.data import chembl_like, movielens_like, synthetic_lowrank, train_test_split
from repro.data.sparse import SparseRatings, csr_from_coo
from repro.data.tokens import TokenStream


def test_chembl_like_shape_and_skew():
    ratings, _, _ = chembl_like(scale=0.01, seed=0)
    ratings.validate()
    deg = ratings.degrees(1)
    # power-law skew like the paper's Fig 2: top 1% of items >> median
    top = np.sort(deg)[-max(1, len(deg) // 100):].mean()
    assert top > 8 * max(np.median(deg), 1)


def test_movielens_like_scale():
    ratings, _, _ = movielens_like(scale=0.002, seed=1)
    ratings.validate()
    n, m = ratings.shape
    target = min(int(20_000_000 * 0.002), n * m // 2)
    assert ratings.nnz >= 0.9 * target  # rejection sampling may stall near cap


def test_split_disjoint_and_complete():
    ratings, _, _ = synthetic_lowrank(100, 80, 4, 2000, seed=2)
    tr, te = train_test_split(ratings, 0.2, seed=3)
    assert tr.nnz + te.nnz == ratings.nnz
    keys = lambda r: set(zip(r.rows.tolist(), r.cols.tolist()))
    assert not (keys(tr) & keys(te))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 50), m=st.integers(1, 40), nnz=st.integers(0, 200),
       seed=st.integers(0, 999))
def test_csr_roundtrip(n, m, nnz, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n, nnz).astype(np.int32)
    cols = rng.integers(0, m, nnz).astype(np.int32)
    vals = rng.normal(size=nnz).astype(np.float32)
    indptr, idx, v = csr_from_coo(rows, cols, vals, n)
    assert indptr[-1] == nnz
    got = []
    for i in range(n):
        for j in range(indptr[i], indptr[i + 1]):
            got.append((i, int(idx[j]), float(v[j])))
    assert sorted(got) == sorted(zip(rows.tolist(), cols.tolist(), vals.astype(float).tolist()))


def test_token_stream_deterministic_and_seekable():
    cfg = reduced(get_config("smollm-360m"))
    s1 = TokenStream(cfg, batch=4, seq=32, seed=5)
    s2 = TokenStream(cfg, batch=4, seq=32, seed=5)
    b_100_a = s1(100)
    _ = s1(3)  # stream position is irrelevant
    b_100_b = s2(100)
    np.testing.assert_array_equal(b_100_a["tokens"], b_100_b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b_100_a["tokens"][:, 1:], b_100_a["labels"][:, :-1])


def test_token_stream_family_extras():
    for arch in ("whisper-medium", "qwen2-vl-7b"):
        cfg = reduced(get_config(arch))
        b = TokenStream(cfg, batch=2, seq=16)(0)
        if cfg.family == "audio":
            assert b["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)
        if cfg.family == "vlm":
            assert b["patch_embeds"].shape == (2, cfg.n_patches, cfg.d_model)
            assert b["labels"].shape[1] == cfg.n_patches + 16
