"""Checkpoint store, fault-tolerant trainer, elastic remesh."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointStore

SRC = str(Path(__file__).resolve().parents[1] / "src")


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16), "d": jnp.asarray(3, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    t = tree()
    store.save(7, t)
    out = store.restore(jax.eval_shape(lambda: t), step=7)
    for l1, l2 in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_keep_last_n_prunes(tmp_path):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, tree())
    assert store.all_steps() == [3, 4]


def test_async_save(tmp_path):
    store = CheckpointStore(tmp_path, keep=3, use_async=True)
    store.save(1, tree())
    store.wait()
    assert store.latest_step() == 1


def test_partial_tmp_dir_is_ignored(tmp_path):
    store = CheckpointStore(tmp_path, keep=3)
    store.save(5, tree())
    # simulate a crash mid-save: orphan tmp dir with garbage
    bad = tmp_path / "step_0000000009.tmp"
    bad.mkdir()
    (bad / "leaf_00000.npy").write_bytes(b"garbage")
    assert store.latest_step() == 5
    out = store.restore(jax.eval_shape(lambda: tree()))
    assert int(np.asarray(jax.tree.leaves(out)[-1])) == 3


def test_trainer_recovers_from_injected_failures(tmp_path):
    from repro.optim import AdamWConfig
    from repro.launch.train import init_train_state, make_train_step
    from repro.runtime import Trainer, TrainerConfig
    from repro.configs import get_config, reduced
    from repro.data.tokens import TokenStream

    cfg = reduced(get_config("smollm-360m"))
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt, total_steps=100))
    data = TokenStream(cfg, batch=2, seq=32)
    tr = Trainer(
        step, state, data,
        TrainerConfig(
            ckpt_dir=str(tmp_path), ckpt_every=5, use_async_ckpt=False,
            fail_at_steps=(7, 12),
        ),
    )
    out = tr.run(20, log_every=100)
    assert out["recoveries"] == 2
    assert out["final_step"] == 20
    # loss should decrease over the run despite failures
    losses = out["loss_history"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_trainer_resume_from_disk(tmp_path):
    from repro.optim import AdamWConfig
    from repro.launch.train import init_train_state, make_train_step
    from repro.runtime import Trainer, TrainerConfig
    from repro.configs import get_config, reduced
    from repro.data.tokens import TokenStream

    cfg = reduced(get_config("smollm-360m"))
    opt = AdamWConfig(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt, total_steps=100))
    data = TokenStream(cfg, batch=2, seq=32)
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, use_async_ckpt=False)

    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    t1 = Trainer(step, state, data, tcfg)
    t1.run(10, log_every=100)

    # brand-new trainer resumes at step 10 from disk
    state2 = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    t2 = Trainer(step, state2, data, tcfg)
    assert t2.step == 10


@pytest.mark.slow
def test_elastic_remesh_8_to_4_devices():
    code = f"""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import sys; sys.path.insert(0, {SRC!r})
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointStore, restore_resharded

    mesh8 = jax.make_mesh((4, 2), ("data", "model"), devices=jax.devices()[:8])
    mesh4 = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
    tree = {{"w": jax.device_put(w, NamedSharding(mesh8, P("data", "model")))}}
    store = CheckpointStore("/tmp/elastic_test", keep=1)
    store.save(3, tree)
    out = restore_resharded(
        store, jax.eval_shape(lambda: tree), {{"w": P("data", "model")}}, mesh4, step=3
    )
    assert out["w"].sharding.mesh.shape["data"] == 2
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
    print("elastic ok")
    """
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=300,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "elastic ok" in res.stdout
