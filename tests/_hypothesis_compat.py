"""Optional-hypothesis shim for the property-test modules.

`hypothesis` is a test extra (see pyproject.toml), not a hard dependency.
A bare module-level import used to abort collection of three whole test
modules when it was missing; a module-level `pytest.importorskip` would fix
collection but throw away every *non*-property test in those modules too.
This shim keeps both: with hypothesis installed the real `given / settings /
strategies` are re-exported; without it the stand-ins below turn each
`@given`-decorated test into an individually skipped test while the rest of
the module runs normally.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install hypothesis)"
            )(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """st.* lookups resolve at decoration time; any call returns None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
