"""BPMF core: sampler correctness, bucket planning, hyperprior sampling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import ALS, GibbsSampler, default_prior, plan_buckets
from repro.core.buckets import workload_model
from repro.core.hyper import sample_normal_wishart, sample_wishart
from repro.data import synthetic_lowrank, train_test_split
from repro.data.sparse import csr_from_coo


@pytest.fixture(scope="module")
def small_data():
    ratings, u, v = synthetic_lowrank(250, 180, k_true=8, nnz=8000, noise=0.3, seed=1)
    return train_test_split(ratings, 0.1, seed=2)


def test_gibbs_converges_to_noise_floor(small_data):
    train, test = small_data
    s = GibbsSampler(train, test, k=16, alpha=1.0 / 0.09, burn_in=8, widths=(8, 32, 128))
    state = s.run(30, seed=0)
    rmse = s.rmse(state)
    assert np.isfinite(rmse)
    # noise floor is 0.3; posterior mean should approach it
    assert rmse < 0.55, rmse


def test_bpmf_beats_or_matches_als(small_data):
    """Paper Sec 5.2: all versions reach the same accuracy; BPMF is robust
    without per-dataset regularization tuning (ALS given an untuned lambda)."""
    train, test = small_data
    s = GibbsSampler(train, test, k=16, alpha=1.0 / 0.09, burn_in=8, widths=(8, 32, 128))
    st_g = s.run(30, seed=0)
    als = ALS(train, test, k=16, lam_reg=0.3, widths=(8, 32, 128))  # untuned lambda
    st_a = als.run(12)
    assert s.rmse(st_g) <= als.rmse(st_a) + 0.02


def test_gibbs_kernel_path_matches_jnp(small_data):
    """use_kernel=True routes through the Pallas syrk + chol kernels."""
    train, test = small_data
    s_ref = GibbsSampler(train, test, k=16, alpha=10.0, widths=(8, 32))
    s_ker = GibbsSampler(train, test, k=16, alpha=10.0, widths=(8, 32), use_kernel=True)
    st_r = s_ref.init(0)
    st_k = s_ker.init(0)
    st_r = s_ref.sweep(st_r)
    st_k = s_ker.sweep(st_k)
    np.testing.assert_allclose(np.asarray(st_r.u), np.asarray(st_k.u), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st_r.v), np.asarray(st_k.v), atol=2e-3, rtol=2e-3)


@settings(max_examples=25, deadline=None)
@given(
    n_items=st.integers(5, 60),
    n_counter=st.integers(5, 40),
    nnz=st.integers(1, 300),
    seed=st.integers(0, 10_000),
)
def test_bucket_plan_preserves_every_rating(n_items, n_counter, nnz, seed):
    """Property: the padded bucket plan is a lossless re-layout of the CSR."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, n_items, nnz).astype(np.int32)
    cols = rng.integers(0, n_counter, nnz).astype(np.int32)
    vals = rng.normal(size=nnz).astype(np.float32)
    indptr, idx, v = csr_from_coo(rows, cols, vals, n_items)
    plan = plan_buckets(indptr, idx, v, n_items, n_counter, widths=(4, 16, 64))

    # reconstruct multiset of (item, counterpart, value) triples
    got = []
    for b in plan.buckets:
        for r in range(b.rows):
            item = b.seg_item_ids[b.seg_ids[r]]
            for w in range(b.width):
                if b.mask[r, w]:
                    got.append((int(item), int(b.indices[r, w]), float(b.values[r, w])))
    want = sorted(zip(rows.tolist(), cols.tolist(), vals.astype(float).tolist()))
    assert sorted(got) == [tuple(x) for x in want]
    assert plan.nnz == nnz
    assert 0 < plan.padding_efficiency <= 1.0


def test_bucket_plan_empty_items_field():
    """Regression: BucketPlan.empty_items is Optional with a None default —
    constructing a plan without naming it must not trip dataclass machinery,
    and a fully-rated matrix yields an empty (not None) array."""
    from repro.core.buckets import BucketPlan

    plan = BucketPlan(n_items=3, n_counterparts=2, buckets=(), nnz=0, padded=0)
    assert plan.empty_items is None

    # every item rated -> empty_items present but zero-length
    rows = np.array([0, 1, 2, 0], np.int32)
    cols = np.array([0, 1, 0, 1], np.int32)
    vals = np.ones(4, np.float32)
    indptr, idx, v = csr_from_coo(rows, cols, vals, 3)
    full = plan_buckets(indptr, idx, v, 3, 2, widths=(4, 16))
    assert full.empty_items is not None and full.empty_items.size == 0

    # item 1 unrated -> reported as empty
    rows = np.array([0, 2], np.int32)
    indptr, idx, v = csr_from_coo(rows, cols[:2], vals[:2], 3)
    gappy = plan_buckets(indptr, idx, v, 3, 2, widths=(4, 16))
    assert gappy.empty_items.tolist() == [1]


def test_workload_model_monotone():
    d = np.array([0, 1, 10, 1000, 100000])
    c = workload_model(d)
    assert np.all(np.diff(c) > 0)


def test_wishart_sampler_moments():
    """E[Wishart(nu, S)] = nu * S."""
    key = jax.random.PRNGKey(0)
    k = 4
    a = np.random.default_rng(0).normal(size=(k, k))
    s = a @ a.T + np.eye(k)
    chol = jnp.linalg.cholesky(jnp.asarray(s, jnp.float32))
    nu = jnp.asarray(12.0)
    samples = jax.vmap(lambda kk: sample_wishart(kk, nu, chol))(
        jax.random.split(key, 3000)
    )
    mean = np.asarray(samples.mean(0))
    np.testing.assert_allclose(mean, 12.0 * s, rtol=0.15)


def test_normal_wishart_posterior_concentrates():
    """With many observations the NW posterior mean tracks the sample mean."""
    rng = np.random.default_rng(1)
    k = 6
    x = rng.normal(loc=1.7, scale=0.5, size=(5000, k)).astype(np.float32)
    prior = default_prior(k)
    sum_x = jnp.asarray(x.sum(0))
    sum_xxt = jnp.asarray(x.T @ x)
    hp = sample_normal_wishart(jax.random.PRNGKey(2), sum_x, sum_xxt, x.shape[0], prior)
    np.testing.assert_allclose(np.asarray(hp.mu), x.mean(0), atol=0.05)
    # precision should approximate 1/var = 4
    prec_diag = np.diag(np.asarray(hp.lam))
    np.testing.assert_allclose(prec_diag, 4.0, rtol=0.3)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(10, 120),
    p=st.integers(2, 8),
    seed=st.integers(0, 500),
)
def test_lpt_partition_properties(n, p, seed):
    """Property: LPT assignment is a permutation-complete, load-bounded
    partition under the paper's workload model."""
    from repro.core.partition import partition_entities

    rng = np.random.default_rng(seed)
    degrees = rng.zipf(1.5, size=n).clip(0, 10_000)
    part = partition_entities(degrees, p)
    # completeness: every entity exactly once
    ids = part.ids[part.ids >= 0]
    assert sorted(ids.tolist()) == list(range(n))
    # local slots are dense per shard
    for sh in range(p):
        members = np.where(part.shard == sh)[0]
        assert sorted(part.local[members].tolist()) == list(range(len(members)))
    # LPT bound: max load <= mean + max single item (classic guarantee)
    cost = workload_model(degrees)
    loads = np.zeros(p)
    np.add.at(loads, part.shard, cost)
    assert loads.max() <= loads.mean() + cost.max() + 1e-9


def test_serving_builder_smoke():
    from repro.launch.serve import build_serving
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("smollm-360m"))
    model, prefill, decode = build_serving(cfg, max_new=4)
    params = model.init(jax.random.PRNGKey(0))
    out = prefill(params, {"tokens": jnp.ones((2, 8), jnp.int32)})
    cache, logits = decode(params, out["cache"], {"tokens": jnp.ones((2, 1), jnp.int32)})
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
