"""Fused (S*B) cold-start fold-in: equivalence against the per-draw loop,
plan-cache shape stability, and the serving-path fixes around it.

Equivalence tolerance: the fused path computes bucket statistics and the
Cholesky factor bit-identically to the loop (verified by construction and
by the use_kernel case, which matches exactly); only the batched triangular
solves may flip last-bit fp32 rounding because XLA picks a different
micro-kernel per batch size. 1e-5 is far above that rounding and far below
any real divergence.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.sparse import SparseRatings
from repro.kernels import bpmf_topn
from repro.serve import (
    FoldInPlanCache,
    PosteriorEnsemble,
    PublicationChannel,
    RecommendFrontend,
    TopNRecommender,
    fold_in,
    fold_in_loop,
)
from repro.serve import foldin as foldin_mod

S, M, N, K = 6, 50, 120, 8


def _spd(k, rng):
    a = rng.normal(size=(k, k)).astype(np.float32) / np.sqrt(k)
    return a @ a.T + 2.0 * np.eye(k, dtype=np.float32)


@pytest.fixture(scope="module")
def ensemble():
    rng = np.random.default_rng(0)
    return PosteriorEnsemble.from_arrays(
        rng.normal(size=(S, M, K)).astype(np.float32),
        rng.normal(size=(S, N, K)).astype(np.float32),
        hyper_u_mu=rng.normal(size=(S, K)).astype(np.float32) * 0.2,
        hyper_u_lam=np.stack([_spd(K, rng) for _ in range(S)]),
        hyper_v_mu=np.zeros((S, K), np.float32),
        hyper_v_lam=np.stack([np.eye(K, dtype=np.float32)] * S),
        global_mean=3.2,
        alpha=2.0,
        steps=list(range(S)),
    )


def _batch(degrees, n_items=N, seed=0):
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for u, d in enumerate(degrees):
        rows.extend([u] * int(d))
        cols.extend(rng.choice(n_items, int(d), replace=False).tolist())
        vals.extend(rng.normal(3.0, 1.0, int(d)).tolist())
    return SparseRatings(
        rows=np.asarray(rows, np.int32), cols=np.asarray(cols, np.int32),
        vals=np.asarray(vals, np.float32), shape=(len(degrees), n_items),
    )


# ---------------------------------------------------------------------------
# fused solve == per-draw loop
# ---------------------------------------------------------------------------
def test_fused_matches_loop_posterior_mean(ensemble):
    ratings = _batch([3, 17, 40, 9, 1], seed=1)
    fused = np.asarray(fold_in(None, ratings, ensemble, sample=False))
    loop = np.asarray(fold_in_loop(None, ratings, ensemble, sample=False))
    assert fused.shape == (S, 5, K)
    np.testing.assert_allclose(fused, loop, rtol=1e-5, atol=1e-5)


def test_fused_matches_loop_sampling_same_key(ensemble):
    """The fused path pre-draws noise with the loop's per-draw key-split
    sequence, so the same key yields the same conditional draws."""
    ratings = _batch([5, 24, 11], seed=2)
    key = jax.random.PRNGKey(7)
    fused = np.asarray(fold_in(key, ratings, ensemble, sample=True))
    loop = np.asarray(fold_in_loop(key, ratings, ensemble, sample=True))
    np.testing.assert_allclose(fused, loop, rtol=1e-5, atol=1e-5)
    # and it is a genuine draw, not the mean
    mean = np.asarray(fold_in(None, ratings, ensemble, sample=False))
    assert np.abs(fused - mean).max() > 1e-3


@pytest.mark.parametrize("sample", [False, True])
def test_fused_matches_loop_kernel_path(ensemble, sample):
    ratings = _batch([4, 30, 12], seed=3)
    key = jax.random.PRNGKey(11) if sample else None
    fused = np.asarray(
        fold_in(key, ratings, ensemble, sample=sample, use_kernel=True)
    )
    loop = np.asarray(
        fold_in_loop(key, ratings, ensemble, sample=sample, use_kernel=True)
    )
    np.testing.assert_allclose(fused, loop, rtol=1e-5, atol=1e-5)


def test_plan_cache_padding_is_exact(ensemble):
    """Quantized pad rows/segments/batch contribute exact zeros: the cached
    path returns the same posteriors as the exact-shape path."""
    ratings = _batch([3, 17, 40, 9, 1], seed=4)
    exact = np.asarray(fold_in(None, ratings, ensemble, sample=False))
    cached = np.asarray(fold_in(
        None, ratings, ensemble, sample=False, plan_cache=FoldInPlanCache()
    ))
    np.testing.assert_allclose(cached, exact, rtol=1e-5, atol=1e-5)
    # sampling mode: batch padding must not perturb the real users' noise
    key = jax.random.PRNGKey(13)
    exact_s = np.asarray(fold_in(key, ratings, ensemble))
    cached_s = np.asarray(fold_in(
        key, ratings, ensemble, plan_cache=FoldInPlanCache()
    ))
    np.testing.assert_allclose(cached_s, exact_s, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# argument validation and the zero-rating path
# ---------------------------------------------------------------------------
def test_sampling_requires_key(ensemble):
    ratings = _batch([4], seed=5)
    with pytest.raises(ValueError, match="needs a PRNG key"):
        fold_in(None, ratings, ensemble, sample=True)
    with pytest.raises(ValueError, match="needs a PRNG key"):
        fold_in_loop(None, ratings, ensemble, sample=True)


def test_empty_batch_serves_prior_mean(ensemble):
    """Zero ratings -> the user hyper-prior posterior N(mu, lam^-1), without
    ever touching the bucket planner."""
    empty = SparseRatings(
        rows=np.zeros(0, np.int32), cols=np.zeros(0, np.int32),
        vals=np.zeros(0, np.float32), shape=(3, N),
    )
    mean = np.asarray(fold_in(None, empty, ensemble, sample=False))
    assert mean.shape == (S, 3, K)
    for s in range(S):
        want = np.broadcast_to(np.asarray(ensemble.hyper_u_mu[s]), (3, K))
        np.testing.assert_allclose(mean[s], want, rtol=1e-4, atol=1e-4)
    # sampling from the prior works too (and differs from the mean)
    draw = np.asarray(fold_in(jax.random.PRNGKey(0), empty, ensemble))
    assert draw.shape == (S, 3, K)
    assert np.abs(draw - mean).max() > 1e-3


# ---------------------------------------------------------------------------
# plan cache: quantization, hits, trace flatness
# ---------------------------------------------------------------------------
def test_plan_cache_same_profile_hits_zero_traces(ensemble):
    cache = FoldInPlanCache()
    degrees = [6, 28, 45, 10]
    fold_in(None, _batch(degrees, seed=10), ensemble, sample=False,
            plan_cache=cache)
    assert cache.stats() == {"hits": 0, "misses": 1, "entries": 1}
    traces = foldin_mod.trace_count()
    for i in range(4):  # fresh items and values, same rating-count profile
        fold_in(None, _batch(degrees, seed=20 + i), ensemble, sample=False,
                plan_cache=cache)
    assert foldin_mod.trace_count() == traces  # plan-cache hits, no retrace
    assert cache.stats() == {"hits": 4, "misses": 1, "entries": 1}


def test_plan_cache_quantizes_similar_profiles(ensemble):
    """Degree profiles that differ within one power-of-two band share a
    schema — the point of quantizing the rating-count profile."""
    cache = FoldInPlanCache()
    fold_in(None, _batch([5, 20, 40], seed=30), ensemble, sample=False,
            plan_cache=cache)
    traces = foldin_mod.trace_count()
    # different counts, same (width, rows<=8, segments<=8) quantized shape
    fold_in(None, _batch([7, 25, 44, 35], seed=31), ensemble, sample=False,
            plan_cache=cache)
    assert cache.hits == 1 and foldin_mod.trace_count() == traces
    # a genuinely new shape family (only heavy users -> the small-width
    # buckets disappear from the profile) misses
    fold_in(None, _batch([100, 110], seed=32), ensemble, sample=False,
            plan_cache=cache)
    assert cache.misses == 2


def test_balanced_plan_cache_non_pow2_trace_flat(ensemble):
    """A cache built from a reference degree profile carries non-pow2
    balanced widths; it must stay trace-flat across batches (the ladder is
    fitted once and frozen, never refit per batch) and stay exact."""
    ref_degrees = np.repeat([2, 3, 5, 11, 21], 40)
    cache = FoldInPlanCache.balanced(ref_degrees)
    assert any(w & (w - 1) for w in cache.widths)  # genuinely non-pow2
    degrees = [2, 5, 11, 21]
    exact = np.asarray(
        fold_in(None, _batch(degrees, seed=40), ensemble, sample=False)
    )
    got = np.asarray(
        fold_in(None, _batch(degrees, seed=40), ensemble, sample=False,
                plan_cache=cache)
    )
    np.testing.assert_allclose(got, exact, rtol=1e-4, atol=1e-4)
    traces = foldin_mod.trace_count()
    for i in range(3):  # same profile, fresh items: no retrace
        fold_in(None, _batch(degrees, seed=41 + i), ensemble, sample=False,
                plan_cache=cache)
    assert foldin_mod.trace_count() == traces
    assert cache.stats()["misses"] == 1


# ---------------------------------------------------------------------------
# frontend: cold path wiring
# ---------------------------------------------------------------------------
def _sample_dict(step, rng, m=M, n=N, k=K):
    return {
        "u": rng.normal(size=(m, k)).astype(np.float32),
        "v": rng.normal(size=(n, k)).astype(np.float32),
        "hyper_u_mu": rng.normal(size=k).astype(np.float32) * 0.2,
        "hyper_u_lam": _spd(k, rng),
        "hyper_v_mu": np.zeros(k, np.float32),
        "hyper_v_lam": np.eye(k, dtype=np.float32),
        "global_mean": np.float32(3.2),
        "alpha": np.float32(2.0),
    }


def _frontend(window=4, prefill=4, max_batch=8):
    rng = np.random.default_rng(42)
    channel = PublicationChannel(window=window)
    for s in range(prefill):
        channel.publish(s, _sample_dict(s, rng))
    fe = RecommendFrontend(channel=channel, subscribe=False,
                           max_batch=max_batch)
    return fe, channel, rng


def test_frontend_empty_ratings_round_trip():
    """submit_ratings([], []) must serve the user-prior posterior mean."""
    fe, _, _ = _frontend()
    ticket = fe.submit_ratings([], [], topk=5)
    (res,) = fe.flush()
    assert res.ticket == ticket
    assert res.items.shape == (5,)
    assert np.all(res.items >= 0) and len(set(res.items.tolist())) == 5
    assert np.all(np.isfinite(res.scores))
    # the prior-mean user's scores: mean over draws of mu_u^s . v_j^s + mean
    ens = fe.ensemble
    mu = np.asarray(ens.hyper_u_mu)            # (S, K)
    lam = np.asarray(ens.hyper_u_lam)
    prior = np.stack([np.linalg.solve(lam[s], lam[s] @ mu[s]) for s in range(ens.n_samples)])
    want = np.mean(
        np.einsum("sk,snk->sn", prior, np.asarray(ens.v)), axis=0
    ) + ens.global_mean
    np.testing.assert_allclose(res.scores, np.sort(want)[::-1][:5],
                               rtol=1e-4, atol=1e-4)


def test_frontend_cold_batches_trace_flat():
    """Varied cold batches (drifting degrees and batch sizes within one
    quantized family) must not retrace the fold-in solve or the top-N
    kernel once the shape families are warm."""
    fe, _, rng = _frontend()
    profiles = [[31, 34, 45], [30, 31, 32, 33, 40, 50], [44, 46]]

    def serve(profiles, seed):
        for i, degs in enumerate(profiles):
            b = _batch(degs, seed=seed + i)
            for u in range(len(degs)):
                m = b.rows == u
                fe.submit_ratings(b.cols[m], b.vals[m], topk=5)
            res = fe.flush()
            assert len(res) == len(degs)

    serve(profiles, seed=50)   # warm every shape family
    topn_traces = bpmf_topn.trace_count()
    foldin_traces = foldin_mod.trace_count()
    hits0 = fe.foldin_cache.hits
    serve(profiles, seed=60)   # same families, fresh data
    assert bpmf_topn.trace_count() == topn_traces
    assert foldin_mod.trace_count() == foldin_traces
    assert fe.foldin_cache.hits > hits0


def test_frontend_publish_keeps_cache_on_rebind_clears_on_shape_change():
    fe, channel, rng = _frontend(window=4, prefill=3)  # S=3 to start
    fe.submit_ratings([1, 2, 3], [4.0, 3.0, 5.0], topk=3)
    fe.flush()
    assert fe.foldin_cache.stats()["entries"] == 1
    # 4th publish grows the window: S changes -> rebuild -> cache cleared
    channel.publish(3, _sample_dict(3, rng))
    assert fe.refresh() is True
    assert fe.foldin_cache.stats()["entries"] == 0
    fe.submit_ratings([1, 2, 3], [4.0, 3.0, 5.0], topk=3)
    fe.flush()
    assert fe.foldin_cache.stats()["entries"] == 1
    # same-shape publish: rebind, cache kept
    rebinds = fe.rebinds
    channel.publish(4, _sample_dict(4, rng))
    assert fe.refresh() is True
    assert fe.rebinds == rebinds + 1
    assert fe.foldin_cache.stats()["entries"] == 1


# ---------------------------------------------------------------------------
# fetch_hint without exclusions
# ---------------------------------------------------------------------------
def test_recommend_rows_fetch_hint_without_exclude(ensemble):
    """A hint must pin the fetch width even when nothing is excluded, and
    the returned topk must be unchanged by the wider fetch."""
    rec = TopNRecommender(ensemble)
    rows = rec.u_flat[:4]
    plain_v, plain_i = rec.recommend_rows(rows, 5)
    hint_v, hint_i = rec.recommend_rows(rows, 5, fetch_hint=64)
    np.testing.assert_array_equal(plain_i, hint_i)
    np.testing.assert_allclose(plain_v, hint_v, rtol=1e-6, atol=1e-6)
    # the hinted fetch compiles one shape: repeating with other hints that
    # quantize to the same power of two stays on the compiled kernel
    traces = bpmf_topn.trace_count()
    rec.recommend_rows(rows, 5, fetch_hint=50)   # 50 -> 64, same shape
    rec.recommend_rows(rows, 5, fetch_hint=64)
    assert bpmf_topn.trace_count() == traces
