"""Minibatch SGLD engine: gradient exactness against dense numpy, budget
allocation, preconditioning/schedule plumbing, chain behavior (determinism,
convergence, cost decoupling), and the distributed modes (subprocess: jax
pins the device count at first init)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import GibbsSampler, SGLDSampler
from repro.core.sgld import (
    alloc_minibatch,
    data_init_scale,
    effective_temperature,
    langevin_update,
    minibatch_likelihood_grad,
    row_grads,
)
from repro.data import synthetic_lowrank, train_test_split

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str, devices: int = 4) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        f"import sys\nsys.path.insert(0, {SRC!r})\n" + textwrap.dedent(code)
    )
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, timeout=600
    )
    assert res.returncode == 0, res.stderr[-4000:]
    return res.stdout


@pytest.fixture(scope="module")
def small_split():
    ratings, _, _ = synthetic_lowrank(
        300, 200, k_true=6, nnz=9000, noise=0.3, seed=2
    )
    return train_test_split(ratings, 0.1, seed=3)


# ---------------------------------------------------------------------------
# gradient exactness
# ---------------------------------------------------------------------------
def test_row_grads_matches_dense_numpy():
    rng = np.random.default_rng(0)
    n, m, k, s, w = 12, 9, 4, 7, 3
    factors = rng.normal(size=(m, k)).astype(np.float32)
    counter = rng.normal(size=(n, k)).astype(np.float32)
    idx = rng.integers(0, n, (s, w)).astype(np.int32)
    val = rng.normal(size=(s, w)).astype(np.float32)
    msk = (rng.random((s, w)) < 0.7).astype(np.float32)
    items = rng.integers(0, m, (s,)).astype(np.int32)

    got = np.asarray(row_grads(
        jnp.asarray(factors), jnp.asarray(counter), jnp.asarray(idx),
        jnp.asarray(val), jnp.asarray(msk), jnp.asarray(items),
    ))
    want = np.zeros((s, k), np.float32)
    for r in range(s):
        for c in range(w):
            if msk[r, c]:
                vj = counter[idx[r, c]]
                want[r] += (val[r, c] - factors[items[r]] @ vj) * vj
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_full_budget_minibatch_grad_is_exact(small_split):
    """A lane budget covering every plan row short-circuits to the exact
    full-data likelihood gradient — pinned against a dense numpy sum over
    the raw (centered) ratings, which also pins the plan bookkeeping."""
    train, test = small_split
    s = SGLDSampler(train, test, k=8, alpha=2.0, minibatch=10**9)
    assert all(sc == 1.0 for sc in s.user_scales + s.item_scales)
    rng = np.random.default_rng(1)
    u = rng.normal(size=(train.shape[0], 8)).astype(np.float32)
    v = rng.normal(size=(train.shape[1], 8)).astype(np.float32)

    got = np.asarray(minibatch_likelihood_grad(
        jax.random.PRNGKey(0), jnp.asarray(u), jnp.asarray(v),
        s.user_buckets, s.user_rows, s.user_scales,
    ))
    c = train.centered()
    want = np.zeros_like(u)
    for r, cc, val in zip(c.rows, c.cols, c.vals):
        want[r] += (val - u[r] @ v[cc]) * v[cc]
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_sampled_minibatch_grad_is_unbiased(small_split):
    """Inverse-inclusion scaling: averaging the stochastic estimator over
    many independent draws must approach the exact gradient."""
    train, test = small_split
    s = SGLDSampler(train, test, k=4, alpha=2.0, minibatch=512)
    assert any(sc > 1.0 for sc in s.user_scales)  # genuinely subsampled
    exact = SGLDSampler(train, test, k=4, alpha=2.0, minibatch=10**9)
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.normal(size=(train.shape[0], 4)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(train.shape[1], 4)).astype(np.float32))

    want = np.asarray(minibatch_likelihood_grad(
        jax.random.PRNGKey(0), u, v,
        exact.user_buckets, exact.user_rows, exact.user_scales,
    ))
    draw = jax.jit(lambda key: minibatch_likelihood_grad(
        key, u, v, s.user_buckets, s.user_rows, s.user_scales,
    ))
    n_draws = 400
    acc = np.zeros_like(want)
    for i in range(n_draws):
        acc += np.asarray(draw(jax.random.PRNGKey(100 + i)))
    mean = acc / n_draws
    # relative error of the mean shrinks as 1/sqrt(n_draws); bound loosely
    err = np.abs(mean - want).mean() / (np.abs(want).mean() + 1e-9)
    assert err < 0.2, err


# ---------------------------------------------------------------------------
# budget allocation, init scale, schedule plumbing
# ---------------------------------------------------------------------------
def test_alloc_minibatch_splits_budget_by_lane_share(small_split):
    train, _ = small_split
    s = SGLDSampler(train, None, k=4, minibatch=2048)
    for plan, n_rows, scales in (
        (s.user_plan_host, s.user_rows, s.user_scales),
        (s.item_plan_host, s.item_rows, s.item_scales),
    ):
        lanes = 0
        for b, sb, sc in zip(plan.buckets, n_rows, scales):
            rows = b.indices.shape[0]
            assert 1 <= sb <= rows
            assert sc == pytest.approx(rows / sb)
            lanes += sb * b.width
        # total sampled lanes track the budget (exact-capped buckets and
        # per-bucket rounding can undershoot, never blow past 2x)
        assert lanes <= 2 * 2048


def test_data_init_scale_matches_ratings_scale():
    assert data_init_scale(np.zeros(0, np.float32), 16) == 0.1
    assert data_init_scale(np.ones(50, np.float32), 16) == 0.1  # var 0: floor
    vals = np.random.default_rng(0).normal(0, 2.0, 5000).astype(np.float32)
    s = data_init_scale(vals, 16)
    assert s == pytest.approx((np.var(vals) / 16) ** 0.25, rel=1e-6)
    # k * s^4 ~= var(ratings): predictions start at the data's scale
    assert 16 * s**4 == pytest.approx(np.var(vals), rel=1e-4)


def test_effective_temperature_ramp():
    step = jnp.asarray(0, jnp.int32)
    assert float(effective_temperature(step, 1.0, 0)) == 1.0  # disabled
    assert float(effective_temperature(step, 1.0, 100)) == 0.0
    assert float(effective_temperature(jnp.asarray(50), 1.0, 100)) == 0.5
    assert float(effective_temperature(jnp.asarray(400), 1.0, 100)) == 1.0


def test_langevin_clip_bounds_drift_but_not_at_equilibrium():
    # T=0 throughout: the noise term is zero, so each call gets its own
    # key purely for PRNG hygiene — the outputs are deterministic drift
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.zeros((5, 3))
    gain = jnp.full((5,), 0.5)
    eps = 0.01
    huge = jnp.full((5, 3), 1e6)
    # pure drift; the trust region caps it at clip * sqrt(eps * gain)
    out = langevin_update(k1, x, huge, gain, eps, temperature=0.0, clip=3.0)
    lim = 3.0 * np.sqrt(eps * 0.5)
    np.testing.assert_allclose(np.asarray(out), lim, rtol=1e-5)
    # the clip is tied to the T=1 noise scale, so a cooled chain still moves
    assert float(jnp.abs(out).min()) > 0.0
    # a small gradient passes through unclipped
    small = jnp.full((5, 3), 0.1)
    a = langevin_update(k2, x, small, gain, eps, temperature=0.0, clip=3.0)
    b = langevin_update(k3, x, small, gain, eps, temperature=0.0, clip=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# chain behavior
# ---------------------------------------------------------------------------
def test_sgld_deterministic_and_thinned_costs_inert(small_split):
    train, test = small_split
    kw = dict(k=8, alpha=2.0, burn_in=10, minibatch=1024, step_size=0.3)
    a = SGLDSampler(train, test, **kw)
    b = SGLDSampler(train, test, **kw)
    sa, sb = a.init(5), b.init(5)
    for _ in range(12):
        sa, sb = a.sweep(sa), b.sweep(sb)
    np.testing.assert_array_equal(np.asarray(sa.u), np.asarray(sb.u))
    np.testing.assert_array_equal(np.asarray(sa.v), np.asarray(sb.v))
    # hyper thinning holds hypers fixed between draws; accum thinning
    # counts only the collected steps
    c = SGLDSampler(train, test, **kw, hyper_every=4, accum_every=3)
    sc = c.init(5)
    lam0 = None
    for i in range(8):
        sc = c.sweep(sc)
        lam = np.asarray(sc.hyper_v.lam)
        if i % 4 == 0:
            lam0 = lam
        else:
            np.testing.assert_array_equal(lam, lam0)  # held, not redrawn
    assert int(sc.pred_count) == 0  # still in burn-in
    for _ in range(6):
        sc = c.sweep(sc)
    assert int(sc.pred_count) == 2  # steps 10 and 13 of 10..13


def test_sgld_converges_and_tracks_gibbs(small_split):
    """Accuracy parity on a genuinely-learnable split: the SGLD posterior
    mean must land within the ISSUE's 0.05 RMSE of converged fused Gibbs."""
    train, test = small_split
    g = GibbsSampler(train, test, k=16, alpha=4.0, burn_in=5, engine="fused")
    gs = g.init(0)
    for _ in range(15):
        gs = g.sweep(gs)
    s = SGLDSampler(train, test, k=16, alpha=4.0, burn_in=250,
                    minibatch=2048, step_size=1.0, step_decay=1.0,
                    step_t0=50.0, clip=6.0, temp_warmup=250,
                    hyper_every=5, accum_every=5)
    ss = s.init(0)
    for _ in range(500):
        ss = s.sweep(ss)
    assert s.rmse(ss) - g.rmse(gs) < 0.05, (s.rmse(ss), g.rmse(gs))


def test_sgld_per_step_cost_flat_in_dataset_size():
    """The tentpole property, as a structural check: the per-step compiled
    program touches O(minibatch) rating lanes, so the sampled-lane count
    must not grow when nnz quadruples at fixed (m, n, minibatch)."""
    lanes = {}
    for mult in (1, 4):
        ratings, _, _ = synthetic_lowrank(
            400, 200, k_true=4, nnz=6000 * mult, noise=0.3, seed=0
        )
        s = SGLDSampler(ratings, None, k=4, minibatch=1024)
        lanes[mult] = sum(
            sb * b.width for sb, b in zip(s.user_rows, s.user_plan_host.buckets)
        )
    assert lanes[4] <= 1.5 * lanes[1], lanes


# ---------------------------------------------------------------------------
# distributed modes
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_distributed_sgld_all_modes_converge():
    out = run_sub("""
    import json
    import numpy as np
    from repro.data import synthetic_lowrank, train_test_split
    from repro.core.sgld import DistributedSGLD

    ratings, _, _ = synthetic_lowrank(300, 200, k_true=8, nnz=9000,
                                      noise=0.3, seed=3)
    train, test = train_test_split(ratings, 0.1, seed=4)
    out = {}
    for mode in ("ring", "allgather", "async"):
        d = DistributedSGLD(train, test, k=16, alpha=4.0, mode=mode,
                            width="auto", minibatch=4096, step_size=0.3,
                            temp_warmup=150, clip=6.0)
        st = d.run(300, seed=0)
        out[mode] = d.rmse(st)
        if mode == "async":
            # the eval pair carries the stale-by-one v the u-phase read
            assert st.v_eval is not None
    print(json.dumps(out))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    for mode, rmse in res.items():
        assert rmse < 0.7, res
    assert max(res.values()) - min(res.values()) < 0.05, res


@pytest.mark.slow
def test_distributed_sgld_matches_single_host_scale():
    """Distributed SGLD is a different chain (per-shard draws) but must
    agree with the single-host sampler's plateau, not just 'converge'."""
    out = run_sub("""
    import json
    from repro.data import synthetic_lowrank, train_test_split
    from repro.core import SGLDSampler
    from repro.core.sgld import DistributedSGLD

    ratings, _, _ = synthetic_lowrank(300, 200, k_true=8, nnz=9000,
                                      noise=0.3, seed=3)
    train, test = train_test_split(ratings, 0.1, seed=4)
    kw = dict(k=16, alpha=4.0, minibatch=4096, step_size=0.3,
              temp_warmup=150, clip=6.0)
    d = DistributedSGLD(train, test, mode="ring", width="auto", **kw)
    st = d.run(300, seed=0)
    s = SGLDSampler(train, test, burn_in=10**9, **kw)
    ss = s.init(0)
    for _ in range(300):
        ss = s.sweep(ss)
    print(json.dumps({"dist": d.rmse(st), "single": s.sample_rmse(ss)}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["dist"] - res["single"]) < 0.05, res
