"""Multi-host serving tier: merge contract, single-host parity, channel
fan-out, and the all-shards-staged epoch barrier (serve/cluster.py)."""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.checkpoint import as_retained_sample
from repro.kernels import bpmf_topn
from repro.serve import (
    ClusterCoordinator,
    PosteriorEnsemble,
    PublicationChannel,
    TopNRecommender,
)
from repro.serve.cluster import _merge_topk, shard_bounds

M, N, K = 40, 57, 4


def make_sample(step: int, *, n_items: int = N, u=None, v=None) -> dict:
    rng = np.random.default_rng(step)
    return {
        "u": (rng.normal(size=(M, K)).astype(np.float32) if u is None else u),
        "v": (rng.normal(size=(n_items, K)).astype(np.float32) if v is None else v),
        "hyper_u_mu": np.zeros(K, np.float32),
        "hyper_u_lam": np.eye(K, dtype=np.float32),
        "hyper_v_mu": np.zeros(K, np.float32),
        "hyper_v_lam": np.eye(K, dtype=np.float32),
        "global_mean": np.float32(0.0),
        "alpha": np.float32(2.0),
    }


def epoch_coded_sample(step: int) -> dict:
    """Top-1 score == step for every user; item = step % N. Any cross-shard
    tear (one shard's epoch mixed with another's) surfaces as a score that
    disagrees with the served epoch."""
    u = np.full((M, K), 1.0 / K, np.float32)
    v = np.zeros((N, K), np.float32)
    v[step % N] = float(step)
    return make_sample(step, u=u, v=v)


def _ensemble(steps, sample_fn=make_sample) -> PosteriorEnsemble:
    return PosteriorEnsemble(tuple(
        as_retained_sample(s, sample_fn(s)) for s in steps
    ))


# ---------------------------------------------------------------------------
# the merge contract: bit-equality with one unsharded lax.top_k
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
@pytest.mark.parametrize("n_items,topk", [
    (57, 8),     # shards wider than topk
    (21, 8),     # EVERY shard narrower than topk (ragged k_eff < topk)
    (130, 50),   # odd split with a ragged final shard
])
def test_merge_topk_matches_unsharded_reference(n_shards, n_items, topk):
    """Per-shard lax.top_k candidates, concatenated in ascending range
    order and merged, must reproduce one monolithic lax.top_k bit-for-bit —
    including tie resolution to the lowest global item index."""
    rng = np.random.default_rng(n_shards * 1000 + n_items)
    scores = rng.normal(size=(6, n_items)).astype(np.float32)
    # plant cross-shard ties: identical score values far apart on the item
    # axis, so stable ordering is observable
    scores[:, n_items - 1] = scores[:, 0]
    scores[:, n_items // 2] = scores[:, 1]
    scores = jnp.asarray(scores)
    topk = min(topk, n_items)

    want_v, want_i = jax.lax.top_k(scores, topk)

    bounds = shard_bounds(n_items, n_shards)
    vals, idx = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        k_eff = min(topk, int(hi - lo))
        v, pos = jax.lax.top_k(scores[:, lo:hi], k_eff)
        vals.append(v)
        idx.append(pos + np.int32(lo))
    got_v, got_i = _merge_topk(
        jnp.concatenate(vals, 1), jnp.concatenate(idx, 1), topk
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_shard_bounds_cover_and_balance():
    b = shard_bounds(10, 4)
    assert b[0] == 0 and b[-1] == 10
    widths = np.diff(b)
    assert widths.min() >= 2 and widths.max() <= 3


# ---------------------------------------------------------------------------
# property tests: the contracts hold for ARBITRARY shapes, not just the
# parametrized grid above (skipped individually when hypothesis is absent)
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st.data())
def test_merge_topk_bit_equal_under_arbitrary_splits(data):
    """Property form of the merge contract: for ANY item count, ANY topk,
    ANY split (uneven, ragged, empty shards included) and duplicate-heavy
    scores, merging per-shard top_k candidates reproduces one monolithic
    lax.top_k bit-for-bit — stability means every tie resolves to the
    lowest global item index, exactly as unsharded top_k would."""
    n_items = data.draw(st.integers(min_value=1, max_value=48), label="n_items")
    topk = data.draw(st.integers(min_value=1, max_value=n_items), label="topk")
    # a tiny value alphabet forces heavy cross-shard score collisions
    flat = data.draw(
        st.lists(st.integers(min_value=-3, max_value=3),
                 min_size=3 * n_items, max_size=3 * n_items),
        label="scores",
    )
    scores = jnp.asarray(np.asarray(flat, np.float32).reshape(3, n_items))
    cuts = data.draw(
        st.lists(st.integers(min_value=0, max_value=n_items), max_size=5),
        label="cuts",
    )
    bounds = [0, *sorted(cuts), n_items]

    want_v, want_i = jax.lax.top_k(scores, topk)
    vals, idx = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi == lo:
            continue  # empty shard: contributes no candidates
        k_eff = min(topk, hi - lo)
        v, pos = jax.lax.top_k(scores[:, lo:hi], k_eff)
        vals.append(v)
        idx.append(pos + np.int32(lo))
    got_v, got_i = _merge_topk(
        jnp.concatenate(vals, 1), jnp.concatenate(idx, 1), topk
    )
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=1, max_value=2000),
       st.integers(min_value=1, max_value=32))
def test_shard_bounds_properties(n_items, n_shards):
    """Coverage (first bound 0, last n_items, widths sum exactly — so the
    half-open ranges tile the catalogue disjointly), monotonicity, and
    balance (widths within one row) for arbitrary layouts, including more
    shards than items (empty shards allowed, never negative)."""
    b = shard_bounds(n_items, n_shards)
    assert len(b) == n_shards + 1
    assert b[0] == 0 and b[-1] == n_items
    widths = np.diff(b)
    assert (widths >= 0).all()
    assert widths.sum() == n_items          # covers exactly once
    assert widths.max() - widths.min() <= 1  # balanced to within one row


# ---------------------------------------------------------------------------
# parity: the tier IS the single-host recommender, shard count irrelevant
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def ensemble():
    return _ensemble((1, 2, 3))


def test_cluster_bit_identical_to_single_host(ensemble):
    users = np.arange(12, dtype=np.int32)
    single = TopNRecommender(ensemble)
    v1, i1 = single.recommend(users, 9)
    for h in (1, 2, 3, 4):
        cluster = ClusterCoordinator(ensemble, n_hosts=h)
        v2, i2 = cluster.recommend(users, 9)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(v1, v2)


def test_cluster_exclusions_and_foldin_rows_match_single_host(ensemble):
    users = np.arange(8, dtype=np.int32)
    exclude = [np.arange(r, r + 4, dtype=np.int32) for r in range(8)]
    single = TopNRecommender(ensemble)
    cluster = ClusterCoordinator(ensemble, n_hosts=3)

    rows = single.u_flat[users]
    a_v, a_i = single.recommend_rows(rows, 6, exclude=exclude, fetch_hint=16)
    b_v, b_i = cluster.recommend_rows(rows, 6, exclude=exclude, fetch_hint=16)
    np.testing.assert_array_equal(a_i, b_i)
    np.testing.assert_array_equal(a_v, b_v)

    rng = np.random.default_rng(0)
    u_draws = jnp.asarray(rng.normal(size=(ensemble.n_samples, 5, K)),
                          jnp.float32)
    a_v, a_i = single.recommend_factors(u_draws, 4)
    b_v, b_i = cluster.recommend_factors(u_draws, 4)
    np.testing.assert_array_equal(a_i, b_i)
    np.testing.assert_array_equal(a_v, b_v)


def test_topn_recommender_is_the_single_host_special_case(ensemble):
    """The historical TopNRecommender surface maps straight onto the tier."""
    rec = TopNRecommender(ensemble, n_shards=3)
    assert isinstance(rec, ClusterCoordinator)
    assert rec.n_shards == rec.n_hosts == 3
    assert [v.shape[0] for v in rec.v_shards] == [19, 19, 19]
    np.testing.assert_array_equal(rec.shard_offsets, [0, 19, 38])
    assert rec.u_flat.shape == (M, ensemble.n_samples * K)
    rebound = rec.rebind(_ensemble((4, 5, 6)))
    assert isinstance(rebound, TopNRecommender) and rebound.n_shards == 3
    with pytest.raises(ValueError, match="shape changed"):
        rec.rebind(_ensemble((1, 2)))


# ---------------------------------------------------------------------------
# epoch barrier: no epoch is served before ALL shards staged it
# ---------------------------------------------------------------------------
def test_partial_staging_does_not_advance_epoch():
    cluster = ClusterCoordinator(_ensemble((1,), epoch_coded_sample),
                                 n_hosts=3)
    nxt = _ensemble((2,), epoch_coded_sample)
    # stage hosts one at a time: the epoch must only move on the last one
    for host in cluster.hosts[:-1]:
        with cluster._lock:
            host.staged = host.stage(nxt)
            assert cluster._commit_locked(None) is False
        assert cluster.epoch == 1
    with cluster._lock:
        cluster.hosts[-1].staged = cluster.hosts[-1].stage(nxt)
        assert cluster._commit_locked(None) is True
    assert cluster.epoch == 2
    assert all(h.staged is None for h in cluster.hosts)
    assert all(h.live.ensemble.epoch == 2 for h in cluster.hosts)


def test_hosts_staggered_across_publishes_skip_to_common_epoch():
    """Host A staged epoch 2, host B jumped to 3: the barrier holds (2 is
    never served torn), then both land on 3 and it commits."""
    cluster = ClusterCoordinator(_ensemble((1,), epoch_coded_sample),
                                 n_hosts=2)
    e2 = _ensemble((2,), epoch_coded_sample)
    e3 = _ensemble((3,), epoch_coded_sample)
    a, b = cluster.hosts
    with cluster._lock:
        a.staged = a.stage(e2)
        b.staged = b.stage(e3)
        assert cluster._commit_locked(None) is False   # mixed epochs: hold
    assert cluster.epoch == 1
    with cluster._lock:
        a.staged = a.stage(e3)
        assert cluster._commit_locked(None) is True
    assert cluster.epoch == 3  # epoch 2 skipped, never served


def test_channel_fanout_commits_and_serves_consistently():
    """Publishes fan out to every host's subscriber loop; a request issued
    at any moment scores a single epoch across all shards (epoch-coded
    draws make a torn cross-shard mix observable), and the compiled top-N
    kernel is never retraced by same-shape publishes."""
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=2, channel=ch,
    )
    users = np.arange(4, dtype=np.int32)
    cluster.recommend(users, 1)  # compile at the serving shape
    traces_before = bpmf_topn.trace_count()

    def publisher():
        for step in range(2, 30):
            ch.publish(step, epoch_coded_sample(step))
            time.sleep(0.002)
        ch.close()

    pub = threading.Thread(target=publisher)
    pub.start()
    served_epochs = []
    try:
        deadline = time.monotonic() + 20.0
        while time.monotonic() < deadline:
            epoch = cluster.epoch
            vals, idx = cluster.recommend(users, 1)
            # every row scored one consistent cross-shard ensemble: the
            # winning item/score pair is some published epoch's signature,
            # no older than the epoch observed before the request
            got = float(vals[0][0])
            assert got == pytest.approx(round(got)), got
            assert idx[0][0] == int(round(got)) % N
            assert got >= epoch
            served_epochs.append(epoch)
            if ch.closed and cluster.epoch >= 29:
                break
    finally:
        pub.join(timeout=20.0)
        # the last publishes may still be mid-adoption: condition-wait for
        # the final barrier instead of polling
        assert cluster.wait_epoch(29, timeout=20.0)
        cluster.close()

    assert cluster.epoch == 29
    assert served_epochs == sorted(served_epochs)
    assert cluster.commits >= 2
    assert bpmf_topn.trace_count() == traces_before  # zero retraces


def test_shape_change_publish_reshards_all_hosts():
    ch = PublicationChannel(window=2)
    ch.publish(1, epoch_coded_sample(1))
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=2, channel=ch,
    )
    assert cluster.ensemble.shape_key()[0] == 1
    ch.publish(2, epoch_coded_sample(2))  # window grows: S 1 -> 2
    assert cluster.wait_epoch(2, timeout=20.0)  # condition wait, no polling
    cluster.close()
    assert cluster.epoch == 2
    assert cluster.reshards == 1 and cluster.commits == 0
    assert cluster.ensemble.shape_key()[0] == 2
    # bounds still cover the catalogue after the reshard
    assert cluster.hosts[0].live.lo == 0
    assert cluster.hosts[-1].live.hi == N
    vals, idx = cluster.recommend(np.arange(3, dtype=np.int32), 1)
    assert idx[0][0] == 2 % N


def test_adopt_survives_stage_reshard_race():
    """host.stage() raising (live shapes changed by a concurrent reshard
    between the shape check and staging) must not kill the host loop: the
    adoption re-runs as a reshard and the publish is still served."""
    big_n = N + 7
    ch = PublicationChannel(window=1)
    ch.publish(2, epoch_coded_sample(2))
    snap = ch.snapshot()
    cluster = ClusterCoordinator(_ensemble((1,), epoch_coded_sample),
                                 n_hosts=2)
    # simulate the race: a reshard to a different item axis already hit
    # this host's live binding while snap's adoption was in flight
    bigger = PosteriorEnsemble((as_retained_sample(
        1, make_sample(1, n_items=big_n)),))
    cluster.hosts[0].live = cluster.hosts[0].build(bigger, 0, big_n)
    cluster._adopt(cluster.hosts[0], snap)  # must not raise
    assert cluster.epoch == 2
    assert all(h.live.ensemble.epoch == 2 for h in cluster.hosts)
    vals, idx = cluster.recommend(np.arange(3, dtype=np.int32), 1)
    assert idx[0][0] == 2 % N


def test_colocated_hosts_share_one_u_table():
    """The single-host special case must not pay the tier's replica cost:
    every colocated shard aliases one U scoring table."""
    ens = _ensemble((1, 2, 3))
    rec = TopNRecommender(ens, n_shards=4)
    u0 = rec.hosts[0].live.u_replica
    assert all(h.live.u_replica is u0 for h in rec.hosts)
    # the routed tier shares it too while hosts are device-less
    cluster = ClusterCoordinator(ens, n_hosts=4)
    u0 = cluster.hosts[0].live.u_replica
    assert all(h.live.u_replica is u0 for h in cluster.hosts)


def test_frontend_routes_through_cluster_tier():
    """RecommendFrontend(n_hosts=) serves through the coordinator and its
    publish swaps preserve the tier layout (rebind returns the same class
    with the same host count)."""
    from repro.serve import RecommendFrontend

    ch = PublicationChannel(window=1)
    ch.publish(5, epoch_coded_sample(5))
    fe = RecommendFrontend(channel=ch, subscribe=False, max_batch=4,
                           n_hosts=2)
    assert isinstance(fe._recommender, ClusterCoordinator)
    assert not isinstance(fe._recommender, TopNRecommender)
    assert fe._recommender.n_hosts == 2
    fe.submit(0, topk=1)
    (res,) = fe.flush()
    assert res.items[0] == 5 % N and res.scores[0] == pytest.approx(5.0)

    ch.publish(6, epoch_coded_sample(6))
    assert fe.refresh() is True and fe.rebinds == 1
    assert fe._recommender.n_hosts == 2
    fe.submit(1, topk=1)
    (res,) = fe.flush()
    assert res.epoch == 6 and res.items[0] == 6 % N


def test_cluster_freshness_clock_records_barrier_latency():
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=2, channel=ch,
    )
    for step in (2, 3):
        ch.publish(step, epoch_coded_sample(step))
        assert cluster.wait_epoch(step, timeout=20.0)
    cluster.close()
    fresh = cluster.freshness_percentiles()
    assert cluster.commits == 2
    assert len(cluster.publish_to_fresh_s) == 2
    assert 0 < fresh["p50"] <= fresh["max"] < 20.0
