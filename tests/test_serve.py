"""Posterior-predictive serving: ensemble scoring, Pallas top-N, fold-in,
sample retention, and the request-batching frontend."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import SampleStore
from repro.core import GibbsSampler
from repro.data import synthetic_lowrank, train_test_split
from repro.data.sparse import SparseRatings
from repro.kernels import ops, ref
from repro.serve import (
    PosteriorEnsemble,
    RecommendFrontend,
    TopNRecommender,
    fold_in,
)


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    """Small trained model with retained samples: (sample_dir, train, test)."""
    ratings, _, _ = synthetic_lowrank(150, 90, k_true=6, nnz=4000, noise=0.3, seed=1)
    train, test = train_test_split(ratings, 0.1, seed=2)
    root = tmp_path_factory.mktemp("samples")
    store = SampleStore(root, keep=10)
    sampler = GibbsSampler(train, test, k=8, alpha=1.0 / 0.09, burn_in=6,
                           widths=(8, 32, 128))
    sampler.run(16, seed=0, store=store)
    return str(root), train, test


@pytest.fixture(scope="module")
def ensemble(trained):
    root, _, _ = trained
    return PosteriorEnsemble.load(root)


# ---------------------------------------------------------------------------
# sample retention through the checkpoint store
# ---------------------------------------------------------------------------
def test_retained_samples_cover_post_burnin_sweeps(trained, ensemble):
    root, train, _ = trained
    store = SampleStore(root)
    steps = store.steps()
    assert len(steps) == 10  # 16 sweeps - 6 burn-in, all within keep
    assert all(s > 6 for s in steps)
    assert ensemble.n_samples == 10
    assert ensemble.u.shape == (10, train.shape[0], 8)
    assert ensemble.v.shape == (10, train.shape[1], 8)
    assert ensemble.alpha == pytest.approx(1.0 / 0.09, rel=1e-5)


# ---------------------------------------------------------------------------
# ensemble posterior-mean scores vs a NumPy reference
# ---------------------------------------------------------------------------
def test_ensemble_scores_match_numpy_reference(ensemble):
    rng = np.random.default_rng(0)
    users = rng.integers(0, ensemble.n_users, 32).astype(np.int32)
    items = rng.integers(0, ensemble.n_items, 32).astype(np.int32)
    mean, var = ensemble.score(jnp.asarray(users), jnp.asarray(items))

    per_draw = np.stack([
        np.einsum("bk,bk->b", np.asarray(s.u)[users], np.asarray(s.v)[items])
        for s in ensemble.samples
    ]) + ensemble.global_mean
    np.testing.assert_allclose(np.asarray(mean), per_draw.mean(0), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(var),
        per_draw.var(0, ddof=1) + 1.0 / ensemble.alpha,
        atol=1e-5,
    )


def test_ensemble_scoring_matrices_identity(ensemble):
    """U' V'^T must equal the posterior-mean score minus the global mean."""
    u_flat, v_flat = ensemble.scoring_matrices()
    got = np.asarray(u_flat[:5] @ v_flat[:7].T)
    want = np.asarray(ensemble.u[:, :5] @ ensemble.v[:, :7].transpose(0, 2, 1))
    np.testing.assert_allclose(got, want.mean(0), atol=1e-5)


# ---------------------------------------------------------------------------
# Pallas streaming top-k vs jax.lax.top_k — bit-for-bit in interpret mode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,n,k,topk", [
    (8, 1000, 64, 10),
    (16, 257, 16, 50),
    (8, 128, 8, 128),    # topk == block_n, single tile
    (8, 10, 4, 10),      # catalogue smaller than one tile
    (24, 5000, 32, 200), # topk > 128 -> wider tile
])
def test_topn_kernel_bitwise_matches_lax_topk(b, n, k, topk):
    rng = np.random.default_rng(b * 100 + n + k)
    u = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
    if n > 300:
        v = v.at[n // 2].set(v[3])  # force a score tie across tiles
    v1, i1 = ops.topn_scores(u, v, topk, interpret=True)
    v2, i2 = ref.topn_scores_ref(u, v, topk)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_topn_kernel_unaligned_batch_selects_identically():
    """A padded batch may flip last-bit score rounding (different XLA gemm
    micro-kernel) but must select the same items in the same order."""
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=(5, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(333, 16)), jnp.float32)
    v1, i1 = ops.topn_scores(u, v, 7)
    v2, i2 = ref.topn_scores_ref(u, v, 7)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


def test_recommender_sharded_merge_matches_single_shard(ensemble):
    users = np.arange(16, dtype=np.int32)
    one = TopNRecommender(ensemble, n_shards=1)
    many = TopNRecommender(ensemble, n_shards=4)
    v1, i1 = one.recommend(users, 12)
    v2, i2 = many.recommend(users, 12)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(v1, v2, rtol=1e-6, atol=1e-6)


def test_recommender_excludes_seen_items(trained, ensemble):
    _, train, _ = trained
    rec = TopNRecommender(ensemble)
    users = np.arange(10, dtype=np.int32)
    vals, idx = rec.recommend(users, 10, seen=train)
    for r, u in enumerate(users):
        seen = set(train.cols[train.rows == u].tolist())
        got = [i for i in idx[r].tolist() if i >= 0]
        assert not seen.intersection(got)
        assert len(got) == len(set(got))


# ---------------------------------------------------------------------------
# cold-start fold-in
# ---------------------------------------------------------------------------
def test_foldin_clone_matches_trained_user(trained, ensemble):
    """Folding in a clone of a trained user from their ratings alone must
    recover that user's factor posterior: per-draw fold-in *means* track the
    trained draws, and posterior-mean predictions agree."""
    _, train, _ = trained
    degrees = np.bincount(train.rows, minlength=train.shape[0])
    user = int(degrees.argmax())  # best-constrained user
    m = train.rows == user
    clone = SparseRatings(
        rows=np.zeros(int(m.sum()), np.int32), cols=train.cols[m],
        vals=train.vals[m], shape=(1, train.shape[1]),
    )
    u_draws = fold_in(jax.random.PRNGKey(0), clone, ensemble, sample=False)
    assert u_draws.shape == (ensemble.n_samples, 1, ensemble.k)

    fold_mean = np.asarray(u_draws[:, 0]).mean(0)
    trained_mean = np.asarray(ensemble.u[:, user]).mean(0)
    scale = np.abs(trained_mean).max()
    np.testing.assert_allclose(fold_mean, trained_mean, atol=0.35 * scale)

    # the serving-level check: predicted ratings agree tightly
    items = jnp.asarray(train.cols[m][:20], jnp.int32)
    mean_t, _ = ensemble.score(jnp.full((len(items),), user, jnp.int32), items)
    mean_f, _ = ensemble.score_factors(
        jnp.repeat(u_draws, len(items), axis=1), items
    )
    np.testing.assert_allclose(np.asarray(mean_f), np.asarray(mean_t), atol=0.25)


def test_foldin_no_ratings_falls_back_to_prior(ensemble):
    """A user with zero ratings gets the hyperprior posterior N(mu, lam^-1)."""
    empty = SparseRatings(
        rows=np.zeros(0, np.int32), cols=np.zeros(0, np.int32),
        vals=np.zeros(0, np.float32), shape=(1, ensemble.n_items),
    )
    u_draws = fold_in(jax.random.PRNGKey(1), empty, ensemble, sample=False)
    for s, smp in enumerate(ensemble.samples):
        np.testing.assert_allclose(
            np.asarray(u_draws[s, 0]), smp.hyper_u_mu, atol=1e-4
        )


# ---------------------------------------------------------------------------
# predictive variance shrinks with ensemble size
# ---------------------------------------------------------------------------
def test_posterior_mean_stderr_shrinks_with_samples(trained):
    root, _, _ = trained
    small = PosteriorEnsemble.load(root, max_samples=2)
    large = PosteriorEnsemble.load(root, max_samples=10)
    rng = np.random.default_rng(4)
    users = jnp.asarray(rng.integers(0, small.n_users, 64), jnp.int32)
    items = jnp.asarray(rng.integers(0, small.n_items, 64), jnp.int32)
    se_small = float(jnp.mean(small.mean_stderr(users, items)))
    se_large = float(jnp.mean(large.mean_stderr(users, items)))
    assert se_large < se_small, (se_small, se_large)


# ---------------------------------------------------------------------------
# frontend: micro-batching + epoch-keyed cache
# ---------------------------------------------------------------------------
def test_frontend_batches_and_matches_direct_path(trained, ensemble):
    root, train, _ = trained
    fe = RecommendFrontend(root, seen=train, max_batch=4)
    assert fe.epoch == ensemble.epoch

    tickets = [fe.submit(u, topk=5) for u in range(6)]
    m = train.rows == 0
    cold_ticket = fe.submit_ratings(train.cols[m], train.vals[m], topk=5)
    results = {r.ticket: r for r in fe.flush()}
    assert fe.pending == 0
    assert set(results) == set(tickets) | {cold_ticket}

    rec = TopNRecommender(ensemble)
    vals, idx = rec.recommend(np.arange(6, dtype=np.int32), 5, seen=train)
    for r, t in enumerate(tickets):
        np.testing.assert_array_equal(results[t].items, idx[r])
    # the cold clone of user 0 must see none of user 0's rated items
    assert not set(train.cols[m]).intersection(results[cold_ticket].items)
    assert all(r.latency_s >= 0 for r in results.values())
    assert fe.latency_percentiles()["p50"] >= 0


def test_frontend_mixed_topk_batch_truncates_per_request(trained, ensemble):
    """A micro-batch runs at max(p.topk) for one kernel shape, but each
    ticket must get exactly its own topk rows back — a topk=5 ticket
    batched with a topk=50 one must not receive 50 rows."""
    root, train, _ = trained
    fe = RecommendFrontend(root, seen=train, max_batch=8)
    t_small = fe.submit(3, topk=5)
    t_big = fe.submit(4, topk=50)
    m = train.rows == 2
    t_cold = fe.submit_ratings(train.cols[m], train.vals[m], topk=3)
    results = {r.ticket: r for r in fe.flush()}
    assert results[t_small].items.shape == (5,)
    assert results[t_small].scores.shape == (5,)
    assert results[t_big].items.shape == (50,)
    assert results[t_cold].items.shape == (3,)
    # and the truncated rows are the same the request would get alone
    rec = TopNRecommender(ensemble)
    vals, idx = rec.recommend(np.asarray([3], np.int32), 5, seen=train)
    np.testing.assert_array_equal(results[t_small].items, idx[0])


def test_recommend_rows_quantizes_fetch_without_exclusions(ensemble):
    """Exclusion-free callers used to compile one kernel shape per distinct
    topk; the fetch is now power-of-two quantized unconditionally, so every
    topk in a pow2 bucket lands on one compiled executable."""
    from repro.kernels import bpmf_topn

    rec = TopNRecommender(ensemble)
    rows = rec.u_flat[:8]
    rec.recommend_rows(rows, 16)  # compile the 16-wide fetch once
    before = bpmf_topn.trace_count()
    for topk in (9, 12, 13, 16):
        vals, idx = rec.recommend_rows(rows, topk)
        assert idx.shape == (8, topk)
    assert bpmf_topn.trace_count() == before  # all served by the one shape


def test_ensemble_load_survives_concurrent_prune(trained, tmp_path):
    """A co-running trainer can prune a draw between a reader listing steps
    and loading them (the store lock is per-process); the loader must skip
    the vanished draw, not crash."""
    import shutil

    root, _, _ = trained
    racy = tmp_path / "racy"
    shutil.copytree(root, racy)
    store = SampleStore(racy)
    steps = store.steps()
    # simulate the race: oldest step dir half-gone (manifest still listed)
    victim = store.store.root / f"step_{steps[0]:010d}"
    for leaf in victim.glob("leaf_*.npy"):
        leaf.unlink()
    ens = PosteriorEnsemble.load(racy)
    assert ens.n_samples == len(steps) - 1


def test_frontend_refresh_adopts_new_epoch(trained):
    root, train, _ = trained
    fe = RecommendFrontend(root, max_batch=4)
    old_epoch = fe.epoch
    assert fe.refresh() is False  # nothing new retained

    store = SampleStore(root)
    last = store.load(store.epoch())
    store.retain(old_epoch + 1, {
        "u": last.u, "v": last.v,
        "hyper_u_mu": last.hyper_u_mu, "hyper_u_lam": last.hyper_u_lam,
        "hyper_v_mu": last.hyper_v_mu, "hyper_v_lam": last.hyper_v_lam,
        "global_mean": np.asarray(last.global_mean, np.float32),
        "alpha": np.asarray(last.alpha, np.float32),
    })
    store.wait()  # retention is async by default; publish before polling
    assert fe.refresh() is True
    assert fe.epoch == old_epoch + 1
    fe.submit(0, topk=3)
    (res,) = fe.flush()
    assert res.epoch == old_epoch + 1
