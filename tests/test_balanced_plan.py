"""Balanced (work-stealing-equivalent) planner: width-ladder fitting, plan
invariants under non-pow2 widths, and the padding-efficiency gate the paper's
load-balance claim rides on."""
import numpy as np
import jax
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import GibbsSampler, plan_buckets
from repro.core.buckets import (
    BALANCED,
    DEFAULT_WIDTHS,
    balanced_widths,
    pad_bucket,
    resolve_widths,
)
from repro.data import chembl_like, train_test_split
from repro.data.sparse import csr_from_coo


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(5, 400),
    zipf_a=st.floats(1.2, 3.0),
    max_buckets=st.integers(1, 10),
    lane=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 5000),
)
def test_balanced_widths_properties(n, zipf_a, max_buckets, lane, seed):
    """Property: the fitted ladder is sorted, unique, lane-aligned, within
    the bucket budget, and wide enough for every in-range degree."""
    rng = np.random.default_rng(seed)
    degrees = rng.zipf(zipf_a, size=n).astype(np.int64)
    widths = balanced_widths(
        degrees, max_buckets=max_buckets, lane=lane, max_width=512
    )
    assert len(widths) >= 1
    assert len(widths) <= max_buckets
    assert list(widths) == sorted(set(widths))
    assert all(w % lane == 0 for w in widths)
    in_range = degrees[(degrees > 0) & (degrees <= 512)]
    if in_range.size:
        assert widths[-1] >= in_range.max() or 512 in widths
    if (degrees > 512).any():
        # oversize mass forces a max-width split bucket
        assert widths[-1] == -(-512 // lane) * lane


def test_balanced_widths_degenerate_inputs():
    assert balanced_widths(np.array([], np.int64)) == (1,)
    assert balanced_widths(np.zeros(10, np.int64)) == (1,)
    # all oversize: only the split bucket
    assert balanced_widths(np.array([9000, 4000]), max_width=512) == (512,)
    with pytest.raises(ValueError):
        balanced_widths(np.array([1, 2, 3]), max_buckets=0)


def test_resolve_widths_rejects_unknown_string():
    with pytest.raises(ValueError, match="balanced"):
        resolve_widths("lpt", np.array([1, 2, 3]))
    assert resolve_widths(BALANCED, np.array([3, 3, 3])) == (3,)
    assert resolve_widths((32, 8), np.array([1])) == (8, 32)


def _chembl_csr():
    ratings, _, _ = chembl_like(scale=0.004, seed=0)
    train, _ = train_test_split(ratings, 0.05, seed=1)
    c = train.centered()
    m, n = train.shape
    indptr, idx, vals = csr_from_coo(c.rows, c.cols, c.vals, m)
    return indptr, idx, vals, m, n


def test_chembl_padding_efficiency_gate():
    """The acceptance gate of the planner rewrite: > 0.7 on the chembl-like
    profile, where the pow2 ladder managed 0.290 (fig4's seed number)."""
    indptr, idx, vals, m, n = _chembl_csr()
    balanced = plan_buckets(indptr, idx, vals, m, n, widths=BALANCED)
    pow2 = plan_buckets(indptr, idx, vals, m, n, widths=DEFAULT_WIDTHS)
    assert balanced.padding_efficiency > 0.7, balanced.stats()
    assert balanced.padding_efficiency > pow2.padding_efficiency
    assert pow2.padding_efficiency < 0.35  # the problem being fixed is real


def test_balanced_plan_is_lossless_and_pad_keeps_invariants():
    """Every rating survives the non-pow2 re-layout, and pad_bucket keeps
    seg_ids dense-nondecreasing (the fused kernel's reduction invariant)."""
    indptr, idx, vals, m, n = _chembl_csr()
    plan = plan_buckets(indptr, idx, vals, m, n, widths=BALANCED)
    assert plan.nnz == int(np.diff(indptr).sum())
    assert sum(float(b.mask.sum()) for b in plan.buckets) == plan.nnz
    for b in plan.buckets:
        padded = pad_bucket(b, b.rows + 5, b.n_segments + 3)
        s = padded.seg_ids
        assert (np.diff(s) >= 0).all()
        assert s.max() == padded.n_segments - 1
        assert padded.mask[b.rows:].sum() == 0  # pad rows contribute nothing
        # unpadded prefix untouched
        np.testing.assert_array_equal(padded.seg_ids[: b.rows], b.seg_ids)
        np.testing.assert_array_equal(padded.values[: b.rows], b.values)


def test_split_item_segment_sum_recombination():
    """A heavy item split across rows of the widest bucket must recombine,
    via the per-bucket segment sum, to the exact unsplit statistics."""
    rng = np.random.default_rng(3)
    deg = 23                      # > widest width below -> 3 split rows
    n_counter = 40
    cols = rng.choice(n_counter, deg, replace=False).astype(np.int32)
    vals = rng.normal(size=deg).astype(np.float32)
    indptr = np.array([0, deg], np.int64)
    plan = plan_buckets(indptr, cols, vals, 1, n_counter, widths=(3, 9))
    (b,) = plan.buckets
    assert b.width == 9 and b.rows == 3 and b.n_segments == 1

    k = 5
    v = rng.normal(size=(n_counter, k)).astype(np.float32)
    g = v[b.indices] * b.mask[..., None]               # (rows, w, k)
    prec_rows = np.einsum("rwk,rwl->rkl", g, g)
    rhs_rows = np.einsum("rwk,rw->rk", g, b.values * b.mask)
    prec = np.zeros((1, k, k), np.float32)
    rhs = np.zeros((1, k), np.float32)
    np.add.at(prec, b.seg_ids, prec_rows)
    np.add.at(rhs, b.seg_ids, rhs_rows)

    vj = v[cols]
    np.testing.assert_allclose(prec[0], vj.T @ vj, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rhs[0], vj.T @ vals, rtol=1e-5, atol=1e-5)


def test_balanced_sweep_matches_pow2_sweep():
    """The Gibbs chain is plan-independent: a sweep under the balanced
    ladder must match the pow2-ladder sweep up to fp32 accumulation-order
    rounding (the noise is drawn per item, not per plan slot)."""
    ratings, _, _ = chembl_like(scale=0.004, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=2)
    s_bal = GibbsSampler(train, test, k=8, alpha=2.0, widths=BALANCED)
    s_pow = GibbsSampler(train, test, k=8, alpha=2.0, widths=(8, 32, 128, 512))
    st_b = s_bal.sweep(s_bal.init(0))
    st_p = s_pow.sweep(s_pow.init(0))
    np.testing.assert_allclose(
        np.asarray(st_b.u), np.asarray(st_p.u), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(st_b.v), np.asarray(st_p.v), rtol=2e-3, atol=2e-3
    )
