"""Chaos suite for the serving tier: deterministic fault injection
(serve/faults.py) driving the replication/quorum machinery in
serve/cluster.py.

Every schedule is reproducible from its FaultPlan (seeded, seam-pinned —
never sleeps), time is injected (StepClock), and synchronization is
condition-based (`wait_epoch`, `wait_state`), so the invariants below are
asserted in bounded time without wall-clock waits:

  * epochs are monotone and never torn across shards (epoch-coded draws
    make a cross-shard mix observable in the served scores);
  * served top-N always comes from a fully-committed epoch;
  * a dead host never wedges the quorum barrier: with replicas >= 2 its
    shard is carried by a replica, with replicas == 1 it is rebuilt on a
    surviving host;
  * whenever at least one replica per shard is live, served results are
    bit-identical to a healthy single-replica tier at the same epoch.

Run under multiple simulated hosts with
XLA_FLAGS=--xla_force_host_platform_device_count=4 (CI does); the suite is
also correct single-device — hosts are threads either way.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import as_retained_sample
from repro.serve import (
    ClusterCoordinator,
    PosteriorEnsemble,
    PublicationChannel,
    TopNRecommender,
)
from repro.serve.faults import (
    DEAD,
    HEALTHY,
    SUSPECT,
    Clock,
    FaultDrop,
    FaultEvent,
    FaultPlan,
    HostHealth,
    HostKilled,
    StepClock,
)

pytestmark = pytest.mark.chaos

M, N, K = 40, 57, 4
WAIT = 20.0  # generous bound for condition waits; normal paths take ms


def make_sample(step: int, *, u=None, v=None) -> dict:
    rng = np.random.default_rng(step)
    return {
        "u": (rng.normal(size=(M, K)).astype(np.float32) if u is None else u),
        "v": (rng.normal(size=(N, K)).astype(np.float32) if v is None else v),
        "hyper_u_mu": np.zeros(K, np.float32),
        "hyper_u_lam": np.eye(K, dtype=np.float32),
        "hyper_v_mu": np.zeros(K, np.float32),
        "hyper_v_lam": np.eye(K, dtype=np.float32),
        "global_mean": np.float32(0.0),
        "alpha": np.float32(2.0),
    }


def epoch_coded_sample(step: int) -> dict:
    """Top-1 score == step, item == step % N: a torn cross-shard ensemble
    (or a served epoch that was never committed) is observable."""
    u = np.full((M, K), 1.0 / K, np.float32)
    v = np.zeros((N, K), np.float32)
    v[step % N] = float(step)
    return make_sample(step, u=u, v=v)


def _ensemble(steps) -> PosteriorEnsemble:
    return PosteriorEnsemble(tuple(
        as_retained_sample(s, epoch_coded_sample(s)) for s in steps
    ))


def _assert_epoch_coded(vals, idx, *, at_least: int):
    """Every row scored one consistent, committed, epoch-coded ensemble."""
    got = float(vals[0][0])
    assert got == pytest.approx(round(got)), got
    assert idx[0][0] == int(round(got)) % N
    assert got >= at_least


# ---------------------------------------------------------------------------
# FaultPlan: deterministic schedules
# ---------------------------------------------------------------------------
def test_fault_event_validates_seam_and_action():
    with pytest.raises(ValueError, match="unknown seam"):
        FaultEvent(seam="nope")
    with pytest.raises(ValueError, match="unknown action"):
        FaultEvent(seam="adopt", action="explode")
    with pytest.raises(ValueError, match="at must be"):
        FaultEvent(seam="adopt", at=0)


def test_fault_plan_fires_on_nth_traversal_per_host():
    plan = FaultPlan([FaultEvent(seam="stage", action="kill", host=1, at=3)])
    assert plan.fire("stage", 1) is None
    assert plan.fire("stage", 0) is None   # other host: separate counter
    assert plan.fire("stage", 1) is None
    assert plan.fire("adopt", 1) is None   # other seam: separate counter
    ev = plan.fire("stage", 1)             # 3rd traversal of (stage, host 1)
    assert ev is not None and ev.action == "kill"
    assert plan.fired_log == [("stage", 1, ev)]


def test_fault_plan_host_agnostic_event_counts_per_seam():
    plan = FaultPlan([FaultEvent(seam="adopt", action="drop", host=None, at=2)])
    assert plan.fire("adopt", 0) is None
    ev = plan.fire("adopt", 1)  # 2nd adopt anywhere, whichever host
    assert ev is not None and ev.action == "drop"


def test_fault_plan_each_event_fires_once():
    plan = FaultPlan([FaultEvent(seam="gather", action="drop", host=0, at=1)])
    assert plan.fire("gather", 0) is not None
    for _ in range(5):
        assert plan.fire("gather", 0) is None
    assert plan.pending == []


def test_fault_plan_random_is_reproducible_from_seed():
    a = FaultPlan.random(7, n_hosts=4)
    b = FaultPlan.random(7, n_hosts=4)
    assert a.events == b.events and len(a.events) >= 1
    for ev in a.events:
        assert ev.seam in ("adopt", "stage", "commit", "gather")
        assert ev.action in ("kill", "drop", "delay")  # no hangs by default
    c = FaultPlan.random(8, n_hosts=4)
    assert a.events != c.events  # distinct seed, distinct schedule


def test_step_clock_advances_without_wall_time():
    clk = StepClock()
    t0 = time.monotonic()
    clk.sleep(3600.0)  # an hour of virtual time, instantly
    assert time.monotonic() - t0 < 1.0
    assert clk.time() == pytest.approx(3600.0)
    with pytest.raises(ValueError, match="backwards"):
        clk.advance(-1.0)


# ---------------------------------------------------------------------------
# HostHealth: escalation, heartbeats on the injected clock
# ---------------------------------------------------------------------------
def test_health_error_escalation_suspect_then_dead():
    h = HostHealth(max_errors=3)
    h.register(0)
    assert h.state(0) == HEALTHY and h.serveable(0)
    h.error(0, RuntimeError("x"))
    assert h.state(0) == SUSPECT and h.serveable(0) and not h.preferred(0)
    h.error(0, RuntimeError("y"))
    assert h.state(0) == SUSPECT
    h.error(0, RuntimeError("z"))  # 3rd error: terminal
    assert h.state(0) == DEAD and not h.serveable(0)
    assert len(h.errors(0)) == 3


def test_health_heartbeat_staleness_on_injected_clock():
    clk = StepClock()
    h = HostHealth(clock=clk, heartbeat_timeout=5.0)
    h.register(0)
    h.beat(0)
    assert h.state(0) == HEALTHY
    clk.advance(5.1)  # "silent for 5.1s" without any wall-clock wait
    assert h.state(0) == SUSPECT  # staleness folded into the read
    h.beat(0)
    assert h.state(0) == HEALTHY  # next heartbeat revives
    # a host that never beat (no subscriber loop) is serveable by fiat
    h.register(1)
    clk.advance(100.0)
    assert h.state(1) == HEALTHY


def test_health_wait_state_is_condition_based():
    h = HostHealth()
    h.register(0)
    assert h.wait_state(0, DEAD, timeout=0.05) is False  # nothing happened
    t = threading.Timer(0.05, h.kill, args=(0,))
    t.start()
    try:
        assert h.wait_state(0, DEAD, timeout=WAIT) is True  # woken, no poll
    finally:
        t.join()


# ---------------------------------------------------------------------------
# replication layout + serving parity
# ---------------------------------------------------------------------------
def test_replicas_layout_owners_hold_identical_bindings():
    ens = _ensemble((1,))
    cluster = ClusterCoordinator(ens, n_hosts=4, replicas=2)
    assert cluster.n_hosts == 4 and cluster.n_shards == 2
    for s, owners in enumerate(cluster._owners):
        assert [h.shard for h in owners] == [s, s]
        a, b = owners
        assert (a.live.lo, a.live.hi) == (b.live.lo, b.live.hi)
        np.testing.assert_array_equal(np.asarray(a.live.v_shard),
                                      np.asarray(b.live.v_shard))
    # shards still tile the catalogue exactly once
    bounds = sorted({(h.live.lo, h.live.hi) for h in cluster.hosts})
    assert bounds[0][0] == 0 and bounds[-1][1] == N
    for (_, hi), (lo, _) in zip(bounds[:-1], bounds[1:]):
        assert hi == lo


def test_replicated_tier_bit_identical_to_single_host():
    ens = PosteriorEnsemble(tuple(
        as_retained_sample(s, make_sample(s)) for s in (1, 2, 3)
    ))
    users = np.arange(12, dtype=np.int32)
    v1, i1 = TopNRecommender(ens).recommend(users, 9)
    for n_hosts, replicas in ((2, 2), (4, 2), (6, 3), (6, 2)):
        cluster = ClusterCoordinator(ens, n_hosts=n_hosts, replicas=replicas)
        v2, i2 = cluster.recommend(users, 9)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(v1, v2)


def test_replicas_clamp_to_at_least_one_shard():
    cluster = ClusterCoordinator(_ensemble((1,)), n_hosts=2, replicas=5)
    assert cluster.n_shards == 1 and cluster.n_hosts == 2
    vals, idx = cluster.recommend(np.arange(3, dtype=np.int32), 1)
    _assert_epoch_coded(vals, idx, at_least=1)


# ---------------------------------------------------------------------------
# kill-mid-request: failover inside one request
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("at", [1, 2])
def test_kill_serving_host_mid_request_routes_to_replica(at):
    """The acceptance bar, request half: whichever host the `at`-th gather
    of a request hits dies mid-gather (host=None: the serving host, not a
    bystander) — the request completes against a surviving replica,
    bit-identical to a healthy tier at the same committed epoch."""
    ens = PosteriorEnsemble(tuple(
        as_retained_sample(s, make_sample(s)) for s in (1, 2, 3)
    ))
    users = np.arange(8, dtype=np.int32)
    want_v, want_i = TopNRecommender(ens).recommend(users, 7)

    plan = FaultPlan([FaultEvent(seam="gather", action="kill",
                                 host=None, at=at)])
    cluster = ClusterCoordinator(ens, n_hosts=4, replicas=2, faults=plan)
    got_v, got_i = cluster.recommend(users, 7)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_v, want_v)
    dead = [h.host_id for h in cluster.hosts
            if cluster.health.state(h.host_id) == DEAD]
    assert len(dead) == 1
    assert cluster.gather_failovers >= 1
    # the dead host stays routed around: next request is clean, no new hosts
    n_hosts = cluster.n_hosts
    got_v, got_i = cluster.recommend(users, 7)
    np.testing.assert_array_equal(got_i, want_i)
    assert cluster.n_hosts == n_hosts and cluster.reassignments == 0


@pytest.mark.parametrize("victim", [0, 1, 2, 3])
def test_any_single_dead_host_serves_bit_identically(victim):
    """Kill ANY single host (preferred replica or standby) with replicas=2:
    serving stays bit-identical to a healthy tier and nothing is rebuilt —
    the other replica of the victim's shard carries it."""
    ens = PosteriorEnsemble(tuple(
        as_retained_sample(s, make_sample(s)) for s in (1, 2, 3)
    ))
    users = np.arange(8, dtype=np.int32)
    want_v, want_i = TopNRecommender(ens).recommend(users, 7)
    cluster = ClusterCoordinator(ens, n_hosts=4, replicas=2)
    cluster.health.kill(victim)
    got_v, got_i = cluster.recommend(users, 7)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_v, want_v)
    assert cluster.reassignments == 0 and cluster.n_hosts == 4


def test_drop_mid_gather_escalates_and_reroutes():
    ens = _ensemble((4,))
    users = np.arange(6, dtype=np.int32)
    want_v, want_i = TopNRecommender(ens).recommend(users, 5)
    plan = FaultPlan([FaultEvent(seam="gather", action="drop", host=1)])
    cluster = ClusterCoordinator(ens, n_hosts=4, replicas=2, faults=plan)
    got_v, got_i = cluster.recommend(users, 5)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_v, want_v)
    # a lost response is an error signal, not a death sentence
    assert cluster.health.state(1) == SUSPECT
    assert len(cluster.health.errors(1)) == 1


def test_kill_all_replicas_reassigns_shard_bit_identically():
    """Cascading double-failure inside one shard: both owners die — the
    shard is rebuilt from the committed ensemble on a fresh host and the
    request still completes, bit-identical (the rebuilt binding is a pure
    function of the same ensemble)."""
    ens = PosteriorEnsemble(tuple(
        as_retained_sample(s, make_sample(s)) for s in (1, 2)
    ))
    users = np.arange(8, dtype=np.int32)
    want_v, want_i = TopNRecommender(ens).recommend(users, 7)
    cluster = ClusterCoordinator(ens, n_hosts=4, replicas=2)
    for h in cluster._owners[0]:  # shard 0's owners: hosts 0 and 2
        cluster.health.kill(h.host_id)
    got_v, got_i = cluster.recommend(users, 7)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_v, want_v)
    assert cluster.reassignments == 1
    assert cluster.n_hosts == 5  # the replacement joined the tier
    # the replacement is an owner of shard 0 and serves subsequent requests
    assert cluster._owners[0][-1].shard == 0
    got_v, got_i = cluster.recommend(users, 7)
    np.testing.assert_array_equal(got_i, want_i)
    assert cluster.reassignments == 1  # no second rebuild


def test_cascading_failures_across_shards_still_serve():
    """One host down in EVERY shard (n_hosts=4, replicas=2): each shard
    leans on its surviving replica; nothing is rebuilt."""
    ens = _ensemble((6,))
    plan = FaultPlan([
        FaultEvent(seam="gather", action="kill", host=0),
        FaultEvent(seam="gather", action="kill", host=1),
    ])
    cluster = ClusterCoordinator(ens, n_hosts=4, replicas=2, faults=plan)
    vals, idx = cluster.recommend(np.arange(4, dtype=np.int32), 1)
    _assert_epoch_coded(vals, idx, at_least=6)
    assert cluster.health.state(0) == DEAD and cluster.health.state(1) == DEAD
    assert cluster.reassignments == 0


def test_delay_fault_runs_on_injected_clock():
    clk = StepClock()
    plan = FaultPlan(
        [FaultEvent(seam="gather", action="delay", host=0, delay_s=120.0)],
        clock=clk,
    )
    cluster = ClusterCoordinator(_ensemble((2,)), n_hosts=2, replicas=1,
                                 faults=plan)
    t0 = time.monotonic()
    vals, idx = cluster.recommend(np.arange(3, dtype=np.int32), 1)
    assert time.monotonic() - t0 < 5.0   # 2 virtual minutes, no wall wait
    assert clk.time() == pytest.approx(120.0)
    _assert_epoch_coded(vals, idx, at_least=2)


# ---------------------------------------------------------------------------
# quorum barrier: staged replicas, dead hosts, late catch-up
# ---------------------------------------------------------------------------
def test_quorum_commits_with_one_staged_replica_per_shard():
    cluster = ClusterCoordinator(_ensemble((1,)), n_hosts=4, replicas=2)
    nxt = _ensemble((2,))
    a0, a1 = cluster._owners[0]
    b0, _ = cluster._owners[1]
    with cluster._lock:
        a0.staged = a0.stage(nxt)
        assert cluster._commit_locked(None) is False  # shard 1 uncovered
    assert cluster.epoch == 1
    with cluster._lock:
        b0.staged = b0.stage(nxt)
        assert cluster._commit_locked(None) is True   # one replica per shard
    assert cluster.epoch == 2
    assert a0.live.ensemble.epoch == 2 and b0.live.ensemble.epoch == 2
    assert a1.live.ensemble.epoch == 1  # the other replica is simply late
    # requests route around the stale replica meanwhile
    vals, idx = cluster.recommend(np.arange(3, dtype=np.int32), 1)
    _assert_epoch_coded(vals, idx, at_least=2)


def test_late_replica_flips_in_place_without_second_commit():
    cluster = ClusterCoordinator(_ensemble((1,)), n_hosts=4, replicas=2)
    snap_like = _ensemble((2,))
    a0, a1 = cluster._owners[0]
    b0, _ = cluster._owners[1]
    with cluster._lock:
        a0.staged = a0.stage(snap_like)
        b0.staged = b0.stage(snap_like)
        cluster._commit_locked(None)
    commits = cluster.commits
    assert cluster.epoch == 2 and a1.live.ensemble.epoch == 1

    # the late replica's subscriber now delivers the already-committed epoch
    ch = PublicationChannel(window=1)
    ch.publish(2, epoch_coded_sample(2))
    cluster._adopt(a1, ch.snapshot())
    assert a1.live.ensemble.epoch == 2 and a1.staged is None
    assert cluster.commits == commits and cluster.epoch == 2  # no re-commit


def test_dead_host_does_not_wedge_barrier_replicas2():
    """The acceptance bar, publish half: with replicas=2, a host killed
    mid-publish leaves the quorum able to commit the newer epoch — the
    barrier no longer waits on the dead."""
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    plan = FaultPlan([FaultEvent(seam="adopt", action="kill", host=2)])
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=4, replicas=2,
        channel=ch, faults=plan,
    )
    try:
        ch.publish(2, epoch_coded_sample(2))
        assert cluster.wait_epoch(2, timeout=WAIT), cluster.stats()
        assert cluster.health.wait_state(2, DEAD, timeout=WAIT)
        vals, idx = cluster.recommend(np.arange(4, dtype=np.int32), 1)
        _assert_epoch_coded(vals, idx, at_least=2)
        # and the NEXT publish also commits: the tier is not limping
        ch.publish(3, epoch_coded_sample(3))
        assert cluster.wait_epoch(3, timeout=WAIT), cluster.stats()
    finally:
        ch.close()
        cluster.close()


@pytest.mark.parametrize("seam", ["adopt", "stage", "commit"])
@pytest.mark.parametrize("victim", [0, 1, 2, 3])
def test_kill_any_host_mid_publish_bit_identical(victim, seam):
    """Acceptance criterion in full: killing ANY single host at ANY
    publish-path seam with replicas=2 leaves the tier serving bit-identical
    results to a healthy tier at the last fully-committed epoch, and a
    subsequent publish commits a newer epoch (no wedged barrier)."""
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    boot = PosteriorEnsemble(ch.snapshot().draws)
    plan = FaultPlan([FaultEvent(seam=seam, action="kill", host=victim)])
    cluster = ClusterCoordinator(boot, n_hosts=4, replicas=2,
                                 channel=ch, faults=plan)
    try:
        ch.publish(2, epoch_coded_sample(2))
        assert cluster.wait_epoch(2, timeout=WAIT), cluster.stats()
        users = np.arange(8, dtype=np.int32)
        want_v, want_i = TopNRecommender(_ensemble((2,))).recommend(users, 5)
        got_v, got_i = cluster.recommend(users, 5)
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_v, want_v)
        ch.publish(3, epoch_coded_sample(3))
        assert cluster.wait_epoch(3, timeout=WAIT), cluster.stats()
        got_v, got_i = cluster.recommend(users, 5)
        want_v, want_i = TopNRecommender(_ensemble((3,))).recommend(users, 5)
        np.testing.assert_array_equal(got_i, want_i)
        np.testing.assert_array_equal(got_v, want_v)
    finally:
        ch.close()
        cluster.close()


def test_single_replica_dead_host_is_reassigned_not_wedged():
    """replicas=1 — the pre-replication wedge case ROADMAP called out: the
    dead host's shard can never stage, so the barrier rebuilds it on a
    fresh host whose subscriber stages the pending epoch. Publishes keep
    committing."""
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    plan = FaultPlan([FaultEvent(seam="adopt", action="kill", host=0)])
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=2, replicas=1,
        channel=ch, faults=plan,
    )
    try:
        ch.publish(2, epoch_coded_sample(2))  # kills host 0 mid-adopt
        assert cluster.health.wait_state(0, DEAD, timeout=WAIT)
        # host 0's shard is uncovered: epoch 2 cannot commit until the
        # replacement (spawned at the next barrier attempt) stages it
        ch.publish(3, epoch_coded_sample(3))
        assert cluster.wait_epoch(3, timeout=WAIT), cluster.stats()
        assert cluster.reassignments >= 1
        vals, idx = cluster.recommend(np.arange(4, dtype=np.int32), 1)
        _assert_epoch_coded(vals, idx, at_least=3)
    finally:
        ch.close()
        cluster.close()


def test_drop_at_adopt_host_catches_up_on_next_publish():
    """A publish lost to one host (drop) delays nothing fatal: its replica
    covers the quorum, the stale host is routed around, and it rejoins at
    the next publish it does receive."""
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    plan = FaultPlan([FaultEvent(seam="adopt", action="drop", host=3)])
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=4, replicas=2,
        channel=ch, faults=plan,
    )
    try:
        ch.publish(2, epoch_coded_sample(2))  # lost to host 3
        assert cluster.wait_epoch(2, timeout=WAIT), cluster.stats()
        vals, idx = cluster.recommend(np.arange(4, dtype=np.int32), 1)
        _assert_epoch_coded(vals, idx, at_least=2)
        ch.publish(3, epoch_coded_sample(3))  # host 3 receives this one
        assert cluster.wait_epoch(3, timeout=WAIT), cluster.stats()
        deadline = time.monotonic() + WAIT
        while (cluster.hosts[3].live.ensemble.epoch < 3
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert cluster.hosts[3].live.ensemble.epoch == 3  # caught up
    finally:
        ch.close()
        cluster.close()


def test_hang_then_recover():
    """A hung host (stalled process, not dead) stops staging; its replica
    carries the quorum. On release it drains the channel, catches up, and
    is preferred for routing again."""
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    plan = FaultPlan([FaultEvent(seam="stage", action="hang", host=1)],
                     hang_timeout=WAIT)
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=4, replicas=2,
        channel=ch, faults=plan,
    )
    try:
        ch.publish(2, epoch_coded_sample(2))  # host 1 hangs mid-stage
        assert cluster.wait_epoch(2, timeout=WAIT), cluster.stats()
        deadline = time.monotonic() + WAIT
        while not plan.hanging and time.monotonic() < deadline:
            time.sleep(0.002)
        assert plan.hanging == {1}
        vals, idx = cluster.recommend(np.arange(4, dtype=np.int32), 1)
        _assert_epoch_coded(vals, idx, at_least=2)  # served around the hang

        plan.release()  # recover
        deadline = time.monotonic() + WAIT
        while (cluster.hosts[1].live.ensemble.epoch < 2
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert cluster.hosts[1].live.ensemble.epoch == 2  # late flip
        ch.publish(3, epoch_coded_sample(3))
        assert cluster.wait_epoch(3, timeout=WAIT), cluster.stats()
    finally:
        plan.release()
        ch.close()
        cluster.close()


def test_wait_epoch_is_condition_based():
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=2, channel=ch,
    )
    try:
        assert cluster.wait_epoch(1, timeout=0.0) is True   # already there
        assert cluster.wait_epoch(9, timeout=0.05) is False  # not yet
        t = threading.Timer(0.05, ch.publish, args=(9, epoch_coded_sample(9)))
        t.start()
        try:
            assert cluster.wait_epoch(9, timeout=WAIT) is True
        finally:
            t.join()
    finally:
        ch.close()
        cluster.close()


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------
def test_stats_reports_health_quorum_and_counters():
    plan = FaultPlan([FaultEvent(seam="gather", action="kill", host=0)])
    cluster = ClusterCoordinator(_ensemble((5,)), n_hosts=4, replicas=2,
                                 faults=plan)
    cluster.recommend(np.arange(2, dtype=np.int32), 1)  # kills host 0
    s = cluster.stats()
    assert s["epoch"] == 5 and s["replicas"] == 2 and s["n_shards"] == 2
    assert s["n_hosts"] == 4 and s["gather_failovers"] >= 1
    assert s["hosts"][0]["state"] == DEAD and s["hosts"][1]["state"] == HEALTHY
    assert s["hosts"][0]["shard"] == 0 and s["hosts"][3]["live_epoch"] == 5
    assert s["quorum"][0]["owners"] == [0, 2]
    assert s["quorum"][0]["serveable"] == [2]  # the dead owner dropped out
    assert s["quorum"][1]["serveable"] == [1, 3]
    assert s["adopt_errors"] == 0 and s["reassignments"] == 0


def test_stats_shows_staged_epochs_mid_barrier():
    cluster = ClusterCoordinator(_ensemble((1,)), n_hosts=4, replicas=2)
    nxt = _ensemble((2,))
    a0 = cluster._owners[0][0]
    with cluster._lock:
        a0.staged = a0.stage(nxt)
    s = cluster.stats()
    assert s["quorum"][0]["staged"] == {a0.host_id: 2}
    assert s["quorum"][1]["staged"] == {}
    assert s["hosts"][a0.host_id]["staged_epoch"] == 2


# ---------------------------------------------------------------------------
# randomized schedules: the invariants survive ANY fault sequence
# ---------------------------------------------------------------------------
CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
N_SCHEDULES = int(os.environ.get("REPRO_CHAOS_SCHEDULES", "50"))


def _run_schedule(seed: int) -> None:
    """One randomized chaos run. The schedule is a pure function of `seed`
    (FaultPlan.random) — a failure here replays bit-for-bit from the seed
    printed in the assertion message."""
    ctx = f"schedule seed={seed}"
    clk = StepClock()
    plan = FaultPlan.random(seed, n_hosts=4, clock=clk, max_delay_s=5.0)
    ch = PublicationChannel(window=1)
    ch.publish(1, epoch_coded_sample(1))
    cluster = ClusterCoordinator(
        PosteriorEnsemble(ch.snapshot().draws), n_hosts=4, replicas=2,
        channel=ch, faults=plan,
    )
    users = np.arange(4, dtype=np.int32)
    try:
        observed = [cluster.epoch]
        for step in range(2, 7):
            ch.publish(step, epoch_coded_sample(step))
            # serve WHILE the publish storm and the fault schedule land
            epoch_before = cluster.epoch
            vals, idx = cluster.recommend(users, 1)
            # invariant: consistent, committed, untorn — the winning
            # (score, item) pair is some single epoch's signature, no older
            # than the epoch observed before the request was issued
            got = float(vals[0][0])
            assert got == pytest.approx(round(got)), (ctx, got)
            assert idx[0][0] == int(round(got)) % N, (ctx, got, idx[0][0])
            assert got >= epoch_before >= 1, (ctx, got, epoch_before)
            observed.append(cluster.epoch)
        # invariant: epochs monotone
        assert observed == sorted(observed), (ctx, observed)

        # invariant: no deadlock — after the (finite) schedule is exhausted,
        # fresh publishes commit in bounded time. Dropped/killed adoptions
        # may hold individual epochs back, so converge with retries bounded
        # by the number of fault events, not a hope.
        step = 7
        for _ in range(len(plan.events) + 3):
            ch.publish(step, epoch_coded_sample(step))
            if cluster.wait_epoch(step, timeout=WAIT):
                break
            step += 1
        else:
            pytest.fail(f"{ctx}: barrier wedged; stats={cluster.stats()}")

        # invariant: converged tier serves bit-identically to a healthy one
        want_v, want_i = TopNRecommender(_ensemble((step,))).recommend(users, 3)
        got_v, got_i = cluster.recommend(users, 3)
        np.testing.assert_array_equal(got_i, want_i, err_msg=ctx)
        np.testing.assert_array_equal(got_v, want_v, err_msg=ctx)
    finally:
        plan.release()
        ch.close()
        cluster.close()


@pytest.mark.parametrize("offset", range(N_SCHEDULES))
def test_randomized_schedule_preserves_invariants(offset):
    """50 seeded schedules (pin with REPRO_CHAOS_SEED; CI runs a small seed
    matrix on top). Kills, drops, and delays land at arbitrary seams on
    arbitrary hosts; every run must keep the tier monotone, untorn,
    commit-serving, and deadlock-free."""
    _run_schedule(CHAOS_SEED * 1000 + offset)
