"""Per-architecture smoke tests + family-specific correctness tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, get_config, reduced
from repro.models import build_model, input_specs
from repro.models.api import ShapeSpec

KEY = jax.random.PRNGKey(0)
TRAIN = ShapeSpec("smoke_train", 64, 2, "train")
PREFILL = ShapeSpec("smoke_pre", 32, 2, "prefill")
DECODE = ShapeSpec("smoke_dec", 32, 2, "decode")


def make_batch(cfg, shape, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    for k, sd in input_specs(cfg, shape).items():
        if sd.dtype == jnp.int32:
            if k == "positions":
                batch[k] = jnp.broadcast_to(
                    jnp.arange(sd.shape[-1], dtype=jnp.int32), sd.shape
                )
            else:
                batch[k] = jax.random.randint(key, sd.shape, 0, min(cfg.vocab_size, 128), jnp.int32)
        else:
            batch[k] = 0.2 * jax.random.normal(key, sd.shape, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_smoke_train_step(arch):
    """Reduced config: one forward/loss step, output shapes + no NaNs."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    loss, metrics = jax.jit(model.loss_fn)(params, make_batch(cfg, TRAIN))
    assert np.isfinite(float(loss)), (arch, loss)
    assert 2.0 < float(loss) < 12.0, (arch, loss)  # ~ln(512) at init


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_smoke_grads_finite(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    grads = jax.jit(jax.grad(lambda p, b: model.loss_fn(p, b)[0]))(
        params, make_batch(cfg, TRAIN)
    )
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all(), (arch, path)


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_prefill_then_decode_matches_full_forward(arch):
    """Cache correctness: prefill(t[:s-1]) + decode(t[s-1]) == logits of a
    full prefill over t — the strongest end-to-end cache invariant."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, PREFILL, seed=1)
    full = jax.jit(model.prefill_fn)(params, batch)
    logits_full = np.asarray(full["logits"], np.float32)  # last position of S

    toks = batch["tokens"]
    short = dict(batch)
    short["tokens"] = toks[:, :-1]
    if cfg.family == "vlm":
        s_total = cfg.n_patches + toks.shape[1]
        short["positions"] = batch["positions"][:, :, : s_total - 1]
    out = jax.jit(model.prefill_fn)(params, short)
    dbatch = {"tokens": toks[:, -1:]}
    if cfg.family == "vlm":
        dbatch["positions"] = batch["positions"][:, :, -1:]
    _, logits_dec = jax.jit(model.decode_fn)(params, out["cache"], dbatch)
    np.testing.assert_allclose(
        logits_full, np.asarray(logits_dec, np.float32), rtol=3e-2, atol=3e-2
    )


def test_mlstm_chunked_matches_sequential():
    from repro.models.ssm import mlstm_chunked, mlstm_sequential, mlstm_init_state

    rng = np.random.default_rng(0)
    b, s, h, d = 2, 96, 3, 16
    q, k, v = (jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32) for _ in range(3))
    i_raw = jnp.asarray(rng.normal(size=(b, s, h)) - 1.0, jnp.float32)
    f_raw = jnp.asarray(rng.normal(size=(b, s, h)) + 2.0, jnp.float32)
    st0 = mlstm_init_state(b, h, d, d)
    o_seq, st_seq = mlstm_sequential(q, k, v, i_raw, f_raw, st0)
    o_chk, st_chk = mlstm_chunked(q, k, v, i_raw, f_raw, st0, chunk=32)
    np.testing.assert_allclose(o_seq, o_chk, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_seq.c, st_chk.c, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_seq.n, st_chk.n, rtol=2e-4, atol=2e-4)


def test_mamba2_chunked_matches_stepwise():
    from repro.models.mamba import ssd_chunked, ssd_step

    rng = np.random.default_rng(1)
    b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    y_chunk, h_chunk = ssd_chunked(x, dt, a, bb, cc, h0, chunk=16)

    hs = h0
    ys = []
    for t in range(s):
        y, hs = ssd_step(x[:, t], dt[:, t], a, bb[:, t], cc[:, t], hs)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_step, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(h_chunk, hs, rtol=3e-4, atol=3e-4)


def test_moe_block_routes_topk_and_drops_overflow():
    from repro.models.layers import ModelConfig, init_moe, moe_block

    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab_size=64, n_experts=8, n_experts_active=2, moe_d_ff=16,
        capacity_factor=8.0,  # effectively dropless
        dtype=jnp.float32, param_dtype=jnp.float32,
    )
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    out, aux = moe_block(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0

    # dense reference: weighted sum over top-k experts, no capacity
    xf = np.asarray(x.reshape(-1, 32))
    logits = xf @ np.asarray(params["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    topw, tope = jax.lax.top_k(probs, 2)
    topw = np.asarray(topw / topw.sum(-1, keepdims=True))
    tope = np.asarray(tope)
    wg, wu, wd = (np.asarray(params[k]) for k in ("w_gate", "w_up", "w_down"))
    want = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for j in range(2):
            e = tope[t, j]
            gate = xf[t] @ wg[e]
            up = xf[t] @ wu[e]
            act = gate / (1 + np.exp(-gate))
            want[t] += topw[t, j] * ((act * up) @ wd[e])
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, 32), want, rtol=2e-3, atol=2e-3
    )


def test_gemma2_local_global_pattern():
    from repro.models.transformer import layer_windows

    cfg = get_config("gemma2-2b")
    w = np.asarray(layer_windows(cfg))
    assert w.shape == (26,)
    assert (w[::2] == 4096).all() and (w[1::2] == 0).all()


def test_mrope_sections_rotate_independently():
    from repro.models.layers import apply_mrope, apply_rope

    rng = np.random.default_rng(2)
    b, s, h, hd = 1, 6, 2, 32
    x = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, 3, s))
    out_m = apply_mrope(x, pos, 10_000.0, (6, 5, 5))
    out_r = apply_rope(x, pos[:, 0], 10_000.0)
    # with all three channels equal, M-RoPE == RoPE
    np.testing.assert_allclose(out_m, out_r, rtol=1e-5, atol=1e-5)


def test_config_parameter_counts():
    """Full (non-reduced) configs hit their published parameter scales.

    Exact counts come from the real init shapes (models/api.count_params).
    xlstm lands below its 350m label because our mLSTM keeps q/k/v in
    d_model space (noted in DESIGN.md) — we assert our own documented count.
    """
    from repro.models.api import count_params

    expect = {
        "granite-20b": (20e9, 0.15),
        "gemma2-2b": (2.6e9, 0.35),
        "smollm-360m": (0.36e9, 0.3),
        "stablelm-1.6b": (1.6e9, 0.3),
        "kimi-k2-1t-a32b": (1.0e12, 0.2),
        "qwen2-vl-7b": (7.6e9, 0.15),
        "zamba2-7b": (7e9, 0.25),
    }
    for arch, (n, tol) in expect.items():
        total, active = count_params(get_config(arch))
        assert abs(total - n) / n < tol, (arch, total, n)
    # MoE active-parameter sanity: kimi-k2 is 1T total / ~32B active
    total, active = count_params(get_config("kimi-k2-1t-a32b"))
    assert 25e9 < active < 40e9, active


# ---------------------------------------------------------------------------
# Perf-variant paths (EXPERIMENTS.md §Perf) must be numerically faithful
# ---------------------------------------------------------------------------
def test_ssd_fold_decay_matches_baseline():
    import dataclasses
    from repro.models.mamba import ssd_chunked

    rng = np.random.default_rng(1)
    b, s, h, p, g, n = 2, 64, 4, 8, 2, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    y0, hs0 = ssd_chunked(x, dt, a, bb, cc, h0, chunk=16, fold_decay=False)
    y1, hs1 = ssd_chunked(x, dt, a, bb, cc, h0, chunk=16, fold_decay=True)
    np.testing.assert_allclose(y0, y1, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(hs0, hs1, rtol=1e-3, atol=1e-3)


def test_grouped_moe_matches_global_dispatch():
    import dataclasses
    from repro.models.layers import ModelConfig, init_moe, moe_block

    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab_size=64, n_experts=8, n_experts_active=2, moe_d_ff=16,
        capacity_factor=8.0, dtype=jnp.float32, param_dtype=jnp.float32,
    )
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32), jnp.float32)
    o0, a0 = moe_block(params, x, cfg)
    o1, a1 = moe_block(params, x, dataclasses.replace(cfg, moe_group_dispatch=True))
    np.testing.assert_allclose(o0, o1, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-4)


def test_bf16_probs_attention_close_to_f32():
    from repro.models.layers import multi_head_attention

    rng = np.random.default_rng(3)
    q, k, v = (jnp.asarray(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
               for _ in range(3))
    o0 = multi_head_attention(q, k, v, causal=True, chunk=32)
    o1 = multi_head_attention(q, k, v, causal=True, chunk=32, probs_bf16=True)
    assert np.abs(np.asarray(o0) - np.asarray(o1)).max() < 0.02


def test_optimized_variant_still_trains():
    from repro.configs.variants import optimized

    cfg = optimized(reduced(get_config("granite-moe-3b-a800m")))
    model = build_model(cfg)
    params = model.init(KEY)
    loss, _ = jax.jit(model.loss_fn)(params, make_batch(cfg, TRAIN))
    assert np.isfinite(float(loss))
