"""Roofline report: reads artifacts/dryrun/*.json into the §Roofline table,
plus the analytic HBM-traffic model of the BPMF sweep engines (predicted
vs measured fused-engine reduction).

For each (arch x shape x mesh) cell: the three terms (compute / memory /
collective, seconds), the dominant bottleneck, MODEL_FLOPS / HLO_FLOPS
(useful-compute ratio), and a one-line what-would-move-the-needle note.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import REPO_ROOT, csv_row

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

NOTES = {
    "compute_s": "raise MXU utilization (larger per-chip tiles, fuse small ops)",
    "memory_s": "cut HBM traffic (flash attention, fewer remat passes, fused loss)",
    "collective_s": "cut ICI bytes (reduce FSDP regathers, overlap grad reduce)",
}


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            continue
        if mesh and not f.stem.endswith(mesh):
            continue
        r["_cell"] = f.stem
        recs.append(r)
    return recs


def markdown_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | useful flops | note |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in recs:
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| {t['dominant'].replace('_s','')} | {t['roofline_fraction']:.3f} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {NOTES[t['dominant']]} |"
        )
    return "\n".join(lines)


def sweep_traffic_model(plan, k: int, *, bf16_gather: bool = False) -> dict:
    """Analytic HBM bytes of one half-sweep's statistics pass, per engine.

    The counterpart-factor gather is the dominant roofline term: the
    two-step path writes the gathered (rows, W, K) block to HBM and reads
    it back (2x), then materializes the row-level (rows, K, K) precision
    intermediate for a separate segment reduction (write + read). The fused
    engine streams the gathered rows through VMEM exactly once (halved
    again by a bf16 gather) and reduces segments in-kernel, so only the
    per-segment outputs touch HBM.
    """
    f32 = 4
    gdtype = 2 if bf16_gather else f32
    lanes = sum(b.rows * b.width for b in plan.buckets)     # padded (row, w) slots
    segs = sum(b.n_segments for b in plan.buckets)
    gathered = lanes * k * f32
    row_level = sum(b.rows for b in plan.buckets) * k * k * f32
    seg_out = segs * (k * k + k) * f32
    scatter = plan.n_items * (k * k + k) * f32              # per-item buffers
    two_step = 2 * gathered + 2 * row_level + seg_out + 2 * scatter
    fused = lanes * k * gdtype + seg_out + scatter
    return {
        "gathered_bytes": gathered,
        "row_level_bytes": row_level,
        "two_step_bytes": two_step,
        "fused_bytes": fused,
        "predicted_reduction": two_step / max(fused, 1),
    }


def sweep_rows() -> list[str]:
    """Predicted fused-engine traffic reduction for the fig4 plan, next to
    the measured speedup from the last BENCH_sweep.json run (CPU measures
    wall time, so the two agree only in trend off-TPU)."""
    from repro.core.buckets import plan_buckets
    from repro.data import chembl_like, train_test_split
    from repro.data.sparse import csr_from_coo

    ratings, _, _ = chembl_like(scale=0.004, seed=0)
    train, _ = train_test_split(ratings, 0.05, seed=1)
    k = 32
    c = train.centered()
    m, n = train.shape
    indptr, idx, vals = csr_from_coo(c.rows, c.cols, c.vals, m)
    plan = plan_buckets(indptr, idx, vals, m, n, (8, 32, 128, 512))
    rows = []
    for bf16 in (False, True):
        t = sweep_traffic_model(plan, k, bf16_gather=bf16)
        tag = "bf16" if bf16 else "f32"
        rows.append(csv_row(
            f"roofline_sweep_fused_{tag}", 0.0,
            f"two_step_MB={t['two_step_bytes'] / 1e6:.2f};"
            f"fused_MB={t['fused_bytes'] / 1e6:.2f};"
            f"predicted_reduction={t['predicted_reduction']:.2f}x",
        ))
    bench = REPO_ROOT / "BENCH_sweep.json"
    if bench.exists():
        data = json.loads(bench.read_text())
        sp = {r["name"]: r["derived"] for r in data.get("rows", [])
              if r["name"].endswith("_speedup")}
        for name, derived in sorted(sp.items()):
            rows.append(csv_row(f"roofline_{name}_measured", 0.0, derived))
    else:
        rows.append(csv_row(
            "roofline_sweep_measured", 0.0,
            "run benchmarks/sweep_throughput.py for measured speedups",
        ))
    return rows


def main() -> list[str]:
    rows = sweep_rows()
    recs = load_records("single")
    if not recs:
        rows.append(csv_row("roofline_missing_artifacts", 0.0, "run launch/dryrun first"))
        return rows
    for r in recs:
        t = r["roofline"]
        rows.append(csv_row(
            f"roofline_{r['arch']}_{r['shape']}",
            t["step_lower_bound_s"] * 1e6,
            f"dom={t['dominant'].replace('_s','')};frac={t['roofline_fraction']:.3f};"
            f"useful={r['useful_flops_ratio']:.3f}",
        ))
    worst = min(recs, key=lambda r: r["roofline"]["roofline_fraction"])
    most_coll = max(recs, key=lambda r: r["roofline"]["collective_s"]
                    / max(r["roofline"]["step_lower_bound_s"], 1e-12))
    rows.append(csv_row("roofline_worst_cell", 0.0,
                        f"{worst['arch']}:{worst['shape']}"))
    rows.append(csv_row("roofline_most_collective_bound", 0.0,
                        f"{most_coll['arch']}:{most_coll['shape']}"))
    return rows


if __name__ == "__main__":
    print(markdown_table(load_records()))
