"""Roofline report: reads artifacts/dryrun/*.json into the §Roofline table.

For each (arch x shape x mesh) cell: the three terms (compute / memory /
collective, seconds), the dominant bottleneck, MODEL_FLOPS / HLO_FLOPS
(useful-compute ratio), and a one-line what-would-move-the-needle note.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_row

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

NOTES = {
    "compute_s": "raise MXU utilization (larger per-chip tiles, fuse small ops)",
    "memory_s": "cut HBM traffic (flash attention, fewer remat passes, fused loss)",
    "collective_s": "cut ICI bytes (reduce FSDP regathers, overlap grad reduce)",
}


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(ART.glob("*.json")):
        r = json.loads(f.read_text())
        if not r.get("ok"):
            continue
        if mesh and not f.stem.endswith(mesh):
            continue
        r["_cell"] = f.stem
        recs.append(r)
    return recs


def markdown_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | useful flops | note |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in recs:
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} | {t['collective_s']:.4f} "
            f"| {t['dominant'].replace('_s','')} | {t['roofline_fraction']:.3f} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {NOTES[t['dominant']]} |"
        )
    return "\n".join(lines)


def main() -> list[str]:
    rows = []
    recs = load_records("single")
    if not recs:
        rows.append(csv_row("roofline_missing_artifacts", 0.0, "run launch/dryrun first"))
        return rows
    for r in recs:
        t = r["roofline"]
        rows.append(csv_row(
            f"roofline_{r['arch']}_{r['shape']}",
            t["step_lower_bound_s"] * 1e6,
            f"dom={t['dominant'].replace('_s','')};frac={t['roofline_fraction']:.3f};"
            f"useful={r['useful_flops_ratio']:.3f}",
        ))
    worst = min(recs, key=lambda r: r["roofline"]["roofline_fraction"])
    most_coll = max(recs, key=lambda r: r["roofline"]["collective_s"]
                    / max(r["roofline"]["step_lower_bound_s"], 1e-12))
    rows.append(csv_row("roofline_worst_cell", 0.0,
                        f"{worst['arch']}:{worst['shape']}"))
    rows.append(csv_row("roofline_most_collective_bound", 0.0,
                        f"{most_coll['arch']}:{most_coll['shape']}"))
    return rows


if __name__ == "__main__":
    print(markdown_table(load_records()))
