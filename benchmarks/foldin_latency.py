"""Cold-start fold-in latency: fused (S*B) batched solve vs per-draw loop.

    PYTHONPATH=src python benchmarks/foldin_latency.py [--smoke]

The seed fold-in ran a Python loop of S separate conditional solves and
rebuilt a bucket plan per request batch. The serving path now (a) fuses the
S solves into one batched (S*B, K, K) precision assembly + Cholesky solve
and (b) caches plan *schemas* by quantized rating-count profile, so
same-profile batches reuse every compiled executable.

This benchmark reports, per batch served end-to-end (plan + stats + solve):

  foldin_loop    the seed per-retained-draw loop (fold_in_loop)
  foldin_fused   the fused solve with a warm FoldInPlanCache

and then proves cache stability: a stream of *distinct* batches drawn from
one degree profile is served with zero new traces of the fused solve and a
cache hit per batch (the same flatness tests/test_foldin.py asserts).

--smoke shrinks the shapes so the CI docs-examples job can run it quickly.
"""
from __future__ import annotations

import argparse

import numpy as np

try:
    from benchmarks.common import csv_row, time_fn
except ModuleNotFoundError:  # invoked as a file: python benchmarks/<name>.py
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import csv_row, time_fn

from repro.data.sparse import SparseRatings
from repro.serve import FoldInPlanCache, PosteriorEnsemble, fold_in, fold_in_loop
from repro.serve import foldin as foldin_mod

S = 16            # retained draws — the acceptance point for the speedup
TOPK = 10


def synthetic_ensemble(s: int, m: int, n: int, k: int, rng) -> PosteriorEnsemble:
    def spd():
        a = rng.normal(size=(k, k)).astype(np.float32) / np.sqrt(k)
        return a @ a.T + 2.0 * np.eye(k, dtype=np.float32)

    return PosteriorEnsemble.from_arrays(
        rng.normal(size=(s, m, k)).astype(np.float32),
        rng.normal(size=(s, n, k)).astype(np.float32),
        hyper_u_mu=rng.normal(size=(s, k)).astype(np.float32) * 0.1,
        hyper_u_lam=np.stack([spd() for _ in range(s)]),
        hyper_v_mu=np.zeros((s, k), np.float32),
        hyper_v_lam=np.stack([np.eye(k, dtype=np.float32)] * s),
        global_mean=3.5,
        alpha=2.0,
        steps=list(range(s)),
    )


def cold_batch(degrees: np.ndarray, n_items: int, seed: int) -> SparseRatings:
    """One request batch with the given per-user rating counts."""
    r = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for u, d in enumerate(degrees):
        rows.extend([u] * int(d))
        cols.extend(r.choice(n_items, int(d), replace=False).tolist())
        vals.extend(r.normal(3.5, 1.0, int(d)).tolist())
    return SparseRatings(
        rows=np.asarray(rows, np.int32), cols=np.asarray(cols, np.int32),
        vals=np.asarray(vals, np.float32), shape=(len(degrees), n_items),
    )


def main(smoke: bool = False) -> list[str]:
    if smoke:
        m, n, k, batch, deg = 400, 600, 8, 8, (4, 24)
        iters, stream = 3, 6
    else:
        m, n, k, batch, deg = 2000, 4000, 32, 32, (8, 64)
        iters, stream = 5, 16
    rng = np.random.default_rng(0)
    ens = synthetic_ensemble(S, m, n, k, rng)
    cache = FoldInPlanCache()
    degrees = rng.integers(*deg, size=batch)
    ratings = cold_batch(degrees, n, seed=1)
    print(f"# S={S} draws, batch={batch} cold users, {n} items, k={k}, "
          f"degrees in {deg}{' (smoke)' if smoke else ''}")

    t_loop = time_fn(
        lambda: fold_in_loop(None, ratings, ens, sample=False),
        warmup=1, iters=iters,
    )
    t_fused = time_fn(
        lambda: fold_in(None, ratings, ens, sample=False, plan_cache=cache),
        warmup=1, iters=iters,
    )
    rows = [
        csv_row("foldin_loop", t_loop * 1e6, f"s={S} per-draw python loop"),
        csv_row("foldin_fused", t_fused * 1e6,
                f"s={S} speedup={t_loop / t_fused:.1f}x"),
    ]

    # repeated same-profile batches (same rating counts, fresh items and
    # values): every one must be a plan-cache hit with zero new traces
    hits0, traces0 = cache.hits, foldin_mod.trace_count()
    for i in range(stream):
        fold_in(None, cold_batch(degrees, n, seed=100 + i), ens,
                sample=False, plan_cache=cache)
    same_traces = foldin_mod.trace_count() - traces0
    same_hits = (cache.hits - hits0) / stream
    rows.append(csv_row(
        "foldin_cache_same_profile", 0.0,
        f"batches={stream} hit_rate={same_hits:.2f} new_traces={same_traces}",
    ))

    # drifting profiles: fresh degree draws per batch — quantization still
    # collapses most of them onto already-compiled shape families
    hits0, traces0 = cache.hits, foldin_mod.trace_count()
    for i in range(stream):
        drift = np.random.default_rng(200 + i).integers(*deg, size=batch)
        fold_in(None, cold_batch(drift, n, seed=300 + i), ens,
                sample=False, plan_cache=cache)
    drift_traces = foldin_mod.trace_count() - traces0
    drift_hits = (cache.hits - hits0) / stream
    rows.append(csv_row(
        "foldin_cache_drifting_profile", 0.0,
        f"batches={stream} hit_rate={drift_hits:.2f} new_traces={drift_traces}",
    ))
    for row in rows:
        print(row)
    print(f"# fused is {t_loop / t_fused:.1f}x faster than the seed loop; "
          f"{stream} repeated same-profile batches -> {same_traces} new "
          f"traces; {stream} drifting-profile batches -> {drift_traces} "
          f"(cache {cache.stats()})")
    if t_loop / t_fused < 3.0:
        print("# WARNING: fused speedup below the 3x acceptance target")
    if same_traces:
        print("# WARNING: same-profile stream was not trace-flat")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
