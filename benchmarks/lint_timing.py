"""repro-lint analyzer throughput over the live tree.

The lint job is blocking in CI, so its cost is part of every push's
latency budget — this suite tracks it the same way the kernel suites
track theirs. One full `analyze_paths` pass over ``src`` and ``tests``
(all four rule passes), timed end to end including parsing:

    repro_lint,<us per file>,files=<n>;findings=<m>;total_ms=<t>

Smoke mode runs one pass (it is already ~1 s); the full mode runs three
and reports the best, so the row is stable against filesystem-cache noise.
"""
from __future__ import annotations

import time
from pathlib import Path

from benchmarks.common import REPO_ROOT


def main(smoke: bool = False):
    from repro.analysis import analyze_paths
    from repro.analysis.cli import discover

    paths = [REPO_ROOT / "src", REPO_ROOT / "tests"]
    n_files = len(discover(paths))
    reps = 1 if smoke else 3
    best_s = float("inf")
    findings: list = []
    for _ in range(reps):
        t0 = time.perf_counter()
        findings, errors = analyze_paths(paths, REPO_ROOT)
        best_s = min(best_s, time.perf_counter() - t0)
        if errors:
            raise RuntimeError(f"repro-lint parse errors: {errors}")
    us_per_file = best_s * 1e6 / max(n_files, 1)
    derived = (f"files={n_files};findings={len(findings)};"
               f"total_ms={best_s * 1e3:.1f}")
    yield f"repro_lint,{us_per_file:.1f},{derived}"


if __name__ == "__main__":
    for row in main():
        print(row)
