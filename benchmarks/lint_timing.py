"""repro-lint analyzer throughput over the live tree.

The lint job is blocking in CI, so its cost is part of every push's
latency budget — this suite tracks it the same way the kernel suites
track theirs. One full `analyze_paths` pass over ``src`` and ``tests``
(all rule passes), timed end to end including parsing:

    repro_lint,<us per file>,files=<n>;findings=<m>;total_ms=<t>

plus one row per pass module (its rule subset run in isolation — parsing
is repeated per row, so the per-pass total_ms columns sum to more than
the combined row; the point is catching a single pass going quadratic):

    repro_lint_<pass>,<us per file>,files=<n>;findings=<m>;total_ms=<t>

Smoke mode runs one rep per row (already ~1 s each); the full mode runs
three and reports the best, so rows are stable against cache noise.
"""
from __future__ import annotations

import time
from pathlib import Path

from benchmarks.common import REPO_ROOT


def _timed_row(name: str, paths, rules, reps: int) -> str:
    from repro.analysis import analyze_paths
    from repro.analysis.cli import discover

    n_files = len(discover(paths))
    best_s = float("inf")
    findings: list = []
    for _ in range(reps):
        t0 = time.perf_counter()
        findings, errors = analyze_paths(paths, REPO_ROOT, rules=rules)
        best_s = min(best_s, time.perf_counter() - t0)
        if errors:
            raise RuntimeError(f"repro-lint parse errors: {errors}")
    us_per_file = best_s * 1e6 / max(n_files, 1)
    derived = (f"files={n_files};findings={len(findings)};"
               f"total_ms={best_s * 1e3:.1f}")
    return f"{name},{us_per_file:.1f},{derived}"


def main(smoke: bool = False):
    from repro.analysis import ALL_RULES
    from repro.analysis.cli import PASSES

    paths = [REPO_ROOT / "src", REPO_ROOT / "tests"]
    reps = 1 if smoke else 3
    yield _timed_row("repro_lint", paths, frozenset(ALL_RULES), reps)
    for pass_mod in PASSES:
        name = pass_mod.__name__.rsplit(".", 1)[-1]
        yield _timed_row(f"repro_lint_{name}", paths,
                         frozenset(pass_mod.RULES), reps)


if __name__ == "__main__":
    for row in main():
        print(row)
