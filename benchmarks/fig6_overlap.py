"""Paper Fig 6: communication/computation overlap, from the compiled HLO.

Without real hardware, overlap is a *structural* property of the schedule:
a collective overlaps compute iff its start has no data dependence on the
compute issued beside it. We lower both samplers on an 8-way mesh and
compare:

  - collective op mix: the ring issues P collective-permutes of one block
    each (pipelinable); the sync version one bulk all-gather (blocking);
  - bytes on the wire per sweep;
  - overlap structure: in the ring's scanned body the permute's operand is
    the *incoming* block, not this step's syrk output -> the DAG admits
    full comm/compute overlap (the paper's "both" region), while the
    all-gather dominates a serial prologue.

Reported: collective bytes, counts, and the dependence check, per mode.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import csv_row

SRC = str(Path(__file__).resolve().parents[1] / "src")

_WORKER = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys, json, re
sys.path.insert(0, {src!r})
import jax
from repro.data import chembl_like, train_test_split
from repro.core.distributed import DistributedBPMF
from repro.launch.hlo_analysis import HloCostModel


def in_loop_permute(txt):
    # dependence check: a collective-permute INSIDE a while body is the
    # pipelined exchange (one block forwarded per scan step, overlappable
    # with that step's syrk); a bulk all-gather sits in straight-line code.
    # Parse the computations named as `body=` of some while op and look for
    # the permute inside those blocks only.
    bodies = set(re.findall(r"body=%?([\w.\-]+)", txt))
    cur = None
    found = False
    for line in txt.splitlines():
        ls = line.rstrip()
        if not line[:1].isspace() and ls.endswith("{{") and "(" in ls:
            # computation header: `%name (params...) -> type {{` (or ENTRY)
            tok = ls.split()[1] if ls.startswith("ENTRY") else ls.split()[0]
            cur = tok.lstrip("%").split("(")[0]
        elif ls == "}}":
            cur = None
        elif " collective-permute(" in line and cur in bodies:
            found = True
    return found


ratings, _, _ = chembl_like(scale=0.002, seed=0)
train, test = train_test_split(ratings, 0.05, seed=1)
out = {{}}
for mode in ("ring", "allgather", "async"):
    s = DistributedBPMF(train, test, k=32, alpha=1.5, mode=mode, width=32)
    st = s.init(0)
    lowered = s._sweep.lower(st)
    txt = lowered.compile().as_text()
    res = HloCostModel(txt).analyze()
    out[mode] = {{
        "collective_bytes": res["collective_bytes"],
        "collective_counts": res["collective_counts"],
        "flops": res["flops"],
        "in_loop_permute": in_loop_permute(txt),
    }}
print(json.dumps(out))
"""


def main() -> list[str]:
    code = _WORKER.format(src=SRC)
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
    )
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # structural gate: the ring (and the fused async ring) MUST schedule its
    # permutes inside the scanned while body — that dependence structure is
    # the whole overlap claim. The bulk all-gather must not.
    assert out["ring"]["in_loop_permute"], (
        "ring mode lost its pipelined collective-permute (no permute found "
        "inside a while body in the compiled HLO)"
    )
    assert out["async"]["in_loop_permute"], (
        "async mode lost its pipelined collective-permute"
    )
    rows = []
    for mode, d in out.items():
        total = sum(d["collective_bytes"].values())
        counts = {k: v for k, v in d["collective_counts"].items() if v}
        rows.append(csv_row(
            f"fig6_{mode}_collectives", 0.0,
            f"bytes={total};counts={counts};flops={d['flops']:.3g};"
            f"in_loop_permute={d['in_loop_permute']}",
        ))
    ring = sum(out["ring"]["collective_bytes"].values())
    sync = sum(out["allgather"]["collective_bytes"].values())
    rows.append(csv_row(
        "fig6_ring_vs_sync_bytes_ratio", 0.0, f"{ring / max(sync, 1):.2f}"
    ))
    rows.append(csv_row(
        "fig6_ring_permutes_pipelined", 0.0,
        f"{out['ring']['collective_counts'].get('collective-permute', 0)}",
    ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
