"""BPMF serving throughput: queries/sec and latency vs request batch size.

    PYTHONPATH=src python benchmarks/serve_topn.py

Scores a synthetic ensemble (no training needed — serving cost depends only
on shapes) for several micro-batch sizes and reports queries/sec plus
p50/p99 per-request latency. Larger batches amortise dispatch overhead at
the cost of per-request latency — the same trade the LM decode path makes —
so this table is the sizing input for the frontend's `max_batch`.

Two engines per batch size:
  xla      jnp matmul + lax.top_k, XLA-compiled — the CPU serving number
  kernel   the Pallas streaming top-k in interpret mode — correctness path
           on CPU (interpret mode is not a speed claim; on TPU the kernel
           IS the serving path and never materialises the (B, N) scores)
"""
from __future__ import annotations

import time

import jax
import numpy as np
import jax.numpy as jnp

try:
    from benchmarks.common import csv_row
except ModuleNotFoundError:  # invoked as a file: python benchmarks/<name>.py
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import csv_row

from repro.kernels import ops, ref

BATCH_SIZES = (8, 32, 128)
N_ITEMS = 20_000
N_SAMPLES = 8
K = 16
TOPK = 10
ITERS = 30


def _measure(fn, u, v, iters: int) -> tuple[float, float]:
    out = fn(u, v, TOPK)
    jax.block_until_ready(out)
    lat = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(u, v, TOPK))
        lat.append(time.perf_counter() - t0)
    lat = np.asarray(lat)
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def main() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    sk = N_SAMPLES * K  # flattened ensemble contraction axis (S*K)
    v_flat = jnp.asarray(rng.normal(size=(N_ITEMS, sk)), jnp.float32)
    xla_topn = jax.jit(ref.topn_scores_ref, static_argnums=2)
    print(f"# catalogue {N_ITEMS} items, ensemble S={N_SAMPLES} k={K} "
          f"(contraction {sk}), topk={TOPK}")
    for batch in BATCH_SIZES:
        u = jnp.asarray(rng.normal(size=(batch, sk)), jnp.float32)
        p50, p99 = _measure(xla_topn, u, v_flat, ITERS)
        row = csv_row(
            f"serve_topn_xla_b{batch}", p50 * 1e6,
            f"qps={batch/p50:,.0f} p50_ms={p50*1e3:.2f} p99_ms={p99*1e3:.2f}",
        )
        print(row)
        rows.append(row)
    # kernel correctness path, one shape (interpret mode is slow on CPU)
    u = jnp.asarray(rng.normal(size=(8, sk)), jnp.float32)
    p50, p99 = _measure(ops.topn_scores, u, v_flat, iters=3)
    row = csv_row(
        "serve_topn_kernel_b8", p50 * 1e6,
        f"qps={8/p50:,.0f} p50_ms={p50*1e3:.2f} interpret=cpu",
    )
    print(row)
    rows.append(row)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
