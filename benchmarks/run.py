"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes each suite's rows
as a machine-readable ``BENCH_<suite>.json`` artifact (same records) so the
perf trajectory is comparable across PRs. ``--smoke`` runs a fast subset
(reduced iteration counts) and appends one compact line per invocation to
the COMMITTED ``BENCH_history.jsonl`` — the BENCH_*.json artifacts are
gitignored, so the history file is what carries the trajectory in git.
Figures:
  fig4   multicore updates/sec (engine comparison + load-balance stats)
  fig5   distributed strong scaling, ring (async) vs allgather (sync)
  fig6   comm/compute overlap structure from compiled HLO
  rmse   accuracy parity across all samplers + ALS baseline (Sec 5.2 / 6)
  rmse_wallclock  minibatch SGLD vs fused Gibbs: RMSE-vs-wallclock curves,
         equal-budget gate at the exact engine's floor cost, flat-iteration
         study (per-step cost vs dataset size)
  roofline  per-(arch x shape) dry-run roofline summary
  serve  BPMF top-N serving qps + latency vs request batch size
  serve_cluster  multi-host tier: qps vs n_hosts, merge overhead, barrier
  publish  publish-to-fresh-recommendation latency, push channel vs disk poll
  foldin  cold-start fold-in: fused (S*B) solve vs per-draw loop, plan cache
  sweep  training-sweep engines: reference vs restructured vs fused
  lint   repro-lint analyzer throughput over the live tree (the CI gate)
"""
from __future__ import annotations

import sys
import traceback


def main(argv: list[str] | None = None) -> None:
    import argparse

    try:
        from benchmarks import fig4_multicore, fig5_distributed, fig6_overlap
    except ImportError:  # script-mode (`python benchmarks/run.py`): put repo root on path
        import pathlib

        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
        from benchmarks import fig4_multicore, fig5_distributed, fig6_overlap
    from benchmarks import foldin_latency, lint_timing, publish_latency
    from benchmarks import rmse_table, rmse_wallclock, roofline
    from benchmarks import serve_cluster, serve_topn, sweep_throughput
    from benchmarks.common import append_history_row, parse_csv_row, write_bench_json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("suite", nargs="?", default=None,
                    help="run only this suite (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset with reduced iters; appends one "
                         "compact row to the committed BENCH_history.jsonl")
    args = ap.parse_args(argv)

    # sweep runs before roofline: roofline's measured-vs-predicted rows
    # read the BENCH_sweep.json the sweep suite just wrote. Suites flagged
    # self_publish write their own (richer) BENCH_<suite>.json — the
    # driver must not overwrite it with a plain copy. smoke_fn, when set,
    # is the reduced-cost variant --smoke runs; suites without one are
    # skipped in smoke mode.
    suites = [
        ("fig4", fig4_multicore.main, False,
         lambda: fig4_multicore.main(smoke=True)),
        ("fig5", fig5_distributed.main, False,
         lambda: fig5_distributed.main(smoke=True)),
        ("fig6", fig6_overlap.main, False, None),
        ("rmse", rmse_table.main, False, None),
        ("rmse_wallclock", rmse_wallclock.main, True,
         lambda: rmse_wallclock.main(smoke=True)),
        ("sweep", sweep_throughput.main, True,
         lambda: sweep_throughput.main(smoke=True)),
        ("roofline", roofline.main, False, None),
        ("serve", serve_topn.main, False, None),
        ("serve_cluster", serve_cluster.main, True,
         lambda: serve_cluster.main(smoke=True)),
        ("publish", publish_latency.main, False, None),
        ("foldin", foldin_latency.main, False,
         lambda: foldin_latency.main(smoke=True)),
        ("lint", lint_timing.main, False,
         lambda: lint_timing.main(smoke=True)),
    ]
    print("name,us_per_call,derived")
    failures = 0
    history: dict[str, dict] = {}
    for name, fn, self_publish, smoke_fn in suites:
        if args.suite and name != args.suite:
            continue
        if args.smoke:
            if smoke_fn is None:
                continue
            fn = smoke_fn
        try:
            rows = list(fn())
            for row in rows:
                print(row)
            if not self_publish:
                write_bench_json(name, rows)
            history[name] = {
                r["name"]: [r["us_per_call"], r["derived"]]
                for r in map(parse_csv_row, rows)
            }
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if args.smoke and history:
        import subprocess as sp
        import time

        try:
            rev = sp.run(["git", "rev-parse", "--short", "HEAD"],
                         capture_output=True, text=True, timeout=10,
                         ).stdout.strip() or None
        except Exception:  # noqa: BLE001
            rev = None
        path = append_history_row({
            "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "rev": rev,
            "suites": history,
        })
        print(f"# appended smoke row -> {path}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
