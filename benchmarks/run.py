"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Figures:
  fig4   multicore updates/sec (engine comparison + load-balance stats)
  fig5   distributed strong scaling, ring (async) vs allgather (sync)
  fig6   comm/compute overlap structure from compiled HLO
  rmse   accuracy parity across all samplers + ALS baseline (Sec 5.2 / 6)
  roofline  per-(arch x shape) dry-run roofline summary
  serve  BPMF top-N serving qps + latency vs request batch size
  publish  publish-to-fresh-recommendation latency, push channel vs disk poll
  foldin  cold-start fold-in: fused (S*B) solve vs per-draw loop, plan cache
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import fig4_multicore, fig5_distributed, fig6_overlap
    from benchmarks import foldin_latency, publish_latency, rmse_table
    from benchmarks import roofline, serve_topn

    suites = [
        ("fig4", fig4_multicore.main),
        ("fig5", fig5_distributed.main),
        ("fig6", fig6_overlap.main),
        ("rmse", rmse_table.main),
        ("roofline", roofline.main),
        ("serve", serve_topn.main),
        ("publish", publish_latency.main),
        ("foldin", foldin_latency.main),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if only and name != only:
            continue
        try:
            for row in fn():
                print(row)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
