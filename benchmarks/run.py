"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes each suite's rows
as a machine-readable ``BENCH_<suite>.json`` artifact (same records) so the
perf trajectory is comparable across PRs. Figures:
  fig4   multicore updates/sec (engine comparison + load-balance stats)
  fig5   distributed strong scaling, ring (async) vs allgather (sync)
  fig6   comm/compute overlap structure from compiled HLO
  rmse   accuracy parity across all samplers + ALS baseline (Sec 5.2 / 6)
  roofline  per-(arch x shape) dry-run roofline summary
  serve  BPMF top-N serving qps + latency vs request batch size
  serve_cluster  multi-host tier: qps vs n_hosts, merge overhead, barrier
  publish  publish-to-fresh-recommendation latency, push channel vs disk poll
  foldin  cold-start fold-in: fused (S*B) solve vs per-draw loop, plan cache
  sweep  training-sweep engines: reference vs restructured vs fused
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import fig4_multicore, fig5_distributed, fig6_overlap
    from benchmarks import foldin_latency, publish_latency, rmse_table
    from benchmarks import roofline, serve_cluster, serve_topn, sweep_throughput
    from benchmarks.common import write_bench_json

    # sweep runs before roofline: roofline's measured-vs-predicted rows
    # read the BENCH_sweep.json the sweep suite just wrote. Suites flagged
    # self_publish write their own (richer) BENCH_<suite>.json — the
    # driver must not overwrite it with a plain copy.
    suites = [
        ("fig4", fig4_multicore.main, False),
        ("fig5", fig5_distributed.main, False),
        ("fig6", fig6_overlap.main, False),
        ("rmse", rmse_table.main, False),
        ("sweep", sweep_throughput.main, True),
        ("roofline", roofline.main, False),
        ("serve", serve_topn.main, False),
        ("serve_cluster", serve_cluster.main, True),
        ("publish", publish_latency.main, False),
        ("foldin", foldin_latency.main, False),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn, self_publish in suites:
        if only and name != only:
            continue
        try:
            rows = list(fn())
            for row in rows:
                print(row)
            if not self_publish:
                write_bench_json(name, rows)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
