"""Paper Sec 5.2 claim: every parallel version reaches the same RMSE.

Runs the four samplers (single-host jnp, single-host Pallas-kernel path,
distributed ring, distributed all-gather — the latter two in an 8-device
subprocess) on the same ChEMBL-like split and reports test RMSE, plus the
ALS baseline (the paper's Sec 6 comparison: BPMF needs no regularization
tuning; ALS gets an untuned lambda).
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import csv_row
from repro.core import ALS, GibbsSampler
from repro.data import chembl_like, train_test_split

SRC = str(Path(__file__).resolve().parents[1] / "src")
N_SWEEPS = 20


def main() -> list[str]:
    rows = []
    ratings, _, _ = chembl_like(scale=0.003, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)

    s = GibbsSampler(train, test, k=32, alpha=4.0, burn_in=6)
    st = s.run(N_SWEEPS, seed=0)
    rows.append(csv_row("rmse_gibbs_single", 0.0, f"{s.rmse(st):.4f}"))

    sk = GibbsSampler(train, test, k=32, alpha=4.0, burn_in=6, use_kernel=True)
    stk = sk.run(N_SWEEPS, seed=0)
    rows.append(csv_row("rmse_gibbs_pallas", 0.0, f"{sk.rmse(stk):.4f}"))

    code = f"""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import sys, json
    sys.path.insert(0, {SRC!r})
    from repro.data import chembl_like, train_test_split
    from repro.core.distributed import DistributedBPMF
    ratings, _, _ = chembl_like(scale=0.003, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)
    out = {{}}
    for mode in ("ring", "allgather"):
        s = DistributedBPMF(train, test, k=32, alpha=4.0, mode=mode)
        st = s.run({N_SWEEPS}, seed=0)
        out[mode] = s.rmse(st)
    print(json.dumps(out))
    """
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=1200,
    )
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    dist = json.loads(res.stdout.strip().splitlines()[-1])
    rows.append(csv_row("rmse_gibbs_ring_8dev", 0.0, f"{dist['ring']:.4f}"))
    rows.append(csv_row("rmse_gibbs_allgather_8dev", 0.0, f"{dist['allgather']:.4f}"))

    als = ALS(train, test, k=32, lam_reg=0.3)
    sta = als.run(12)
    rows.append(csv_row("rmse_als_untuned", 0.0, f"{als.rmse(sta):.4f}"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
