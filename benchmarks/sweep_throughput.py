"""Gibbs training-sweep throughput: updates/sec per sweep engine.

    PYTHONPATH=src python benchmarks/sweep_throughput.py [--smoke]

The paper's headline numbers are *training* throughput (Fig 4 multicore
updates/sec). This benchmark pins the repo's own trajectory for the
single-host sweep across the three engine generations:

  reference   the seed data flow: einsum row statistics, per-bucket
              segment_sum + two full-size scatter-add passes, and the
              LAPACK-style 3-triangular-solve sampler.
  einsum      the restructured flow (default engine): identical statistics
              written once into their seg_item_ids slots (no full-size zero
              buffers, one scatter per output) and the batch-vectorized
              substitution solver.
  fused       the restructured flow with statistics from the fused
              gather→syrk→segment-reduce engine (`ops.gather_syrk_seg`:
              the Pallas kernel on TPU, the fused-semantics jnp path here).

Updates/sec counts one resampled entity (user or movie) per sweep, the
paper's Fig 4 metric. Engines are also cross-checked: one sweep from a
shared key must produce the same samples to fp32 tolerance.

Emits machine-readable BENCH_sweep.json (suite rows + speedup summary) so
the perf trajectory finally has data; `--smoke` shrinks shapes for the CI
job. The two-step Pallas `kernel` engine is measured by fig4 in interpret
mode (a correctness path, not a speed claim) and is skipped here.
"""
from __future__ import annotations

import argparse

import numpy as np

try:
    from benchmarks.common import csv_row, time_fn, write_bench_json
except ModuleNotFoundError:  # invoked as a file: python benchmarks/<name>.py
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import csv_row, time_fn, write_bench_json

from repro.core import GibbsSampler
from repro.data import chembl_like, train_test_split

ENGINES = ("reference", "einsum", "fused")
TARGET_SPEEDUP = 1.5   # acceptance floor: restructured/fused vs reference


def measure_engine(train, widths, engine, k, iters):
    s = GibbsSampler(train, None, k=k, alpha=1.5, widths=widths, engine=engine)
    state = s.init(0)
    sweep = s._sweep          # the sampler's own jitted sweep (run() path)
    t = time_fn(sweep, state, warmup=1, iters=iters)
    n_updates = s.m + s.n
    out = sweep(state)
    return t, n_updates / t, (np.asarray(out.u), np.asarray(out.v))


def main(smoke: bool = False) -> list[str]:
    # k=32 everywhere: at toy K the XLA batched solve never leaves its
    # vectorized small-matrix path and the engine comparison is meaningless
    if smoke:
        scale, k, iters = 0.004, 32, 2
        profiles = [(8, 32, 128, 512)]
    else:
        scale, k, iters = 0.004, 32, 5
        profiles = [(8, 32, 128, 512), (16, 128), (32,)]
    ratings, _, _ = chembl_like(scale=scale, seed=0)
    train, _ = train_test_split(ratings, 0.05, seed=1)
    print(f"# m={train.shape[0]} n={train.shape[1]} nnz={train.nnz} k={k}"
          f"{' (smoke)' if smoke else ''}")

    rows = []
    speedups = {}
    for widths in profiles:
        tag = "x".join(map(str, widths))
        times = {}
        samples = {}
        for engine in ENGINES:
            t, ups, uv = measure_engine(train, widths, engine, k, iters)
            times[engine] = t
            samples[engine] = uv
            rows.append(csv_row(
                f"sweep_{tag}_{engine}", t * 1e6, f"updates_per_s={ups:.0f}"
            ))
        # engine equivalence from the shared key (fp32 tolerance)
        dev = max(
            float(np.abs(samples[e][i] - samples["reference"][i]).max())
            for e in ENGINES[1:] for i in (0, 1)
        )
        rows.append(csv_row(f"sweep_{tag}_max_sample_dev", 0.0, f"{dev:.2e}"))
        for engine in ENGINES[1:]:
            sp = times["reference"] / times[engine]
            speedups[f"{tag}_{engine}"] = round(sp, 3)
            rows.append(csv_row(
                f"sweep_{tag}_{engine}_speedup", 0.0, f"{sp:.2f}x"
            ))
        if widths == (8, 32, 128, 512):
            for engine in ENGINES[1:]:
                if times["reference"] / times[engine] < TARGET_SPEEDUP:
                    print(f"# WARNING: {engine} speedup below the "
                          f"{TARGET_SPEEDUP}x acceptance target at {tag}")
            if dev > 5e-3:
                print(f"# WARNING: engine sample deviation {dev:.2e} above "
                      "fp32 tolerance")

    path = write_bench_json("sweep", rows, extra={"speedups": speedups})
    print(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in main(smoke=args.smoke):
        print(row)
