"""Multi-host serving tier: throughput, merge overhead, barrier freshness.

    PYTHONPATH=src python benchmarks/serve_cluster.py [--smoke]

Three questions about serve/cluster.py, answered per host count:

* throughput — queries/sec through the full scatter/gather path
  (per-host kernel scoring + candidate exchange + coordinator merge) as
  n_hosts grows over a fixed catalogue. On CPU every simulated host shares
  one device and the Pallas kernel runs in interpret mode, so this is the
  *structural* cost of the tier (more, smaller kernel launches + the
  merge), not a hardware scaling claim — on a real pod the per-host
  scoring runs compiled on disjoint chips.

* merge overhead — wall time of the coordinator's stable `_merge_topk`
  over the gathered (B, sum k_eff) candidate matrix. The exchange is
  bounded by O(hosts * topk) candidates per request row regardless of
  catalogue size; the reported width column makes the linear growth (and
  its small absolute cost next to scoring) visible.

* publish -> all-shards-fresh — latency from a channel publish to the
  epoch barrier committing (a quorum of every shard staged, coordinator
  flipped): the cross-host analogue of benchmarks/publish_latency.py's
  swap clock.

* degraded mode — qps with replicas=2 and one host killed: the price of
  routing every affected request around the dead replica (health check +
  failover pick), vs the same replicated tier fully healthy.

Writes BENCH_serve_cluster.json (self-published: keeps the host-count
sweep as structured `scaling` records alongside the flat rows); under
`benchmarks/run.py --smoke` the rows — including the degraded-mode ones —
land in the committed BENCH_history.jsonl.
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

try:
    from benchmarks.common import csv_row, time_fn, write_bench_json
except ModuleNotFoundError:  # invoked as a file: python benchmarks/<name>.py
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import csv_row, time_fn, write_bench_json

from repro.checkpoint import as_retained_sample
from repro.serve import ClusterCoordinator, PosteriorEnsemble, PublicationChannel
from repro.serve.cluster import _merge_topk


def _make_ensemble(n_users: int, n_items: int, s: int, k: int,
                   *, base_step: int = 100) -> PosteriorEnsemble:
    rng = np.random.default_rng(0)
    draws = []
    for i in range(s):
        draws.append(as_retained_sample(base_step + i, {
            "u": rng.normal(size=(n_users, k)).astype(np.float32),
            "v": rng.normal(size=(n_items, k)).astype(np.float32),
            "hyper_u_mu": np.zeros(k, np.float32),
            "hyper_u_lam": np.eye(k, dtype=np.float32),
            "hyper_v_mu": np.zeros(k, np.float32),
            "hyper_v_lam": np.eye(k, dtype=np.float32),
            "global_mean": np.float32(0.0),
            "alpha": np.float32(2.0),
        }))
    return PosteriorEnsemble(tuple(draws))


def _sample_dict(s) -> dict:
    return {
        "u": s.u, "v": s.v,
        "hyper_u_mu": s.hyper_u_mu, "hyper_u_lam": s.hyper_u_lam,
        "hyper_v_mu": s.hyper_v_mu, "hyper_v_lam": s.hyper_v_lam,
        "global_mean": np.float32(s.global_mean),
        "alpha": np.float32(s.alpha),
    }


def main(smoke: bool = False) -> list[str]:
    n_users, n_items = (400, 4000) if smoke else (2000, 20000)
    s, k, topk, batch = 4, 16, 10, 32
    host_counts = (1, 2, 4) if smoke else (1, 2, 4, 8)
    publishes = 3 if smoke else 8
    ensemble = _make_ensemble(n_users, n_items, s, k)
    rng = np.random.default_rng(1)
    users = rng.integers(0, n_users, batch).astype(np.int32)
    rows, scaling = [], []
    print(f"# catalogue {n_items} items, ensemble S={s} k={k}, "
          f"topk={topk}, batch={batch}")

    baseline = None
    for h in host_counts:
        cluster = ClusterCoordinator(ensemble, n_hosts=h)
        sec = time_fn(lambda: cluster.recommend(users, topk), iters=5)
        qps = batch / sec
        # the coordinator-side merge in isolation, on the same candidate
        # widths the serve path gathered: (B, sum min(fetch, shard)) where
        # fetch is the pow2-quantized topk
        fetch = 1 << (topk - 1).bit_length()
        width = sum(min(fetch, int(hi - lo)) for lo, hi in zip(
            np.linspace(0, n_items, h + 1).astype(int)[:-1],
            np.linspace(0, n_items, h + 1).astype(int)[1:]))
        cand_v = jnp.asarray(rng.normal(size=(batch, width)), jnp.float32)
        cand_i = jnp.asarray(
            rng.integers(0, n_items, (batch, width)), jnp.int32)
        merge_s = (time_fn(lambda: _merge_topk(cand_v, cand_i, fetch), iters=5)
                   if h > 1 else 0.0)
        if baseline is None:
            baseline = sec
        row = csv_row(
            f"serve_cluster_h{h}", sec * 1e6,
            f"qps={qps:,.0f} merge_us={merge_s*1e6:.0f} "
            f"cand_width={width} vs_h1={sec/baseline:.2f}x",
        )
        print(row)
        rows.append(row)
        scaling.append({
            "hosts": h, "qps": qps, "merge_us": merge_s * 1e6,
            "cand_width": width, "rel_time_vs_h1": sec / baseline,
        })

    # degraded mode: replicas=2 at the widest host count, one host killed —
    # every request to the dead replica's shard pays the failover pick
    h = host_counts[-1]
    degraded = {}
    cluster = ClusterCoordinator(ensemble, n_hosts=h, replicas=2)
    sec_healthy = time_fn(lambda: cluster.recommend(users, topk), iters=5)
    cluster.health.kill(cluster.hosts[0].host_id)
    cluster.recommend(users, topk)  # settle routing around the dead host
    sec_down = time_fn(lambda: cluster.recommend(users, topk), iters=5)
    for label, sec in (("healthy", sec_healthy), ("1down", sec_down)):
        qps = batch / sec
        degraded[label] = {"qps": qps, "us_per_call": sec * 1e6}
        row = csv_row(
            f"serve_cluster_h{h}r2_{label}", sec * 1e6,
            f"qps={qps:,.0f} replicas=2 "
            f"{'host0 dead, failover-routed' if label == '1down' else 'all hosts live'}",
        )
        print(row)
        rows.append(row)

    # publish -> all-shards-fresh barrier latency at the widest host count
    channel = PublicationChannel(window=s)
    for d in ensemble.samples:
        channel.publish(d.step, _sample_dict(d))
    cluster = ClusterCoordinator(ensemble, n_hosts=h, channel=channel)
    base = ensemble.samples[-1]
    for i in range(publishes):
        channel.publish(base.step + 1 + i, _sample_dict(base))
        if not cluster.wait_epoch(base.step + 1 + i, timeout=60.0):
            raise TimeoutError(f"barrier stuck at epoch {cluster.epoch}")
    cluster.close()
    fresh = cluster.freshness_percentiles()
    row = csv_row(
        f"serve_cluster_fresh_h{h}", fresh["p50"] * 1e6,
        f"publish_to_all_shards_fresh_p50_ms={fresh['p50']*1e3:.1f} "
        f"max_ms={fresh['max']*1e3:.1f} commits={cluster.commits}",
    )
    print(row)
    rows.append(row)

    write_bench_json("serve_cluster", rows, extra={
        "scaling": scaling,
        "merge_model": "O(shards * topk) candidates exchanged per request row",
        "fresh": {"p50_s": fresh["p50"], "max_s": fresh["max"],
                  "hosts": h, "commits": cluster.commits},
        "degraded": {"hosts": h, "replicas": 2, **degraded},
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
