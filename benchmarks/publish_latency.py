"""Publish-to-fresh-recommendation latency: push channel vs disk poll.

    PYTHONPATH=src python benchmarks/publish_latency.py

Measures how long a newly retained Gibbs draw takes to become visible in
served recommendations on the two refresh paths:

  push   PublicationChannel.publish() -> in-memory ensemble build ->
         atomic swap (rebind, compiled top-N executables reused) -> first
         flush() whose results carry the new epoch. No disk in the loop.
  poll   SampleStore.retain() -> async checkpoint write lands on disk ->
         RecommendFrontend.refresh() polled in a tight loop notices the
         new epoch -> ensemble reloaded from disk, V' re-sharded -> first
         fresh flush(). The tight loop is the *floor* for the poll path: a
         production poller adds half its poll interval on average.

Both paths serve the same synthetic ensemble (no training — latency
depends only on shapes) and the same request stream. The push path's
steady-state cost is a buffer swap, so the gap below is the disk write +
directory listing + reload the channel removes from the freshness path.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

try:
    from benchmarks.common import csv_row
except ModuleNotFoundError:  # invoked as a file: python benchmarks/<name>.py
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import csv_row

from repro.checkpoint import SampleStore
from repro.serve import PublicationChannel, RecommendFrontend

M, N, K = 2000, 5000, 16
WINDOW = 4          # steady-state ensemble size (S) on both paths
PUBLISHES = 12      # timed publishes per path
TOPK = 10


def synthetic_sample(step: int, rng) -> dict:
    return {
        "u": rng.normal(size=(M, K)).astype(np.float32),
        "v": rng.normal(size=(N, K)).astype(np.float32),
        "hyper_u_mu": np.zeros(K, np.float32),
        "hyper_u_lam": np.eye(K, dtype=np.float32),
        "hyper_v_mu": np.zeros(K, np.float32),
        "hyper_v_lam": np.eye(K, dtype=np.float32),
        "global_mean": np.float32(0.0),
        "alpha": np.float32(2.0),
    }


def _first_fresh(fe: RecommendFrontend, epoch: int, user_iter) -> float:
    """Serve until a result carries `epoch`; returns that wall time."""
    while True:
        fe.submit(next(user_iter), topk=TOPK)
        results = fe.flush()
        t_now = time.perf_counter()
        if any(r.epoch >= epoch for r in results):
            return t_now
        fe.refresh()  # poll path: notice the new epoch; push path: no-op


def bench_push(rng) -> np.ndarray:
    channel = PublicationChannel(window=WINDOW)
    for s in range(WINDOW):  # pre-fill so S is steady before timing
        channel.publish(s, synthetic_sample(s, rng))
    # subscribe=False: adoption happens on refresh() inside the serve loop,
    # so the measurement includes the full swap, not a thread handoff race
    fe = RecommendFrontend(channel=channel, subscribe=False, max_batch=1)
    users = iter(np.random.default_rng(0).integers(0, M, 10_000).tolist())
    _first_fresh(fe, WINDOW - 1, users)  # warm the kernel at serving shape
    lat = []
    for i in range(PUBLISHES):
        step = WINDOW + i
        t0 = time.perf_counter()
        channel.publish(step, synthetic_sample(step, rng))
        fe.refresh()
        lat.append(_first_fresh(fe, step, users) - t0)
    fe.close()
    return np.asarray(lat)


def bench_poll(rng) -> np.ndarray:
    root = tempfile.mkdtemp(prefix="bpmf_publat_")
    store = SampleStore(root, keep=WINDOW)
    for s in range(WINDOW):
        store.retain(s, synthetic_sample(s, rng))
    store.wait()
    fe = RecommendFrontend(root, max_batch=1)
    users = iter(np.random.default_rng(0).integers(0, M, 10_000).tolist())
    _first_fresh(fe, WINDOW - 1, users)
    lat = []
    for i in range(PUBLISHES):
        step = WINDOW + i
        t0 = time.perf_counter()
        store.retain(step, synthetic_sample(step, rng))
        # no store.wait(): the async write overlaps serving exactly as a
        # co-running trainer's does; refresh() only sees it once it lands
        lat.append(_first_fresh(fe, step, users) - t0)
    return np.asarray(lat)


def main() -> list[str]:
    rng = np.random.default_rng(7)
    rows = []
    print(f"# ensemble S={WINDOW} x ({M} users, {N} items, k={K}), "
          f"{PUBLISHES} publishes per path, topk={TOPK}")
    push = bench_push(rng)
    poll = bench_poll(rng)
    for name, lat in (("push_channel", push), ("poll_store", poll)):
        p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
        row = csv_row(
            f"publish_to_fresh_{name}", p50 * 1e6,
            f"p50_ms={p50*1e3:.2f} p99_ms={p99*1e3:.2f}",
        )
        print(row)
        rows.append(row)
    print(f"# push is {np.percentile(poll, 50) / np.percentile(push, 50):.1f}x "
          "faster to freshness (and the poll floor here has no poll interval)")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
