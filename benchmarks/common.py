"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

REPO_ROOT = Path(__file__).resolve().parents[1]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (blocks on device results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def parse_csv_row(row: str) -> dict:
    """One printed benchmark row back into its (name, us_per_call, derived)
    record — the schema of the BENCH_<suite>.json artifacts."""
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def append_history_row(record: dict, path: Path | str | None = None) -> Path:
    """Append ONE compact JSON line to BENCH_history.jsonl.

    The full BENCH_<suite>.json artifacts are gitignored (BENCH_*.json), so
    the repo's perf trajectory was invisible across PRs; this file is the
    committed counterpart — one line per `run.py --smoke` invocation, small
    enough to live in git while CI also uploads it alongside the full
    artifacts.
    """
    path = Path(path) if path is not None else REPO_ROOT / "BENCH_history.jsonl"
    with path.open("a") as f:
        f.write(json.dumps(record, separators=(",", ":"), sort_keys=True) + "\n")
    return path


def write_bench_json(suite: str, rows: list[str], extra: dict | None = None,
                     out_dir: Path | str | None = None) -> Path:
    """Persist a suite's rows as BENCH_<suite>.json next to the repo root,
    so the perf trajectory is machine-readable across PRs (CI uploads the
    artifact; benchmarks/roofline.py reads the sweep suite's measurements).
    """
    out_dir = Path(out_dir) if out_dir is not None else REPO_ROOT
    payload = {"suite": suite, "rows": [parse_csv_row(r) for r in rows]}
    if extra:
        payload.update(extra)
    path = out_dir / f"BENCH_{suite}.json"
    path.write_text(json.dumps(payload, indent=2))
    return path
