"""Paper Fig 5: distributed strong scaling, sync vs async communication.

Measured in a subprocess per device count (jax pins the host device count at
first init). For each P in {1, 2, 4, 8}: updates/sec of the ring (pipelined,
GASPI analogue), the all-gather (bulk-synchronous, MPI_bcast analogue), and
the stale-tolerant fused "async" sampler on the ChEMBL-like benchmark, plus
parallel efficiency vs P=1 and an RMSE-parity gate for async at P=4.

Wall-clock on a single shared CPU is a *scheduling* proxy — the structural
comparison (collective bytes, overlap) is in fig6_overlap.py; both views
together reproduce the paper's Fig 5/6 story.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import csv_row

SRC = str(Path(__file__).resolve().parents[1] / "src")

_WORKER = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={p}'
import sys, json, time
sys.path.insert(0, {src!r})
import jax
import jax.numpy as jnp
from repro.data import chembl_like, train_test_split
from repro.core.distributed import DistributedBPMF

def timed_sweeps(s, iters):
    st = s.init(0)
    st = s.sweep(st); jax.block_until_ready(st.u)   # compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        st = s.sweep(st)
        jax.block_until_ready(st.u)
        times.append(time.perf_counter() - t0)
    times.sort()
    return st, times[len(times) // 2]   # median: robust to scheduler noise

ratings, _, _ = chembl_like(scale=0.002, seed=0)
train, test = train_test_split(ratings, 0.05, seed=1)
out = {{}}
for mode in ("ring", "allgather", "async"):
    s = DistributedBPMF(train, test, k=32, alpha=1.5, mode=mode, width=32)
    st, dt = timed_sweeps(s, {iters})
    # per-phase split by ablation: rebuild the same program with every
    # collective replaced by a shape-preserving local stub (ppermute ->
    # identity, all_gather -> broadcast, psum -> x * P). The stub trace is
    # per-instance (each sampler jits its own closure), so compute_s is
    # the same sharded sweep minus communication; exchange_s is the rest.
    # Numerically wrong, timing-valid — an ablation, not a chain.
    real = (jax.lax.ppermute, jax.lax.all_gather, jax.lax.psum)
    n_sh = s.n_shards
    jax.lax.ppermute = lambda x, *a, **kw: x
    jax.lax.all_gather = lambda x, *a, **kw: jnp.broadcast_to(
        x, (n_sh,) + x.shape)
    jax.lax.psum = lambda x, *a, **kw: x * n_sh
    try:
        s2 = DistributedBPMF(train, test, k=32, alpha=1.5, mode=mode,
                             width=32)
        _, compute = timed_sweeps(s2, {iters})
    finally:
        jax.lax.ppermute, jax.lax.all_gather, jax.lax.psum = real
    # run on to a common sweep count before scoring: the stale-by-one
    # async chain needs ~2x the burn-in in sweeps, so RMSE parity is a
    # plateau property, not a sweep-4 property
    for _ in range(10 - 1 - {iters}):
        st = s.sweep(st)
    out[mode] = {{"sweep_s": dt, "compute_s": min(compute, dt),
                  "exchange_s": max(dt - compute, 0.0),
                  "rmse": s.rmse(st),
                  "items": train.shape[0] + train.shape[1]}}
print(json.dumps(out))
"""


def run_p(p: int, iters: int = 3) -> dict:
    code = _WORKER.format(p=p, src=SRC, iters=str(iters))
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
    )
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return json.loads(res.stdout.strip().splitlines()[-1])


def main(smoke: bool = False) -> list[str]:
    import os

    rows = []
    base = {}
    rmse_p4 = {}
    # parallel efficiency is relative to the cores that physically exist:
    # on an n-core host, P > n forced host devices time-slice, so the
    # ideal is base * min(P, n), not base * P. (The seed's flat ~0.5
    # "efficiency" at any P was a recompile artifact — the timed window
    # was compile time, constant in P — not real scaling.)
    cores = os.cpu_count() or 1
    for p in (1, 4) if smoke else (1, 2, 4, 8):
        out = run_p(p, iters=1 if smoke else 3)
        for mode in ("ring", "allgather", "async"):
            d = out[mode]
            ups = d["items"] / d["sweep_s"]
            if p == 1:
                base[mode] = ups
            eff = ups / (base[mode] * min(p, cores))
            if p == 4:
                rmse_p4[mode] = d["rmse"]
            rows.append(csv_row(
                f"fig5_{mode}_p{p}", d["sweep_s"] * 1e6,
                f"updates_per_s={ups:.0f};efficiency={eff:.2f};"
                f"rmse={d['rmse']:.3f};compute_s={d['compute_s']:.4f};"
                f"exchange_s={d['exchange_s']:.4f}",
            ))
    # RMSE-parity gate (paper Sec 5.2): the stale-by-one async chain must
    # land on the same plateau as the exact ring sampler at p=4
    gap = abs(rmse_p4["async"] - rmse_p4["ring"])
    rows.append(csv_row("fig5_async_rmse_parity_p4", 0.0,
                        f"|async-ring|={gap:.4f}"))
    assert gap < 0.05, (
        f"async RMSE diverged from ring at p=4: {rmse_p4['async']:.4f} vs "
        f"{rmse_p4['ring']:.4f}"
    )
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
