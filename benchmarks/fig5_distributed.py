"""Paper Fig 5: distributed strong scaling, sync vs async communication.

Measured in a subprocess per device count (jax pins the host device count at
first init). For each P in {1, 2, 4, 8}: updates/sec of the ring (async,
GASPI analogue) vs the all-gather (bulk-synchronous, MPI_bcast analogue)
sampler on the ChEMBL-like benchmark, plus parallel efficiency vs P=1.

Wall-clock on a single shared CPU is a *scheduling* proxy — the structural
comparison (collective bytes, overlap) is in fig6_overlap.py; both views
together reproduce the paper's Fig 5/6 story.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import csv_row

SRC = str(Path(__file__).resolve().parents[1] / "src")

_WORKER = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={p}'
import sys, json, time
sys.path.insert(0, {src!r})
import jax
from repro.data import chembl_like, train_test_split
from repro.core.distributed import DistributedBPMF

ratings, _, _ = chembl_like(scale=0.002, seed=0)
train, test = train_test_split(ratings, 0.05, seed=1)
out = {{}}
for mode in ("ring", "allgather"):
    s = DistributedBPMF(train, test, k=32, alpha=1.5, mode=mode, width=32)
    st = s.init(0)
    st = s.sweep(st); jax.block_until_ready(st.u)   # compile
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        st = s.sweep(st)
    jax.block_until_ready(st.u)
    dt = (time.perf_counter() - t0) / iters
    out[mode] = {{"sweep_s": dt, "rmse": s.rmse(st),
                  "items": train.shape[0] + train.shape[1]}}
print(json.dumps(out))
"""


def run_p(p: int) -> dict:
    code = _WORKER.format(p=p, src=SRC)
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
    )
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return json.loads(res.stdout.strip().splitlines()[-1])


def main() -> list[str]:
    rows = []
    base = {}
    for p in (1, 2, 4, 8):
        out = run_p(p)
        for mode in ("ring", "allgather"):
            d = out[mode]
            ups = d["items"] / d["sweep_s"]
            if p == 1:
                base[mode] = ups
            eff = ups / (base[mode] * p)
            rows.append(csv_row(
                f"fig5_{mode}_p{p}", d["sweep_s"] * 1e6,
                f"updates_per_s={ups:.0f};efficiency={eff:.2f};rmse={d['rmse']:.3f}",
            ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
