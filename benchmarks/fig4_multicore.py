"""Paper Fig 4: single-node BPMF throughput (updates to U and V per second).

The paper compares TBB / OpenMP / ExaSHARK / GraphLab on 12 cores. On one
CPU device the corresponding axis is the *update engine*:

  naive     per-item python-loop Cholesky updates (the "35 lines of C++"
            baseline before any optimization)
  bucketed  degree-bucketed batched syrk + batched Cholesky (our TPU-style
            engine — the work-stealing analogue)
  kernel    same, routed through the Pallas kernels in interpret mode
            (correctness path; interpret mode is not a speed claim)

Also reports the plan's padding efficiency (= the static load balance the
paper achieves dynamically) and the Fig 2-style degree histogram.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import GibbsSampler
from repro.core.gibbs import update_factors
from repro.data import chembl_like, train_test_split


def naive_update(key, v, indptr, indices, values, hyper, alpha):
    """Per-item loop — the unoptimized reference engine."""
    m = len(indptr) - 1
    k = v.shape[1]
    out = np.zeros((m, k), np.float32)
    vn = np.asarray(v)
    lam = np.asarray(hyper.lam)
    mu = np.asarray(hyper.mu)
    rng = np.random.default_rng(0)
    for i in range(m):
        sl = slice(indptr[i], indptr[i + 1])
        vj = vn[indices[sl]]
        prec = lam + alpha * vj.T @ vj
        rhs = lam @ mu + alpha * vj.T @ values[sl]
        l = np.linalg.cholesky(prec)
        mean = np.linalg.solve(prec, rhs)
        out[i] = mean + np.linalg.solve(l.T, rng.normal(size=k))
    return out


def main(smoke: bool = False) -> list[str]:
    rows = []
    ratings, _, _ = chembl_like(scale=0.004, seed=0)
    train, _ = train_test_split(ratings, 0.05, seed=1)
    k = 32

    deg = train.degrees(0)
    hist, edges = np.histogram(deg[deg > 0], bins=[1, 2, 4, 8, 16, 32, 64, 128, 1024])
    print("# Fig2-style degree histogram (ChEMBL-like):",
          dict(zip(edges[:-1].tolist(), hist.tolist())))

    # balanced planner (the work-stealing analogue): widths fit to the
    # degree profile, per entity set
    s = GibbsSampler(train, None, k=k, alpha=1.5, widths="balanced")
    print("# plan:", s.user_plan_host.stats())
    state = s.init(0)
    n_items = s.m + s.n

    # bucketed engine (jit, jnp path)
    sweep = jax.jit(s._sweep_impl)
    t = time_fn(sweep, state, warmup=1, iters=1 if smoke else 3)
    rows.append(csv_row("fig4_bucketed_updates_per_s", t * 1e6, f"{n_items / t:.0f}"))

    if not smoke:
        # kernel path (interpret mode — correctness, not speed)
        sk = GibbsSampler(train, None, k=k, alpha=1.5, widths="balanced",
                          use_kernel=True)
        sweep_k = jax.jit(sk._sweep_impl)
        t_k = time_fn(sweep_k, sk.init(0), warmup=1, iters=1)
        rows.append(csv_row("fig4_kernel_interpret_updates_per_s", t_k * 1e6, f"{n_items / t_k:.0f}"))

        # naive python engine on a subsample (extrapolated)
        sub = 200
        from repro.data.sparse import csr_from_coo
        c = train.centered()
        indptr, indices, values = csr_from_coo(c.rows, c.cols, c.vals, s.m)
        import time as _t
        t0 = _t.perf_counter()
        naive_update(None, np.asarray(state.v), indptr[: sub + 1], indices, values,
                     state.hyper_u, 1.5)
        t_n = (_t.perf_counter() - t0) * (s.m / sub) * 2  # both U and V sweeps
        rows.append(csv_row("fig4_naive_updates_per_s", t_n * 1e6, f"{n_items / t_n:.0f}"))

    eff = s.user_plan_host.padding_efficiency
    rows.append(csv_row("fig4_plan_padding_efficiency", 0.0, f"{eff:.3f}"))
    # the load-balance gate this figure now reports against: the balanced
    # planner must clear 0.7 on the chembl-like profile (the pow2 ladder
    # sat at 0.290)
    assert eff > 0.7, f"balanced plan padding_efficiency {eff:.3f} <= 0.7"

    # Fig 3-style study: bucket-width ladders trade MXU lane fill against
    # per-bucket launch count (the paper's rank-one-vs-Cholesky threshold,
    # restated as a static planning knob). "balanced" = the degree-fit DP.
    from repro.core.buckets import plan_buckets
    from repro.data.sparse import csr_from_coo

    c = train.centered()
    indptr, indices, values = csr_from_coo(c.rows, c.cols, c.vals, s.m)
    for widths in ("balanced", (4, 16, 64), (8, 32, 128, 512), (16, 128),
                   (32,), (256,)):
        p = plan_buckets(indptr, indices, values, s.m, s.n, widths)
        tag = widths if isinstance(widths, str) else "x".join(map(str, widths))
        rows.append(csv_row(
            f"fig4_widths_{tag}", 0.0,
            f"lane_eff={p.padding_efficiency:.3f};rows={sum(b.rows for b in p.buckets)}",
        ))
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
